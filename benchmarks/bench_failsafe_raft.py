"""Failsafe (paper §3.4) and Raft HA (paper §3.4.1, Fig. 3) benchmarks."""

from __future__ import annotations

import time

from repro.core import Colonies, Crypto, ExecutorBase, FunctionSpec, InProcTransport
from repro.core.cluster import HAColonyCluster, standalone_server
from repro.core.raft import SimRaftCluster, ThreadedRaftCluster

from .common import Row, timeit


def run() -> None:
    # --- failsafe scan cost vs table size -------------------------------
    server_prv, colony_prv = Crypto.prvkey(), Crypto.prvkey()
    srv = standalone_server(Crypto.id(server_prv), verify_signatures=False)
    client = Colonies(InProcTransport([srv]), insecure=True)
    client.add_colony("bench", Crypto.id(colony_prv), server_prv)
    for i in range(2000):
        client.submit(
            FunctionSpec.from_dict({
                "conditions": {"colonyname": "bench", "executortype": "worker"},
                "funcname": "echo", "maxexectime": 3600,
            }),
            colony_prv,
        )
    us = timeit(srv.failsafe_scan, 20)
    Row.add("failsafe_scan_2000_procs", us, "stateless deadline sweep")

    # --- recovery latency: crash -> re-queued ----------------------------
    ex = ExecutorBase(client, "bench", "w", "worker", colony_prvkey=colony_prv)
    p = client.submit(
        FunctionSpec.from_dict({
            "conditions": {"colonyname": "bench", "executortype": "worker"},
            "funcname": "echo", "maxexectime": 1, "maxretries": 5,
        }),
        colony_prv,
    )
    client.assign("bench", 2.0, ex.prvkey)  # take the lease and vanish
    t0 = time.perf_counter()
    deadline = time.time() + 10
    while time.time() < deadline:
        srv.failsafe_scan()
        if client.get_process(p["processid"], colony_prv)["state"] == "waiting":
            break
        time.sleep(0.02)
    us = (time.perf_counter() - t0) * 1e6
    Row.add("failsafe_recovery_lease_1s", us, "crash -> re-queued")
    srv.stop()

    # --- raft: election + failover + replication throughput --------------
    elect_ms = []
    for seed in range(5):
        sim = SimRaftCluster(3, seed=seed)
        t0 = sim.now_ms
        assert sim.run_until_leader() is not None
        elect_ms.append(sim.now_ms - t0)
    Row.add("raft_election_3node", sum(elect_ms) / len(elect_ms) * 1e3,
            f"{min(elect_ms)}-{max(elect_ms)} ms simclock")

    fail_ms = []
    for seed in range(5):
        sim = SimRaftCluster(3, seed=seed + 50)
        l1 = sim.run_until_leader()
        sim.kill(l1)
        t0 = sim.now_ms
        while not [l for l in sim.leaders() if l != l1]:
            sim.step()
        fail_ms.append(sim.now_ms - t0)
    Row.add("raft_failover_3node", sum(fail_ms) / len(fail_ms) * 1e3,
            f"{min(fail_ms)}-{max(fail_ms)} ms simclock")

    sim = SimRaftCluster(3, seed=7)
    leader = sim.run_until_leader()
    n = 200
    t0 = time.perf_counter()
    for v in range(n):
        sim.nodes[leader].propose({"v": v})
        sim.step()
    while sim.nodes[leader].last_applied < n - 1:
        sim.step()
    us = (time.perf_counter() - t0) / n * 1e6
    Row.add("raft_replicated_propose", us, f"{1e6 / us:.0f} entries/s (wallclock)")

    # --- commit wakeup: condition-variable wait vs poll loop --------------
    # propose_and_wait parks on the node's commit_cv (notified from
    # _apply_committed); before PR 8 it polled last_applied on a
    # tick_ms/2 sleep loop. Measure both against the same live cluster
    # (the poll variant re-implements the old loop inline at its exact
    # sleep interval). tick_ms=1 so commit latency doesn't quantize both
    # variants to the same tick boundary.
    cluster = ThreadedRaftCluster(3, seed=13, tick_ms=1)
    cluster.start()
    try:
        deadline = time.time() + 10
        leader = None
        while time.time() < deadline and leader is None:
            leader = cluster.leader_id()
            time.sleep(0.02)
        assert leader is not None
        node = cluster.nodes[leader]

        def propose_cv() -> None:
            cluster.propose_and_wait(leader, {"op": "noop"})

        def propose_poll() -> None:
            with cluster._lock:
                idx = node.propose({"op": "noop"})
            assert idx is not None
            while node.last_applied < idx:
                time.sleep(cluster.tick_ms / 2000.0)

        us_poll = timeit(propose_poll, 30)
        us_cv = timeit(propose_cv, 30)
        Row.add("raft_commit_wait_poll", us_poll, "pre-PR8 sleep-poll loop")
        Row.add("raft_commit_wait_cv", us_cv,
                f"{us_poll / us_cv:.2f}x vs poll; wakes on notify, 0 poll"
                " wakeups")
    finally:
        cluster.stop()

    # --- HA assign latency end-to-end (raft-serialized broker op) ---------
    ha = HAColonyCluster(Crypto.id(server_prv), replicas=3,
                         verify_signatures=False, seed=14)
    ha.start(failsafe_interval=5.0)
    try:
        assert ha.wait_for_leader(10)
        hclient = Colonies(InProcTransport(ha.servers), insecure=True)
        hclient.add_colony("habench", Crypto.id(colony_prv), server_prv)
        hex_ = ExecutorBase(hclient, "habench", "ha-w", "worker",
                            colony_prvkey=colony_prv)
        n = 30
        for _ in range(n):
            hclient.submit(
                FunctionSpec.from_dict({
                    "conditions": {"colonyname": "habench",
                                   "executortype": "worker"},
                    "funcname": "echo", "maxexectime": 3600,
                }),
                colony_prv,
            )
        t0 = time.perf_counter()
        for _ in range(n):
            pd = hclient.assign("habench", 5.0, hex_.prvkey)
            hclient.close(pd["processid"], [], hex_.prvkey)
        us = (time.perf_counter() - t0) / (2 * n) * 1e6
        Row.add("ha_assign_close_op", us,
                "per raft-serialized broker op, 3 replicas")
    finally:
        ha.stop()
