"""CFS metadata-plane scaling (paper §3.4.5): ops must be flat in table size.

The colony holds ``total`` file revisions, almost all of them cold bulk
data spread over many labels; a fixed 100-file ``/hot`` subtree is the
working set. ``getfile``/``getfiles``/``createsnapshot`` against the hot
subtree must cost the same no matter how much cold data the colony has
accumulated — the seed implementation ``kv_list``-scanned every file in
every colony on each of these RPCs, so its latency grew linearly with
``total``.

Also probes ``removefile``'s pin check (refcount read, not a scan over
every snapshot) with many snapshots present.
"""

from __future__ import annotations

from repro.core import Colonies, Crypto, InProcTransport, MemoryDatabase, SqliteDatabase
from repro.core.cluster import standalone_server

from .common import Row, timeit

HOT_FILES = 100


def _setup(db):
    server_prv, colony_prv = Crypto.prvkey(), Crypto.prvkey()
    srv = standalone_server(Crypto.id(server_prv), db, verify_signatures=False)
    client = Colonies(InProcTransport([srv]), insecure=True)
    client.add_colony("bench", Crypto.id(colony_prv), server_prv)
    return srv, client, colony_prv


def _fill(client, colony_prv, total: int) -> None:
    """HOT_FILES files under /hot; the rest cold, fanned over 64 labels."""
    for i in range(HOT_FILES):
        client.add_file(
            {"colonyname": "bench", "label": "/hot", "name": f"h{i:04d}.bin",
             "size": 1, "checksum": f"{i:064x}",
             "storage": {"backend": "mem", "url": f"mem://{i:064x}"}},
            colony_prv,
        )
    for i in range(total - HOT_FILES):
        client.add_file(
            {"colonyname": "bench", "label": f"/bulk/shard-{i % 64:02d}",
             "name": f"c{i:06d}.bin", "size": 1, "checksum": f"{i:064x}",
             "storage": {"backend": "mem", "url": f"mem://{i:064x}"}},
            colony_prv,
        )


def run() -> None:
    for db_name, db_factory in (("memdb", MemoryDatabase), ("sqlite", SqliteDatabase)):
        for total in (100, 10_000):
            srv, client, colony_prv = _setup(db_factory())
            _fill(client, colony_prv, total)
            us = timeit(
                lambda: client.get_file("bench", "/hot", "h0050.bin", colony_prv), 100
            )
            Row.add(f"cfs_getfile_{db_name}_total_{total}", us, "head lookup")
            us = timeit(lambda: client.get_files("bench", "/hot", colony_prv), 50)
            Row.add(
                f"cfs_getfiles_{db_name}_total_{total}", us,
                f"{HOT_FILES}-file subtree listing",
            )
            us = timeit(
                lambda: client.create_snapshot("bench", "/hot", "s", colony_prv), 50
            )
            Row.add(
                f"cfs_snapshot_{db_name}_total_{total}", us,
                f"pin {HOT_FILES}-file subtree",
            )
            # removefile pin check with many snapshots on the books: add/remove
            # an unpinned scratch file (the snapshots above pinned /hot only).
            def pin_cycle():
                meta = client.add_file(
                    {"colonyname": "bench", "label": "/scratch", "name": "x",
                     "size": 1, "checksum": "0" * 64,
                     "storage": {"backend": "mem", "url": "mem://" + "0" * 64}},
                    colony_prv,
                )
                client.remove_file("bench", meta["fileid"], colony_prv)

            us = timeit(pin_cycle, 50)
            Row.add(
                f"cfs_add_remove_{db_name}_total_{total}", us,
                "pin check vs 50+ snapshots",
            )
            srv.stop()
