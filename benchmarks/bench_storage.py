"""Blob-plane benchmark (STORAGE.md): put/get/repair latency vs shard
count, over both child backends.

Three questions the sharded design must answer with numbers:

* what does R-way replication cost on the write path (put latency vs a
  single raw backend, across shard counts)?
* is the read path free of sharding overhead when all replicas are
  healthy (get latency vs shard count)?
* what does a degraded read cost (get that finds its first replica
  missing, rotates, and read-repairs it on the way out)?

``memdb`` rows use MemoryStorage children (pure data-structure cost);
``local`` rows use LocalStorage children on disk — the memdb-vs-local
parity check for the same ring/replication logic. Also prints the
observed key split across shards so the vnode count can be judged
(VNODES=64 should keep a 3-shard ring within a few percent of even).
"""

from __future__ import annotations

import shutil
import tempfile

from repro.core.blobstore import ShardedStorage
from repro.core.fs import LocalStorage, MemoryStorage, checksum

from .common import Row, timeit

BLOB = b"\x5a" * 4096  # 4 KiB — checkpoint-chunk-shaped
SHARD_COUNTS = (1, 3, 8)
REPLICAS = 2
SPLIT_KEYS = 600


def _payloads(salt: str, n: int) -> list[bytes]:
    # Salted per benchmark section: content-addressed stores dedupe, so
    # reused payloads would hit the exists-short-circuit and bench nothing.
    return [BLOB + salt.encode() + i.to_bytes(4, "big") for i in range(n)]


def _bench_backend(backend: str, make_children) -> None:
    # Raw single-backend baseline (no ring, no replication).
    raw = make_children("raw", 1)[0]
    datas = iter(_payloads(f"{backend}-raw", 10_000))
    us = timeit(lambda: raw.put(next(datas)), 200)
    Row.add(f"storage_put_raw_{backend}", us, "single backend, no replication")
    url = raw.put(BLOB)
    us = timeit(lambda: raw.get(url), 200)
    Row.add(f"storage_get_raw_{backend}", us, "single backend")

    for n in SHARD_COUNTS:
        store = ShardedStorage(make_children(f"ring{n}", n), replicas=REPLICAS)
        datas = iter(_payloads(f"{backend}-{n}", 10_000))
        us = timeit(lambda: store.put(next(datas)), 200)
        Row.add(
            f"storage_put_{backend}_shards_{n}", us,
            f"R={store.replicas} replicated write",
        )
        url = store.put(BLOB)
        us = timeit(lambda: store.get(url), 200)
        Row.add(
            f"storage_get_{backend}_shards_{n}", us,
            "healthy read, first replica",
        )
        if n > 1:
            # Degraded read: first replica missing -> rotate + read-repair.
            key = url.split("://", 1)[1]
            first = store.replicas_for(key)[0]

            def degraded_get():
                store.shards[first].quarantine(key)
                return store.get(url)  # repairs `first` on the way out

            us = timeit(degraded_get, 100)
            Row.add(
                f"storage_repair_{backend}_shards_{n}", us,
                "rotate past missing replica + read-repair",
            )


def _key_split(n: int = 3) -> str:
    store = ShardedStorage([MemoryStorage() for _ in range(n)], replicas=1)
    counts = [0] * n
    for i in range(SPLIT_KEYS):
        counts[store.replicas_for(checksum(i.to_bytes(4, "big")))[0]] += 1
    return "/".join(str(c) for c in counts)


def run() -> None:
    _bench_backend("memdb", lambda tag, n: [MemoryStorage() for _ in range(n)])
    tmp = tempfile.mkdtemp(prefix="bench_storage_")
    try:
        _bench_backend(
            "local",
            lambda tag, n: [LocalStorage(f"{tmp}/{tag}-{i}") for i in range(n)],
        )
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    Row.add("storage_ring_split_3shards", 0.0, f"{SPLIT_KEYS} keys split {_key_split()}")
