"""Workflow engine (paper Tables 3-4, Fig. 4): DAG latency + dataflow."""

from __future__ import annotations

import time

from repro.core import Colonies, Crypto, ExecutorBase, InProcTransport, WorkflowSpec
from repro.core.cluster import standalone_server

from .common import Row


def _node(name, deps):
    return {
        "nodename": name,
        "funcname": "echo",
        "conditions": {"executortype": "worker", "dependencies": deps},
    }


def run() -> None:
    server_prv, colony_prv = Crypto.prvkey(), Crypto.prvkey()
    srv = standalone_server(Crypto.id(server_prv), verify_signatures=False)
    client = Colonies(InProcTransport([srv]), insecure=True)
    client.add_colony("bench", Crypto.id(colony_prv), server_prv)
    workers = []
    for i in range(2):
        ex = ExecutorBase(client, "bench", f"w{i}", "worker", colony_prvkey=colony_prv)
        ex.register_function("echo", lambda ctx, *a: list(ctx.inputs) or [0])
        ex.start(poll_timeout=0.1)
        workers.append(ex)

    # Fig. 4 diamond: t1 -> (t2 | t3) -> t4
    diamond = WorkflowSpec.from_dict({
        "colonyname": "bench",
        "functionspecs": [
            _node("t1", []), _node("t2", ["t1"]), _node("t3", ["t1"]),
            _node("t4", ["t2", "t3"]),
        ],
    })
    n = 10
    t0 = time.perf_counter()
    for _ in range(n):
        r = client.submit_workflow(diamond, colony_prv)
        client.wait(r["processes"][-1]["processid"], colony_prv, timeout=30, poll=0.01)
    us = (time.perf_counter() - t0) / n * 1e6
    Row.add("workflow_diamond_4node_e2e", us, f"{us / 4:.0f} us/process")

    # sequential chain of 8 — pure dependency-release latency
    chain = WorkflowSpec.from_dict({
        "colonyname": "bench",
        "functionspecs": [_node(f"c{i}", [f"c{i-1}"] if i else []) for i in range(8)],
    })
    t0 = time.perf_counter()
    for _ in range(5):
        r = client.submit_workflow(chain, colony_prv)
        client.wait(r["processes"][-1]["processid"], colony_prv, timeout=30, poll=0.01)
    us = (time.perf_counter() - t0) / 5 * 1e6
    Row.add("workflow_chain_8node_e2e", us, f"{us / 8:.0f} us/hop")

    for ex in workers:
        ex.stop()
    srv.stop()
