"""Shared benchmark utilities."""

from __future__ import annotations

import time


def timeit(fn, n: int, warmup: int = 1) -> float:
    """Mean microseconds per call."""
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


class Row:
    rows: list[tuple[str, float, str]] = []

    @classmethod
    def add(cls, name: str, us_per_call: float, derived: str = "") -> None:
        cls.rows.append((name, us_per_call, derived))
        print(f"{name},{us_per_call:.1f},{derived}", flush=True)

    @classmethod
    def dump(cls) -> list[tuple[str, float, str]]:
        return list(cls.rows)
