"""Compute-plane benchmarks: smoke-config train/decode step timings on CPU
(per assigned architecture) — the executor-side cost the broker dispatches."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, TrainConfig, get_config
from repro.data.pipeline import SyntheticTokens
from repro.models import decode_step, init_params, model_spec, prefill
from repro.train.train_step import init_state, make_train_step

from .common import Row


def run() -> None:
    for arch in ARCH_IDS:
        cfg = get_config(arch, "smoke").copy(
            param_dtype="float32", compute_dtype="float32"
        )
        params = init_params(jax.random.key(0), model_spec(cfg), jnp.float32)
        tcfg = TrainConfig()
        state = init_state(params, tcfg)
        step = jax.jit(make_train_step(cfg, tcfg))
        batch = {
            k: jnp.asarray(v)
            for k, v in SyntheticTokens(cfg, 2, 32, seed=0).batch_at(0).items()
        }
        state, _ = step(state, batch)  # compile
        n = 5
        t0 = time.perf_counter()
        for _ in range(n):
            state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
        Row.add(f"train_step_smoke_{arch}", (time.perf_counter() - t0) / n * 1e6,
                "B=2 S=32 cpu")

        pre = dict(batch)
        pre["tokens"] = batch["tokens"][:, :16]
        _, cache = jax.jit(lambda p, b: prefill(p, cfg, b, max_len=64))(params, pre)
        dec = jax.jit(lambda p, t, c, pos: decode_step(p, cfg, t, c, pos))
        tok = batch["tokens"][:, :1]
        logits, cache = dec(params, tok, cache, jnp.int32(16))  # compile
        t0 = time.perf_counter()
        for i in range(n):
            logits, cache = dec(params, tok, cache, jnp.int32(17 + i))
        jax.block_until_ready(logits)
        Row.add(f"serve_step_smoke_{arch}", (time.perf_counter() - t0) / n * 1e6,
                "B=2 one token cpu")
