"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus a trailing roofline
summary distilled from the dry-run artifacts, if present).

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run broker     # one suite
"""

from __future__ import annotations

import json
import os
import sys

SUITES = ("broker", "workflow", "failsafe_raft", "crypto_cfs", "cfs", "storage", "models")


def _roofline_summary() -> None:
    """Append per-cell roofline rows from results/dryrun (if generated)."""
    outdir = "results/dryrun"
    if not os.path.isdir(outdir):
        return
    from benchmarks.common import Row

    for fname in sorted(os.listdir(outdir)):
        if not fname.endswith(".json") or fname == "summary.json":
            continue
        with open(os.path.join(outdir, fname)) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        r = rec["roofline"]
        dominant = max(r["compute_s"], r["memory_s"], r["collective_s"])
        Row.add(
            f"roofline_{fname[:-5]}",
            dominant * 1e6,  # dominant-term step time, us
            f"{r['bottleneck']}-bound frac={r['roofline_fraction']:.4f}",
        )


def main() -> None:
    wanted = sys.argv[1:] or list(SUITES)
    print("name,us_per_call,derived")
    for suite in wanted:
        if suite not in SUITES:
            raise SystemExit(f"unknown suite {suite!r}; known: {SUITES}")
        module = __import__(f"benchmarks.bench_{suite}", fromlist=["run"])
        module.run()
    if not sys.argv[1:]:
        _roofline_summary()


if __name__ == "__main__":
    main()
