"""Broker throughput (paper Tables 1-2, Fig. 2): the process table as a queue.

Measures submit/assign/close cycles across database backends and with the
zero-trust signature path on and off (isolates crypto cost from queue
cost), plus three hot-path scaling probes:

* candidate-query latency vs queue depth, with a *realistic* queue mix —
  blocked (``wait_for_parents``) and executor-pinned processes sorted
  ahead of the runnable tail, exactly the population that pinned the
  seed broker's queue head;
* ``colonystats`` latency vs total processes ever stored (counter-backed
  stats must be flat);
* idle ``failsafe_scan`` tick latency vs fleet size (deadline-heap scans
  must be flat).
"""

from __future__ import annotations

import time

from repro.core import (
    Colonies,
    Crypto,
    ExecutorBase,
    FunctionSpec,
    InProcTransport,
    MemoryDatabase,
    SqliteDatabase,
)
from repro.core.cluster import standalone_server
from repro.core.process import FAILED, RUNNING, SUCCESSFUL, WAITING, Process, now_ns

from .common import Row, timeit


def _setup(db, verify: bool, idempotency: bool = True):
    server_prv = Crypto.prvkey()
    colony_prv = Crypto.prvkey()
    srv = standalone_server(Crypto.id(server_prv), db, verify_signatures=verify)
    client = Colonies(
        InProcTransport([srv]), insecure=not verify, idempotency=idempotency
    )
    client.add_colony("bench", Crypto.id(colony_prv), server_prv)
    ex = ExecutorBase(client, "bench", "w", "worker", colony_prvkey=colony_prv)
    ex.register_function("echo", lambda ctx, *a: list(a))
    return srv, client, colony_prv, ex


def _spec(priority: int = 0, names: list[str] | None = None) -> FunctionSpec:
    return FunctionSpec.from_dict({
        "conditions": {"colonyname": "bench", "executortype": "worker",
                       "executornames": names or []},
        "funcname": "echo", "args": [1], "maxexectime": 300, "priority": priority,
    })


def _fill_queue_mix(db, depth: int) -> None:
    """Realistic backlog: 40% blocked on parents, 40% pinned to another
    executor — all *older* (better priority_time) than the runnable 20%,
    so naive head scans must wade through them on every call."""
    base = now_ns()
    n_blocked = n_pinned = 2 * depth // 5
    for i in range(n_blocked):
        p = Process.create(_spec(), submission_ns=base - 2 * 10**9 + i)
        p.wait_for_parents = True
        db.add_process(p)
    for i in range(n_pinned):
        p = Process.create(_spec(names=["some-other-executor"]),
                           submission_ns=base - 10**9 + i)
        db.add_process(p)
    for i in range(depth - n_blocked - n_pinned):
        db.add_process(Process.create(_spec(), submission_ns=base + i))


def run() -> None:
    cycle_us: dict[tuple[str, str], float] = {}
    for db_name, db_factory in (("memdb", MemoryDatabase), ("sqlite", SqliteDatabase)):
        for verify in (True, False):
            srv, client, colony_prv, ex = _setup(db_factory(), verify)
            n = 30 if verify else 200

            def cycle():
                client.submit(_spec(), colony_prv)
                ex.step(timeout=2.0)

            us = timeit(cycle, n, warmup=2)
            tag = "signed" if verify else "nosig"
            cycle_us[(db_name, tag)] = us
            Row.add(
                f"broker_submit_assign_close_{db_name}_{tag}",
                us,
                f"{1e6 / us:.0f} proc/s",
            )
            srv.stop()

    # dedup overhead: the exactly-once bookkeeping a keyed RPC adds with
    # retries idle — msgid generation (client) plus spec lookup, replay
    # probe (a miss), colony attribution and the marshal reply snapshot
    # (server). Timed per-operation rather than as an end-to-end A/B: on
    # a 1-core box the cycle's run-to-run jitter (GC and scheduler) is
    # ±15%, which swamps a few-µs effect in either direction. The note
    # relates it to BOTH cycles above: the signed cycle is the
    # production hot path (zero-trust signatures are mandatory outside
    # benchmarks — ROBUSTNESS.md bounds the overhead there at <5%, and
    # it lands orders of magnitude under), while the crypto-free cycle
    # is the harshest possible denominator.
    from repro.core import idempotency
    from repro.core.process import new_id

    for db_name, db_factory in (("memdb", MemoryDatabase), ("sqlite", SqliteDatabase)):
        srv, client, colony_prv, ex = _setup(db_factory(), False)
        db = srv.db
        client.submit(_spec(), colony_prv)
        reply = client.get_processes("bench", colony_prv)[0]  # realistic size
        payload = {"spec": _spec().to_dict()}
        seq = iter(range(10**9))

        def keyed_rpc_extra():
            m = new_id()
            idempotency.classify("submitfunctionspec")
            key = f"id:{m}"
            db.dedup_get(key)  # miss: the hot (non-replay) path
            colony = idempotency.reply_colony("submitfunctionspec", payload, reply)
            db.dedup_put(f"{key}:{next(seq)}", colony, now_ns(), reply)

        us = timeit(keyed_rpc_extra, 500, warmup=20)
        per_cycle = 3 * us  # submit, assign and close are all keyed
        pct_signed = 100.0 * per_cycle / cycle_us[(db_name, "signed")]
        pct_nosig = 100.0 * per_cycle / cycle_us[(db_name, "nosig")]
        Row.add(
            f"broker_dedup_overhead_{db_name}",
            us,
            f"per keyed RPC; cycle +{pct_signed:.2f}% signed"
            f" +{pct_nosig:.1f}% nosig",
        )
        srv.stop()

    # queue-depth scaling: candidate query latency with a deep, mixed
    # backlog (blocked + pinned processes ahead of the runnable head)
    for depth in (100, 1000, 5000):
        srv, client, colony_prv, ex = _setup(MemoryDatabase(), False)
        db = srv.db
        _fill_queue_mix(db, depth)
        us = timeit(lambda: db.candidates("bench", "worker", "w"), 200)
        Row.add(f"broker_candidates_depth_{depth}", us, "queue head lookup")
        srv.stop()

    # colonystats scaling: counter-backed stats must not scan the table
    for total in (100, 10_000):
        srv, client, colony_prv, ex = _setup(MemoryDatabase(), False)
        db = srv.db
        states = (WAITING, RUNNING, SUCCESSFUL, FAILED)
        for i in range(total):
            p = Process.create(_spec())
            p.state = states[i % 4]
            db.add_process(p)
        us = timeit(lambda: client.stats("bench", colony_prv), 200)
        Row.add(f"broker_stats_total_{total}", us, "colonystats latency")
        srv.stop()

    # failsafe scaling: the 250 ms tick over a healthy running fleet
    for total in (100, 10_000):
        srv, client, colony_prv, ex = _setup(MemoryDatabase(), False)
        db = srv.db
        far = now_ns() + 3600 * 10**9
        for i in range(total):
            p = Process.create(_spec())
            p.state = RUNNING
            p.deadline_ns = far + i
            db.add_process(p)
        us = timeit(srv.failsafe_scan, 100)
        Row.add(f"broker_failsafe_fleet_{total}", us, "idle failsafe tick")
        srv.stop()
