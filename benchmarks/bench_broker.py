"""Broker throughput (paper Tables 1-2, Fig. 2): the process table as a queue.

Measures submit/assign/close cycles across database backends and with the
zero-trust signature path on and off (isolates crypto cost from queue
cost), plus candidate-query latency vs queue depth (the ORDER BY
priority_time index at work).
"""

from __future__ import annotations

import time

from repro.core import (
    Colonies,
    Crypto,
    ExecutorBase,
    FunctionSpec,
    InProcTransport,
    MemoryDatabase,
    SqliteDatabase,
)
from repro.core.cluster import standalone_server

from .common import Row, timeit


def _setup(db, verify: bool):
    server_prv = Crypto.prvkey()
    colony_prv = Crypto.prvkey()
    srv = standalone_server(Crypto.id(server_prv), db, verify_signatures=verify)
    client = Colonies(InProcTransport([srv]), insecure=not verify)
    client.add_colony("bench", Crypto.id(colony_prv), server_prv)
    ex = ExecutorBase(client, "bench", "w", "worker", colony_prvkey=colony_prv)
    ex.register_function("echo", lambda ctx, *a: list(a))
    return srv, client, colony_prv, ex


def _spec(priority: int = 0) -> FunctionSpec:
    return FunctionSpec.from_dict({
        "conditions": {"colonyname": "bench", "executortype": "worker"},
        "funcname": "echo", "args": [1], "maxexectime": 300, "priority": priority,
    })


def run() -> None:
    for db_name, db_factory in (("memdb", MemoryDatabase), ("sqlite", SqliteDatabase)):
        for verify in (True, False):
            srv, client, colony_prv, ex = _setup(db_factory(), verify)
            n = 30 if verify else 200

            def cycle():
                client.submit(_spec(), colony_prv)
                ex.step(timeout=2.0)

            us = timeit(cycle, n, warmup=2)
            tag = "signed" if verify else "nosig"
            Row.add(
                f"broker_submit_assign_close_{db_name}_{tag}",
                us,
                f"{1e6 / us:.0f} proc/s",
            )
            srv.stop()

    # queue-depth scaling: candidate query latency with a deep backlog
    for depth in (100, 1000, 5000):
        srv, client, colony_prv, ex = _setup(MemoryDatabase(), False)
        for i in range(depth):
            client.submit(_spec(priority=i % 3), colony_prv)
        db = srv.db
        us = timeit(lambda: db.candidates("bench", "worker", "w"), 200)
        Row.add(f"broker_candidates_depth_{depth}", us, "queue head lookup")
        srv.stop()
