"""Zero-trust crypto (paper §3.4.6) and CFS (paper §3.4.5) benchmarks."""

from __future__ import annotations

from repro.core import Colonies, Crypto, InProcTransport
from repro.core.cluster import standalone_server
from repro.core.fs import CFSClient, MemoryStorage, checksum

from .common import Row, timeit


def run() -> None:
    prv = Crypto.prvkey()
    msg = b"x" * 256
    sig = Crypto.sign(msg, prv)
    Row.add("crypto_sign_256B", timeit(lambda: Crypto.sign(msg, prv), 20),
            "ECDSA secp256k1 + RFC6979")
    Row.add("crypto_recover_256B", timeit(lambda: Crypto.recover(msg, sig), 20),
            "pubkey recovery + SHA3 id")

    server_prv, colony_prv = Crypto.prvkey(), Crypto.prvkey()
    srv = standalone_server(Crypto.id(server_prv), verify_signatures=False)
    client = Colonies(InProcTransport([srv]), insecure=True)
    client.add_colony("bench", Crypto.id(colony_prv), server_prv)
    cfs = CFSClient(client, MemoryStorage(), colony_prv)

    blob = b"\xab" * (1 << 20)  # 1 MiB
    i = [0]

    def up():
        i[0] += 1
        cfs.upload_bytes("bench", "/bench", f"f{i[0]}.bin", blob)

    us = timeit(up, 20)
    Row.add("cfs_upload_1MiB", us, f"{1.0 / (us / 1e6):.0f} MiB/s metadata+store")
    us = timeit(lambda: cfs.download_bytes("bench", "/bench", "f5.bin"), 20)
    Row.add("cfs_download_1MiB", us, "checksum-verified")

    for _ in range(80):
        up()
    us = timeit(
        lambda: client.create_snapshot("bench", "/bench", "s", colony_prv), 10
    )
    Row.add("cfs_snapshot_100files", us, "revision pinning")
    srv.stop()
