"""Broker-scale regression tests: the indexed core does bounded work.

10k-process scenarios assert that ``colonystats`` reads counters (never a
table scan), the failsafe pops only expired deadline-heap entries, and the
candidate queues side-line blocked/targeted processes and actually evict
stale entries — the O(n)-per-tick behaviours of the seed broker stay gone.
"""

import pytest

from repro.core import (
    Colonies,
    Crypto,
    FunctionSpec,
    InProcTransport,
    MemoryDatabase,
    SqliteDatabase,
)
from repro.core.cluster import standalone_server
from repro.core.process import FAILED, RUNNING, SUCCESSFUL, WAITING, Process, now_ns


def _spec(colony="scale", etype="worker", priority=0, names=None, **kw):
    d = {
        "conditions": {
            "colonyname": colony,
            "executortype": etype,
            "executornames": names or [],
        },
        "funcname": "echo",
        "priority": priority,
    }
    d.update(kw)
    return FunctionSpec.from_dict(d)


def _proc(state=WAITING, ts=None, **kw):
    p = Process.create(_spec(**kw), submission_ns=ts)
    p.state = state
    return p


# ---------------------------------------------------------------------------
# colonystats: O(1) counters, total over every state
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("db_factory", [MemoryDatabase, SqliteDatabase])
def test_colonystats_counter_backed_at_10k(db_factory, monkeypatch):
    server_prv = Crypto.prvkey()
    colony_prv = Crypto.prvkey()
    db = db_factory()
    srv = standalone_server(Crypto.id(server_prv), db, verify_signatures=False)
    client = Colonies(InProcTransport([srv]), insecure=True)
    client.add_colony("scale", Crypto.id(colony_prv), server_prv)

    n = 10_000 if db_factory is MemoryDatabase else 2_000
    mix = (WAITING, RUNNING, SUCCESSFUL, FAILED, WAITING)
    for i in range(n):
        db.add_process(_proc(state=mix[i % len(mix)]))

    # The handler must never fall back to scanning the process table.
    def no_scan(*a, **kw):
        raise AssertionError("colonystats scanned the process table")

    monkeypatch.setattr(db, "list_processes", no_scan)
    stats = client.stats("scale", colony_prv)
    assert stats["waiting"] == 2 * (n // 5)
    assert stats["running"] == n // 5
    assert stats["successful"] == n // 5
    assert stats["failed"] == n // 5
    srv.stop()


@pytest.mark.parametrize("db_factory", [MemoryDatabase, SqliteDatabase])
def test_colonystats_total_over_unknown_states(db_factory):
    """A process in a state outside the four counted ones must not
    KeyError the endpoint (seed bug) — it shows up as its own bucket."""
    server_prv = Crypto.prvkey()
    colony_prv = Crypto.prvkey()
    db = db_factory()
    srv = standalone_server(Crypto.id(server_prv), db, verify_signatures=False)
    client = Colonies(InProcTransport([srv]), insecure=True)
    client.add_colony("scale", Crypto.id(colony_prv), server_prv)
    db.add_process(_proc(state=WAITING))
    db.add_process(_proc(state="quarantined"))
    stats = client.stats("scale", colony_prv)
    assert stats["waiting"] == 1 and stats["quarantined"] == 1
    srv.stop()


def test_counters_track_full_lifecycle():
    db = MemoryDatabase()
    procs = [_proc() for _ in range(50)]
    for p in procs:
        db.add_process(p)
    assert db.colony_stats("scale") == {WAITING: 50}
    for p in procs[:30]:
        p.state = RUNNING
        db.update_process(p)
    for p in procs[:10]:
        p.state = SUCCESSFUL
        db.update_process(p)
    assert db.colony_stats("scale") == {WAITING: 20, RUNNING: 20, SUCCESSFUL: 10}
    db.delete_process(procs[0].processid)  # successful one
    db.delete_process(procs[45].processid)  # waiting one
    assert db.colony_stats("scale") == {WAITING: 19, RUNNING: 20, SUCCESSFUL: 9}


# ---------------------------------------------------------------------------
# failsafe: deadline heaps pop only expired entries
# ---------------------------------------------------------------------------


def test_failsafe_bounded_work_at_10k():
    server_prv = Crypto.prvkey()
    db = MemoryDatabase()
    srv = standalone_server(Crypto.id(server_prv), db, verify_signatures=False)
    ts = now_ns()
    far = ts + 3600 * 10**9
    for i in range(10_000):  # healthy running fleet — never expired
        p = _proc(state=RUNNING)
        p.deadline_ns = far + i
        db.add_process(p)
    expired_exec = []
    for _ in range(5):  # crashed executors
        p = _proc(state=RUNNING, maxretries=2)
        p.deadline_ns = ts - 10**9
        db.add_process(p)
        expired_exec.append(p)
    for _ in range(3):  # queued past maxwaittime
        p = _proc(state=WAITING)
        p.waitdeadline_ns = ts - 10**9
        db.add_process(p)

    db.metrics["deadline_pops"] = 0
    counters = srv.failsafe_scan()
    assert counters["reset"] == 5 and counters["waitexpired"] == 3
    # bounded: only the expired entries (and their revalidation) were popped,
    # not the 10k healthy processes
    assert db.metrics["deadline_pops"] <= 2 * (5 + 3)
    for p in expired_exec:
        assert p.state == WAITING and p.retries == 1

    # a second scan immediately after does near-zero work: it only drains
    # the now-stale entries of the 5 reset + 3 expired processes
    db.metrics["deadline_pops"] = 0
    counters = srv.failsafe_scan()
    assert counters == {"reset": 0, "failed": 0, "waitexpired": 0}
    assert db.metrics["deadline_pops"] <= 5 + 3
    srv.stop()


def test_failsafe_work_independent_of_fleet_size():
    """Same number of expired processes -> same heap pops at 100 and 10k."""
    pops = {}
    for fleet in (100, 10_000):
        server_prv = Crypto.prvkey()
        db = MemoryDatabase()
        srv = standalone_server(Crypto.id(server_prv), db, verify_signatures=False)
        ts = now_ns()
        for i in range(fleet):
            p = _proc(state=RUNNING)
            p.deadline_ns = ts + 3600 * 10**9 + i
            db.add_process(p)
        for _ in range(4):
            p = _proc(state=RUNNING)
            p.deadline_ns = ts - 10**9
            db.add_process(p)
        db.metrics["deadline_pops"] = 0
        srv.failsafe_scan()
        pops[fleet] = db.metrics["deadline_pops"]
        srv.stop()
    assert pops[100] == pops[10_000]


# ---------------------------------------------------------------------------
# candidates: purity, side-listing, stale eviction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("db_factory", [MemoryDatabase, SqliteDatabase])
def test_candidates_never_blocked_or_wrongly_targeted(db_factory):
    db = db_factory()
    base = now_ns()
    blocked, pinned_other, pinned_me, open_procs = [], [], [], []
    for i in range(200):  # oldest: would pin the seed queue head
        p = _proc(ts=base - 10**9 + i)
        p.wait_for_parents = True
        db.add_process(p)
        blocked.append(p.processid)
    for i in range(200):
        p = _proc(ts=base - 5 * 10**8 + i, names=["someone-else"])
        db.add_process(p)
        pinned_other.append(p.processid)
    for i in range(3):
        p = _proc(ts=base + i, names=["me"])
        db.add_process(p)
        pinned_me.append(p.processid)
    for i in range(3):
        p = _proc(ts=base + 100 + i)
        db.add_process(p)
        open_procs.append(p.processid)

    got = db.candidates("scale", "worker", "me", limit=8)
    got_ids = [p.processid for p in got]
    assert got_ids == pinned_me + open_procs  # priority order, nothing else
    for p in got:
        assert p.queue_ready
        assert not p.spec.conditions.executornames or "me" in p.spec.conditions.executornames

    # an unrelated executor sees only the open processes
    got2 = db.candidates("scale", "worker", "other-worker", limit=8)
    assert [p.processid for p in got2] == open_procs


def test_stale_entries_are_evicted():
    db = MemoryDatabase()
    procs = [_proc(ts=now_ns() + i) for i in range(1000)]
    for p in procs:
        db.add_process(p)
    shard = db._shard("scale")
    assert len(shard.queues["worker"]) == 1000

    # 600 processes close without ever being dequeued -> entries go stale;
    # once stale entries dominate (501*2 > 1000), the whole queue is rebuilt
    # in one pass, and the 99 stragglers stay until scanned or re-dominant.
    for p in procs[:600]:
        p.state = SUCCESSFUL
        db.update_process(p)
    assert len(shard.queues["worker"]) == 499
    assert db.metrics["compactions"] >= 1

    # the next candidate scan walks the head, finds the 99 leftover stale
    # entries ahead of the live ones, and evicts the whole scanned prefix
    # in a single rebuild (no repeated list.remove)
    before = db.metrics["stale_evicted"]
    got = db.candidates("scale", "worker", "w", limit=8)
    assert len(got) == 8
    assert db.metrics["stale_evicted"] == before + 99
    assert len(shard.queues["worker"]) == 400

    # a handful more go stale mid-head: evicted by the following scan
    for p in procs[600:620]:
        p.state = FAILED
        db.update_process(p)
    got = db.candidates("scale", "worker", "w", limit=8)
    assert len(got) == 8
    assert len(shard.queues["worker"]) == 380


def test_requeue_is_duplicate_free():
    db = MemoryDatabase()
    p = _proc()
    db.add_process(p)
    db.requeue(p)
    db.requeue(p)
    shard = db._shard("scale")
    assert len(shard.queues["worker"]) == 1


@pytest.mark.parametrize("db_factory", [MemoryDatabase, SqliteDatabase])
def test_released_child_reenters_queue(db_factory):
    """wait_for_parents processes are side-lined, then become assignable
    exactly when released (requeue path)."""
    db = db_factory()
    child = _proc()
    child.wait_for_parents = True
    db.add_process(child)
    assert db.candidates("scale", "worker", "w") == []
    child.wait_for_parents = False
    db.update_process(child)
    db.requeue(child)
    assert [p.processid for p in db.candidates("scale", "worker", "w")] == [
        child.processid
    ]


def test_multi_target_stale_entries_compact():
    """A process pinned to k executors leaves k queue entries; the stale
    estimate must count all of them or side queues never compact."""
    db = MemoryDatabase()
    procs = [_proc(names=["a", "b"]) for _ in range(200)]
    for p in procs:
        db.add_process(p)
    shard = db._shard("scale")
    assert len(shard.targeted["worker"]["a"]) == 200
    assert len(shard.targeted["worker"]["b"]) == 200
    for p in procs:  # all close without either side queue being scanned
        p.state = SUCCESSFUL
        db.update_process(p)
    assert db.metrics["compactions"] >= 1
    # executor "b" never polls, yet its side queue must not leak forever
    assert len(shard.targeted.get("worker", {}).get("b", [])) < 200


def test_sqlite_migration_backfills_targets(tmp_path):
    """Opening a pre-`targets`-column db file must backfill pinning from the
    body JSON — otherwise old pinned processes become assignable by anyone."""
    import json
    import sqlite3

    path = str(tmp_path / "old.db")
    pinned = _proc(names=["gpu-1"])
    open_p = _proc()
    conn = sqlite3.connect(path)
    conn.executescript(
        """
        CREATE TABLE processes (
            processid TEXT PRIMARY KEY, colonyname TEXT NOT NULL,
            executortype TEXT NOT NULL, state TEXT NOT NULL,
            waitforparents INTEGER NOT NULL DEFAULT 0,
            prioritytime INTEGER NOT NULL, deadline INTEGER NOT NULL DEFAULT 0,
            waitdeadline INTEGER NOT NULL DEFAULT 0, body TEXT NOT NULL
        );
        """
    )
    for p in (pinned, open_p):
        conn.execute(
            "INSERT INTO processes VALUES (?,?,?,?,?,?,?,?,?)",
            (p.processid, p.colonyname, "worker", p.state, 0, p.priority_time,
             0, 0, p.to_json()),
        )
    conn.commit()
    conn.close()

    db = SqliteDatabase(path)
    got = [p.processid for p in db.candidates("scale", "worker", "cpu-9")]
    assert got == [open_p.processid]  # the gpu-pinned process stays invisible
    got = [p.processid for p in db.candidates("scale", "worker", "gpu-1")]
    assert set(got) == {pinned.processid, open_p.processid}


def test_ha_assign_confirms_apply_won():
    """If the Raft apply lost its CAS (conflict swallowed by the cluster),
    assign must not hand the executor an unassigned process."""
    from repro.core import ColoniesServer
    from repro.core.process import Executor

    db = MemoryDatabase()
    srv = ColoniesServer("srv", db, verify_signatures=False)
    srv.set_assign_proposer(lambda op: None)  # proposal commits, apply loses
    db.add_process(_proc())
    ex = Executor(executorid="e1", executorname="w", executortype="worker",
                  colonyname="scale", state="approved")
    assert srv._try_assign_once("scale", ex) is None


def test_backends_agree_on_candidate_order():
    dbs = [MemoryDatabase(), SqliteDatabase()]
    base = now_ns()
    specs = [(base + i * 1000, i % 3) for i in range(60)]
    for ts, prio in specs:
        spec = _spec(priority=prio)
        for db in dbs:
            db.add_process(Process.create(spec, submission_ns=ts))
    orders = [
        [(p.priority_time) for p in db.candidates("scale", "worker", "w", limit=30)]
        for db in dbs
    ]
    assert orders[0] == orders[1]
    assert orders[0] == sorted(orders[0])
