"""Broker behaviour: process table as queue, Eq. (1) priority, matching."""

import time

import pytest

from repro.core import Colonies, Crypto, ExecutorBase, FunctionSpec, InProcTransport
from repro.core.errors import AuthError, TimeoutError_, ValidationError
from repro.core.process import PRIORITY_NS_PER_LEVEL, Process, priority_time


def spec(colony="dev", etype="worker", func="echo", **kw):
    d = {
        "conditions": {"colonyname": colony, "executortype": etype},
        "funcname": func,
        "maxexectime": 60,
    }
    d.update(kw)
    return FunctionSpec.from_dict(d)


def make_executor(colony, name="w1", etype="worker"):
    ex = ExecutorBase(
        colony["client"], colony["name"], name, etype, colony_prvkey=colony["colony_prv"]
    )
    ex.register_function("echo", lambda ctx, *a: list(a))
    return ex


def test_submit_assign_close(colony):
    client = colony["client"]
    ex = make_executor(colony)
    p = client.submit(spec(args=["hi"]), colony["colony_prv"])
    assert p["state"] == "waiting"
    assert ex.step(timeout=2.0)
    done = client.get_process(p["processid"], colony["colony_prv"])
    assert done["state"] == "successful" and done["out"] == ["hi"]


def test_priority_time_equation():
    """Eq. (1): priority_time = submission_ns - priority * 1e9*60*60*24."""
    ts = 1_679_906_715_352_024_000
    assert priority_time(ts, 0) == ts
    assert priority_time(ts, 1) == ts - PRIORITY_NS_PER_LEVEL
    assert priority_time(ts, 5) == ts - 5 * PRIORITY_NS_PER_LEVEL


def test_priority_ordering(colony):
    """Higher-priority processes are assigned first despite later submission."""
    client = colony["client"]
    ex = make_executor(colony, name="w-prio")
    low = client.submit(spec(args=["low"], priority=0), colony["colony_prv"])
    high = client.submit(spec(args=["high"], priority=2), colony["colony_prv"])
    order = []
    ex._handlers["echo"] = lambda ctx, tag: order.append(tag) or [tag]
    assert ex.step(2.0) and ex.step(2.0)
    assert order == ["high", "low"]


def test_fifo_within_priority(colony):
    client = colony["client"]
    ex = make_executor(colony, name="w-fifo")
    ids = [client.submit(spec(args=[i]), colony["colony_prv"])["processid"] for i in range(3)]
    got = []
    ex._handlers["echo"] = lambda ctx, i: got.append(i) or [i]
    for _ in range(3):
        assert ex.step(2.0)
    assert got == [0, 1, 2]


def test_executor_type_matching(colony):
    """Processes only go to executors of the matching type."""
    client = colony["client"]
    ex_b = make_executor(colony, name="w-b", etype="other")
    p = client.submit(spec(etype="worker"), colony["colony_prv"])
    assert not ex_b.step(timeout=0.3)  # other-type executor never gets it
    ex_a = make_executor(colony, name="w-a", etype="worker")
    assert ex_a.step(timeout=2.0)


def test_targeted_executornames(colony):
    """Fine-grained assignment: pin a process to one executor by name
    (the paper's argument for database-backed queues)."""
    client = colony["client"]
    ex1 = make_executor(colony, name="target-1")
    ex2 = make_executor(colony, name="target-2")
    s = spec(args=["pinned"])
    s.conditions.executornames = ["target-2"]
    p = client.submit(s, colony["colony_prv"])
    assert not ex1.step(timeout=0.3)
    assert ex2.step(timeout=2.0)
    done = client.get_process(p["processid"], colony["colony_prv"])
    assert done["assignedexecutorid"] == ex2.executorid


def test_assign_timeout(colony):
    ex = make_executor(colony, name="w-idle")
    t0 = time.time()
    with pytest.raises(TimeoutError_):
        colony["client"].assign(colony["name"], 0.4, ex.prvkey)
    assert time.time() - t0 >= 0.35


def test_longpoll_wakes_on_submit(colony):
    """The hanging assign returns promptly when a process arrives."""
    import threading

    client = colony["client"]
    ex = make_executor(colony, name="w-poll")
    got = {}

    def poll():
        got["p"] = client.assign(colony["name"], 5.0, ex.prvkey)

    th = threading.Thread(target=poll)
    th.start()
    time.sleep(0.2)
    t0 = time.time()
    client.submit(spec(args=["wake"]), colony["colony_prv"])
    th.join(timeout=3.0)
    assert not th.is_alive() and time.time() - t0 < 2.0
    assert got["p"]["spec"]["funcname"] == "echo"


def test_stats_and_introspection(colony):
    client = colony["client"]
    make_executor(colony, name="w-stats")
    client.submit(spec(), colony["colony_prv"])
    stats = client.stats(colony["name"], colony["colony_prv"])
    assert stats["waiting"] >= 1 and stats["executors"] >= 1
    procs = client.get_processes(colony["name"], colony["colony_prv"], state="waiting")
    assert len(procs) >= 1


def test_submit_requires_executortype(colony):
    s = spec()
    s.conditions.executortype = ""
    with pytest.raises(ValidationError):
        colony["client"].submit(s, colony["colony_prv"])


def test_double_close_rejected(colony):
    client = colony["client"]
    ex = make_executor(colony, name="w-dc")
    p = client.submit(spec(), colony["colony_prv"])
    pd = client.assign(colony["name"], 2.0, ex.prvkey)
    client.close(pd["processid"], ["done"], ex.prvkey)
    from repro.core.errors import ConflictError

    with pytest.raises(ConflictError):
        client.close(pd["processid"], ["again"], ex.prvkey)
