"""Training substrate: optimizers, schedules, microbatching, loss descent."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_config
from repro.data.pipeline import SyntheticTokens
from repro.models import forward, init_params, model_spec
from repro.train.optimizer import (
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    lr_schedule,
)
from repro.train.train_step import cross_entropy, init_state, make_train_step


def test_adamw_minimizes_quadratic():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=400,
                       weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    for step in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw w^2
        params, opt = adamw_update(params, grads, opt, jnp.int32(step), tcfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_adafactor_minimizes_quadratic_matrix():
    tcfg = TrainConfig(optimizer="adafactor", learning_rate=0.3, warmup_steps=0,
                       total_steps=200, weight_decay=0.0)
    params = {"w": jnp.ones((4, 8)) * 3.0}
    opt = adafactor_init(params)
    for step in range(150):
        grads = {"w": 2 * params["w"]}
        params, opt = adafactor_update(params, grads, opt, jnp.int32(step), tcfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((16, 32)), "b": jnp.zeros((16,))}
    opt = adafactor_init(params)
    assert opt["v"]["w"]["vr"].shape == (16,)
    assert opt["v"]["w"]["vc"].shape == (32,)
    assert opt["v"]["b"]["v"].shape == (16,)  # vectors not factored


def test_lr_schedule_warmup_and_decay():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_schedule(tcfg, jnp.int32(0))) == 0.0
    assert abs(float(lr_schedule(tcfg, jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr_schedule(tcfg, jnp.int32(5))) == pytest.approx(5e-4)
    assert float(lr_schedule(tcfg, jnp.int32(100))) == pytest.approx(1e-4, rel=0.01)


def test_grad_clip():
    tree = {"a": jnp.ones(4) * 10.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


def test_cross_entropy_uniform():
    v = 7
    logits = jnp.zeros((2, 3, v))
    targets = jnp.zeros((2, 3), jnp.int32)
    ce, _ = cross_entropy(logits, targets)
    assert float(ce) == pytest.approx(np.log(v), rel=1e-5)


def test_microbatch_matches_full_batch():
    """Pre-split accumulation over k microbatches == one full batch step."""
    cfg = get_config("stablelm-3b", "smoke").copy(
        param_dtype="float32", compute_dtype="float32"
    )
    tcfg1 = TrainConfig(learning_rate=1e-3, warmup_steps=0, total_steps=10,
                        microbatches=1, grad_clip=0.0)
    tcfg2 = TrainConfig(learning_rate=1e-3, warmup_steps=0, total_steps=10,
                        microbatches=2, grad_clip=0.0)
    params = init_params(jax.random.key(0), model_spec(cfg), jnp.float32)
    batch = {
        k: jnp.asarray(v)
        for k, v in SyntheticTokens(cfg, 4, 16, seed=1).batch_at(0).items()
    }
    s1, m1 = jax.jit(make_train_step(cfg, tcfg1))(init_state(params, tcfg1), batch)
    split = {k: v.reshape(2, 2, *v.shape[1:]) for k, v in batch.items()}
    s2, m2 = jax.jit(make_train_step(cfg, tcfg2))(init_state(params, tcfg2), split)
    assert float(m1["ce"]) == pytest.approx(float(m2["ce"]), rel=1e-5)
    d = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), s1["params"], s2["params"]
    )
    assert max(jax.tree.leaves(d)) < 1e-5


def test_loss_decreases_over_steps():
    """The whole stack learns the synthetic stream (loss drops)."""
    cfg = get_config("stablelm-3b", "smoke").copy(
        param_dtype="float32", compute_dtype="float32"
    )
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=30)
    params = init_params(jax.random.key(0), model_spec(cfg), jnp.float32)
    state = init_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    data = SyntheticTokens(cfg, 8, 32, seed=0)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["ce"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses


def test_mtp_loss_present_for_deepseek():
    cfg = get_config("deepseek-v3-671b", "smoke").copy(
        param_dtype="float32", compute_dtype="float32"
    )
    tcfg = TrainConfig()
    params = init_params(jax.random.key(0), model_spec(cfg), jnp.float32)
    state = init_state(params, tcfg)
    batch = {
        k: jnp.asarray(v)
        for k, v in SyntheticTokens(cfg, 2, 16, seed=0).batch_at(0).items()
    }
    _, metrics = jax.jit(make_train_step(cfg, tcfg))(state, batch)
    assert "mtp_ce" in metrics and np.isfinite(float(metrics["mtp_ce"]))
