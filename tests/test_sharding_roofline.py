"""Unit tests for the distribution plane: logical-axis resolution,
cache sharding fallbacks, optimizer-state specs, roofline math."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, TrainConfig, get_config
from repro.configs.base import ModelConfig
from repro.configs.shapes import CellSkip, cell_skip_reason, decode_specs, input_specs
from repro.launch.mesh import make_smoke_mesh
from repro.launch.roofline import PEAK_FLOPS, Roofline, active_params, model_flops
from repro.launch.sharding_plan import cache_pspecs, opt_pspecs
from repro.models.sharding import (
    DEFAULT_RULES,
    ParamLeaf,
    param_pspecs,
    resolve_axes,
    rules_for,
)


@pytest.fixture(scope="module")
def mesh22():
    # 2x2 host mesh with the production axis names (4 CPU "devices" not
    # needed — resolve_axes/_divisible only read mesh.shape)
    import numpy as np
    from jax.sharding import Mesh

    dev = np.array(jax.devices() * 4).reshape(2, 2)
    return Mesh(dev, ("data", "model"))


def test_resolve_axes_basic(mesh22):
    assert resolve_axes(("embed", "ffn"), DEFAULT_RULES, mesh22) == P(None, "model")
    assert resolve_axes(("vocab", "embed"), DEFAULT_RULES, mesh22) == P("model", None)
    # batch -> (pod, data): pod absent on this mesh -> data only
    assert resolve_axes(("batch", None), DEFAULT_RULES, mesh22) == P(("data",), None)


def test_resolve_axes_never_repeats_mesh_axis(mesh22):
    # two logical axes mapping to "model": only the first keeps it
    spec = resolve_axes(("heads", "ffn"), DEFAULT_RULES, mesh22)
    axes = [a for a in tuple(spec) if a is not None]
    assert axes.count("model") == 1


def test_param_pspecs_divisibility(mesh22):
    spec = {
        "even": ParamLeaf((8, 4), ("embed", "ffn")),
        "odd": ParamLeaf((8, 5), ("embed", "ffn")),  # 5 % 2 != 0 -> replicated
    }
    pps = param_pspecs(spec, rules_for(get_config("stablelm-3b", "smoke")), mesh22)
    assert tuple(pps["even"])[1] == "model"
    assert tuple(pps["odd"]) == (None, None) or tuple(pps["odd"])[1] is None


def test_opt_pspecs_adamw_mirrors_params(mesh22):
    spec = {"w": ParamLeaf((8, 4), ("embed", "ffn"))}
    pps = param_pspecs(spec, DEFAULT_RULES, mesh22)
    opt = opt_pspecs(spec, pps, TrainConfig(optimizer="adamw"))
    assert opt["m"]["w"] == pps["w"] and opt["v"]["w"] == pps["w"]


def test_opt_pspecs_adafactor_drops_factored_axis(mesh22):
    spec = {"w": ParamLeaf((8, 4), ("embed", "ffn"))}
    pps = param_pspecs(spec, DEFAULT_RULES, mesh22)
    opt = opt_pspecs(spec, pps, TrainConfig(optimizer="adafactor"))
    assert opt["v"]["w"]["vr"] == P(*tuple(pps["w"])[:-1])  # row stats drop last dim


def test_cache_pspecs_kv_heads_vs_seq_fallback(mesh22):
    # kv divisible -> heads sharded; kv indivisible -> seq sharded
    cfg = get_config("stablelm-3b", "full")
    div = {"layers": {"b0": {
        "k": jax.ShapeDtypeStruct((2, 4, 8, 2, 16), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((2, 4, 8, 2, 16), jnp.bfloat16),
    }}, "memory": None}
    ps = cache_pspecs(cfg, div, mesh22)
    assert tuple(ps["layers"]["b0"]["k"])[3] == "model"  # kv=2 % 2 == 0
    odd = {"layers": {"b0": {
        "k": jax.ShapeDtypeStruct((2, 4, 8, 3, 16), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((2, 4, 8, 3, 16), jnp.bfloat16),
    }}, "memory": None}
    ps = cache_pspecs(cfg, odd, mesh22)
    assert tuple(ps["layers"]["b0"]["k"])[2] == "model"  # seq fallback


def test_cell_skip_policy():
    for arch, skipped in (
        ("qwen2.5-14b", True), ("starcoder2-15b", True), ("deepseek-v3-671b", True),
        ("rwkv6-7b", False), ("jamba-1.5-large-398b", False), ("mixtral-8x7b", False),
    ):
        cfg = get_config(arch, "full")
        reason = cell_skip_reason(cfg, SHAPES["long_500k"])
        assert (reason is not None) == skipped, arch
    with pytest.raises(CellSkip):
        input_specs(get_config("granite-3-8b", "full"), "long_500k")


def test_decode_specs_cache_matches_prefill_structure():
    """The dry-run's abstract cache tree must match what prefill returns."""
    from repro.models import init_params, model_spec, prefill

    cfg = get_config("mixtral-8x7b", "smoke").copy(
        param_dtype="float32", compute_dtype="float32"
    )
    params = init_params(jax.random.key(0), model_spec(cfg), jnp.float32)
    tokens = jnp.zeros((2, 16), jnp.int32)
    _, cache = prefill(params, cfg, {"tokens": tokens}, max_len=16)
    abstract = decode_specs(cfg, SHAPES["decode_32k"])["cache"]
    real_paths = {jax.tree_util.keystr(p) for p, _ in
                  jax.tree_util.tree_flatten_with_path(cache)[0]}
    abs_paths = {jax.tree_util.keystr(p) for p, _ in
                 jax.tree_util.tree_flatten_with_path(abstract)[0]}
    assert real_paths == abs_paths


def test_active_params_discounts_experts():
    total, active = active_params(get_config("mixtral-8x7b", "full"))
    assert active < total  # top-2 of 8
    assert active > total * 0.25  # attention/embeddings not discounted
    t2, a2 = active_params(get_config("granite-3-8b", "full"))
    assert t2 == a2  # dense: no discount


def test_model_flops_kinds():
    cfg = get_config("stablelm-3b", "full")
    train = model_flops(cfg, SHAPES["train_4k"])
    prefill = model_flops(cfg, SHAPES["prefill_32k"])
    decode = model_flops(cfg, SHAPES["decode_32k"])
    _, n = active_params(cfg)
    assert train == 6.0 * n * 256 * 4096
    assert prefill == 2.0 * n * 32 * 32768
    assert decode == 2.0 * n * 128  # one token per sequence


def test_roofline_terms_and_bottleneck():
    r = Roofline(
        flops_per_device=PEAK_FLOPS,  # exactly 1 s of compute
        bytes_per_device=819e9 * 2,  # 2 s of memory
        collective_bytes_per_device=50e9 * 0.5,  # 0.5 s of wire
        chips=4,
        model_flops_total=PEAK_FLOPS * 4,  # ideal 1 s
    )
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(2.0)
    assert r.collective_s == pytest.approx(0.5)
    assert r.bottleneck == "memory"
    assert r.roofline_fraction == pytest.approx(0.5)  # ideal 1s / max 2s
    assert r.useful_flops_fraction == pytest.approx(1.0)
