"""prefill+decode == full forward, per family (the serving contract)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import SyntheticTokens
from repro.models import decode_step, forward, init_params, model_spec, prefill

B, S = 2, 16


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch, "smoke").copy(param_dtype="float32", compute_dtype="float32")
    params = init_params(jax.random.key(0), model_spec(cfg), jnp.float32)
    src = SyntheticTokens(cfg, B, S + 2, seed=3)
    batch = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}
    tokens = batch["tokens"]

    logits, _ = forward(params, cfg, batch)
    pre = dict(batch)
    pre["tokens"] = tokens[:, :S]
    last, cache = prefill(params, cfg, pre, max_len=S + 4)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(logits[:, S - 1 : S]), atol=2e-2, rtol=1e-3
    )
    # two consecutive decode steps
    dl, cache = decode_step(params, cfg, tokens[:, S : S + 1], cache, jnp.int32(S))
    np.testing.assert_allclose(
        np.asarray(dl)[:, 0], np.asarray(logits[:, S]), atol=2e-2, rtol=1e-3
    )
    dl2, _ = decode_step(params, cfg, tokens[:, S + 1 : S + 2], cache, jnp.int32(S + 1))
    np.testing.assert_allclose(
        np.asarray(dl2)[:, 0], np.asarray(logits[:, S + 1]), atol=3e-2, rtol=1e-3
    )


def test_swa_ring_buffer_long_decode():
    """Mixtral-style rolling cache: decoding past the window stays exact."""
    cfg = get_config("mixtral-8x7b", "smoke").copy(
        param_dtype="float32", compute_dtype="float32"
    )
    assert cfg.sliding_window == 8
    params = init_params(jax.random.key(0), model_spec(cfg), jnp.float32)
    total = 24  # 3x the window
    tokens = jax.random.randint(jax.random.key(5), (B, total), 0, cfg.vocab_size)
    logits, _ = forward(params, cfg, {"tokens": tokens})
    # prefill the first 4 (< window), then decode one by one past the window
    _, cache = prefill(params, cfg, {"tokens": tokens[:, :4]}, max_len=total)
    for pos in range(4, total):
        dl, cache = decode_step(params, cfg, tokens[:, pos : pos + 1], cache, jnp.int32(pos))
        np.testing.assert_allclose(
            np.asarray(dl)[:, 0], np.asarray(logits[:, pos]), atol=3e-2, rtol=1e-3,
            err_msg=f"divergence at pos {pos}",
        )
