"""Zero-trust enforcement (paper §3.4.6, Table 5): three roles, always verify."""

import pytest

from repro.core import Colonies, Crypto, ExecutorBase, FunctionSpec, InProcTransport
from repro.core.errors import AuthError
from repro.core.security import open_envelope, sign_envelope


def spec():
    return FunctionSpec.from_dict(
        {"conditions": {"colonyname": "dev", "executortype": "worker"},
         "funcname": "echo"}
    )


def test_only_server_owner_creates_colonies(colony):
    rando = Crypto.prvkey()
    with pytest.raises(AuthError):
        colony["client"].add_colony("rogue", Crypto.id(rando), rando)


def test_only_colony_owner_registers_executors(colony):
    rando = Crypto.prvkey()
    with pytest.raises(AuthError):
        colony["client"].add_executor(
            {"executorname": "evil", "executorid": Crypto.id(rando),
             "colonyname": "dev", "executortype": "worker"},
            rando,
        )


def test_unapproved_executor_cannot_assign(colony):
    """Table 5: membership requires owner approval, not just registration."""
    client = colony["client"]
    prv = Crypto.prvkey()
    client.add_executor(
        {"executorname": "pending-w", "executorid": Crypto.id(prv),
         "colonyname": "dev", "executortype": "worker"},
        colony["colony_prv"],
    )
    with pytest.raises(AuthError):
        client.assign("dev", 0.2, prv)
    client.approve_executor(Crypto.id(prv), colony["colony_prv"])
    client.submit(spec(), colony["colony_prv"])
    assert client.assign("dev", 2.0, prv)["spec"]["funcname"] == "echo"


def test_rejected_executor_is_locked_out(colony):
    client = colony["client"]
    prv = Crypto.prvkey()
    client.add_executor(
        {"executorname": "rej-w", "executorid": Crypto.id(prv),
         "colonyname": "dev", "executortype": "worker"},
        colony["colony_prv"],
    )
    client.reject_executor(Crypto.id(prv), colony["colony_prv"])
    with pytest.raises(AuthError):
        client.assign("dev", 0.2, prv)


def test_non_member_cannot_submit_or_read(colony):
    outsider = Crypto.prvkey()
    with pytest.raises(AuthError):
        colony["client"].submit(spec(), outsider)
    with pytest.raises(AuthError):
        colony["client"].stats("dev", outsider)


def test_only_assigned_executor_can_close(colony):
    """Fig. 2: only the assigned executor has write access to the process."""
    client = colony["client"]
    ex1 = ExecutorBase(client, "dev", "sec-1", "worker", colony_prvkey=colony["colony_prv"])
    ex2 = ExecutorBase(client, "dev", "sec-2", "worker", colony_prvkey=colony["colony_prv"])
    p = client.submit(spec(), colony["colony_prv"])
    pd = client.assign("dev", 2.0, ex1.prvkey)
    from repro.core.errors import ConflictError

    with pytest.raises(ConflictError):
        client.close(pd["processid"], ["hijack"], ex2.prvkey)
    client.close(pd["processid"], ["ok"], ex1.prvkey)


def test_envelope_tamper_detected():
    """Tampering changes the RECOVERED identity (never the signer's), so
    the tamperer gains no authority — the zero-trust property."""
    prv = Crypto.prvkey()
    ident = Crypto.id(prv)
    env = sign_envelope("submit", {"a": 1}, prv)
    env["payload"] = env["payload"].replace("1", "2")
    try:
        recovered, _, _ = open_envelope(env)
        assert recovered != ident
    except AuthError:
        pass  # outright rejection is also acceptable


def test_envelope_type_tamper_detected():
    """Signature binds the payload TYPE too (no cross-operation replay)."""
    prv = Crypto.prvkey()
    ident = Crypto.id(prv)
    env = sign_envelope("getprocess", {"processid": "x"}, prv)
    env["payloadtype"] = "removeexecutor"
    recovered, _, _ = open_envelope(env)
    assert recovered != ident  # recovers a DIFFERENT identity -> no authority


def test_user_role_can_submit_but_not_assign(colony):
    client = colony["client"]
    user_prv = Crypto.prvkey()
    client.add_user("dev", Crypto.id(user_prv), "alice", colony["colony_prv"])
    p = client.submit(spec(), user_prv)  # members may submit
    assert p["state"] == "waiting"
    with pytest.raises(AuthError):  # but users are not executors
        client.assign("dev", 0.2, user_prv)
