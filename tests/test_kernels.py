"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dependency — only the property test below needs it
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = None

from repro.kernels.ops import flash_attention, mamba_chunk_scan, rwkv6_chunked
from repro.kernels.ref import flash_attention_ref, mamba_scan_ref, rwkv6_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (B, S, H, KV, D, window, blocks)
    (1, 128, 4, 4, 32, 0, 64),  # MHA
    (2, 128, 4, 2, 32, 0, 64),  # GQA group 2
    (1, 256, 8, 2, 64, 0, 128),  # GQA group 4, bigger head
    (2, 128, 4, 2, 32, 48, 32),  # sliding window
    (1, 64, 2, 1, 16, 0, 16),  # tiny blocks
]


@pytest.mark.parametrize("b,s,h,kv,d,window,blk", FLASH_CASES)
def test_flash_attention_matches_ref(b, s, h, kv, d, window, blk):
    ks = jax.random.split(jax.random.key(b * s + h), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, d), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, block_q=blk, block_k=blk)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-4)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 32), jnp.bfloat16)
    k = jax.random.normal(ks[1], (2, 128, 2, 32), jnp.bfloat16)
    v = jax.random.normal(ks[2], (2, 128, 2, 32), jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=3e-2, rtol=3e-2
    )


if given is not None:

    @settings(max_examples=8, deadline=None)
    @given(
        s_blocks=st.integers(2, 6),
        group=st.sampled_from([1, 2, 4]),
        blk=st.sampled_from([16, 32]),
        causal=st.booleans(),
    )
    def test_property_flash_attention(s_blocks, group, blk, causal):
        s = s_blocks * blk
        kv, d = 2, 16
        h = kv * group
        ks = jax.random.split(jax.random.key(s * group + blk), 3)
        q = jax.random.normal(ks[0], (1, s, h, d), jnp.float32)
        k = jax.random.normal(ks[1], (1, s, kv, d), jnp.float32)
        v = jax.random.normal(ks[2], (1, s, kv, d), jnp.float32)
        out = flash_attention(q, k, v, causal=causal, block_q=blk, block_k=blk)
        ref = flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), atol=3e-5, rtol=1e-4
        )

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_flash_attention():
        pass


# ---------------------------------------------------------------------------
# rwkv6 wkv
# ---------------------------------------------------------------------------

RWKV_CASES = [
    (1, 32, 2, 8, 16),  # (B, T, H, K, chunk)
    (2, 64, 3, 16, 16),
    (2, 96, 2, 16, 32),
]


@pytest.mark.parametrize("b,t,h,k,chunk", RWKV_CASES)
def test_rwkv6_matches_ref(b, t, h, k, chunk):
    ks = jax.random.split(jax.random.key(t + h), 5)
    r = jax.random.normal(ks[0], (b, t, h, k))
    kk = jax.random.normal(ks[1], (b, t, h, k))
    v = jax.random.normal(ks[2], (b, t, h, k))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, t, h, k)))
    u = jax.random.normal(ks[4], (h, k)) * 0.2
    s0 = jnp.zeros((b, h, k, k))
    out, sf = rwkv6_chunked(r, kk, v, logw, u, s0, chunk=chunk)
    ref_o, ref_s = rwkv6_ref(r, kk, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_o), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(ref_s), atol=1e-4, rtol=1e-3)


def test_rwkv6_nonzero_initial_state():
    """Chunk-boundary state carry: start from a random state, not zeros."""
    b, t, h, k = 1, 32, 2, 8
    ks = jax.random.split(jax.random.key(3), 6)
    r, kk, v = (jax.random.normal(ks[i], (b, t, h, k)) for i in range(3))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, t, h, k)))
    u = jax.random.normal(ks[4], (h, k)) * 0.2
    s0 = jax.random.normal(ks[5], (b, h, k, k))
    out, sf = rwkv6_chunked(r, kk, v, logw, u, s0, chunk=8)
    ref_o, ref_s = rwkv6_ref(r, kk, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_o), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(ref_s), atol=1e-4, rtol=1e-3)


def test_rwkv6_extreme_decay_no_overflow():
    """Log-space pairwise form: strong decay must not produce inf/nan
    (the failure mode of the exp(-cum) rescaling formulation)."""
    b, t, h, k = 1, 64, 1, 8
    ks = jax.random.split(jax.random.key(9), 3)
    r, kk, v = (jax.random.normal(ks[i], (b, t, h, k)) for i in range(3))
    logw = jnp.full((b, t, h, k), -30.0)  # near-instant forgetting
    u = jnp.zeros((h, k))
    s0 = jnp.zeros((b, h, k, k))
    out, sf = rwkv6_chunked(r, kk, v, logw, u, s0, chunk=32)
    assert np.isfinite(np.asarray(out)).all() and np.isfinite(np.asarray(sf)).all()
    ref_o, _ = rwkv6_ref(r, kk, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_o), atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------

MAMBA_CASES = [
    (1, 64, 32, 4, 32, 32),  # (B, T, DI, N, chunk, d_block)
    (2, 128, 64, 8, 32, 32),
    (2, 64, 96, 16, 16, 48),
]


@pytest.mark.parametrize("b,t,di,n,chunk,dblk", MAMBA_CASES)
def test_mamba_scan_matches_ref(b, t, di, n, chunk, dblk):
    ks = jax.random.split(jax.random.key(di + n), 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, t, di)))
    bm = jax.random.normal(ks[1], (b, t, n))
    cm = jax.random.normal(ks[2], (b, t, n))
    a = -jnp.exp(jax.random.normal(ks[3], (di, n)) * 0.5)
    x = jax.random.normal(ks[4], (b, t, di))
    h0 = jnp.zeros((b, di, n))
    y, hf = mamba_chunk_scan(dt, bm, cm, a, x, h0, chunk=chunk, d_block=dblk)
    ry, rh = mamba_scan_ref(dt, bm, cm, a, x, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ry), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(rh), atol=1e-4, rtol=1e-3)


def test_mamba_nonzero_state_carry():
    b, t, di, n = 1, 32, 16, 4
    ks = jax.random.split(jax.random.key(17), 6)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, t, di)))
    bm = jax.random.normal(ks[1], (b, t, n))
    cm = jax.random.normal(ks[2], (b, t, n))
    a = -jnp.exp(jax.random.normal(ks[3], (di, n)) * 0.5)
    x = jax.random.normal(ks[4], (b, t, di))
    h0 = jax.random.normal(ks[5], (b, di, n))
    y, hf = mamba_chunk_scan(dt, bm, cm, a, x, h0, chunk=16, d_block=16)
    ry, rh = mamba_scan_ref(dt, bm, cm, a, x, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ry), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(rh), atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# model-level: use_pallas == XLA path
# ---------------------------------------------------------------------------


def test_model_forward_pallas_equals_xla():
    from repro.configs import get_config
    from repro.models import forward, init_params, model_spec

    for arch in ("mixtral-8x7b", "rwkv6-7b", "jamba-1.5-large-398b"):
        cfg = get_config(arch, "smoke").copy(
            param_dtype="float32", compute_dtype="float32"
        )
        params = init_params(jax.random.key(0), model_spec(cfg), jnp.float32)
        tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
        ref_logits, _ = forward(params, cfg, {"tokens": tokens})
        pal_logits, _ = forward(
            params, cfg.copy(use_pallas=True), {"tokens": tokens}
        )
        np.testing.assert_allclose(
            np.asarray(pal_logits), np.asarray(ref_logits), atol=5e-3, rtol=1e-3,
            err_msg=arch,
        )
