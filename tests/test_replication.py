"""Replication-safety plane: replint rules, digests, journals, and the
HA divergence contracts under REPRO_REPL_CHECK=1 (see REPLICATION.md)."""

import time

import pytest

from repro.analysis import statehash
from repro.analysis.replint import collect_ops, lint_source, run as replint_run
from repro.analysis.statehash import (
    ClusterJournal,
    ColonyDigest,
    ReplicationDivergenceError,
    full_colony_digest,
)
from repro.core import Colonies, ExecutorBase, FunctionSpec, InProcTransport
from repro.core.cluster import REPLICATED_OPS, HAColonyCluster
from repro.core.errors import ConflictError
from repro.core.process import new_id, now_ns
from repro.core.raft import RaftNode, ThreadedRaftCluster


def spec(**kw):
    d = {"conditions": {"colonyname": "dev", "executortype": "worker"},
         "funcname": "echo", "maxexectime": 60}
    d.update(kw)
    return FunctionSpec.from_dict(d)


@pytest.fixture()
def repl_check():
    """REPRO_REPL_CHECK on for the test, restored afterwards."""
    prev = statehash.is_enabled()
    statehash.enable(True)
    yield
    statehash.enable(prev)


# ---------------------------------------------------------------------------
# replint: every rule fires on a seeded fixture; the real repo is clean
# ---------------------------------------------------------------------------


def _rules(src):
    return {v.rule for v in lint_source(src, "fixture.py")}


def test_rep001_nondeterministic_call_fires_interprocedurally():
    src = '''
import time
class C:
    def _apply(self, nid, entry, index):
        self.helper(entry)
    def helper(self, entry):
        return time.time()
'''
    assert "REP001" in _rules(src)


def test_rep001_repo_wrappers_fire():
    src = '''
class C:
    def _apply(self, nid, entry, index):
        entry["ts"] = now_ns()
        entry["opid"] = new_id()
'''
    vs = [v for v in lint_source(src, "f.py") if v.rule == "REP001"]
    assert len(vs) == 2


def test_rep002_unordered_iteration_into_db_write_fires():
    src = '''
class C:
    def _apply(self, nid, entry, index):
        for k, v in self.index.items():
            self.db.update_process(v)
'''
    assert "REP002" in _rules(src)


def test_rep002_sorted_iteration_is_clean():
    src = '''
class C:
    def _apply(self, nid, entry, index):
        for k, v in sorted(self.index.items()):
            self.db.update_process(v)
'''
    assert "REP002" not in _rules(src)


def test_rep003_unguarded_mutation_fires():
    src = '''
class C:
    def _apply(self, nid, entry, index):
        p = self.db.get_process(entry["processid"])
        self.db.update_process(p)
'''
    assert "REP003" in _rules(src)


def test_rep003_cas_under_colony_lock_is_clean():
    src = '''
class C:
    def _apply(self, nid, entry, index):
        with self.db.colony_lock("dev"):
            p = self.db.get_process(entry["processid"])
            if p.state != "waiting":
                raise ConflictError("gone")
            self.db.update_process(p)
'''
    assert "REP003" not in _rules(src)


def test_rep004_unstamped_propose_fires_and_forwarding_is_exempt():
    bad = '''
class C:
    def go(self):
        self.raft.propose_and_wait("n0", {"op": "assign", "processid": "p"})
'''
    vs = [v for v in lint_source(bad, "f.py") if v.rule == "REP004"]
    assert len(vs) == 1 and "opid" in vs[0].msg and "ts" in vs[0].msg
    forwarding = '''
class C:
    def forward(self, entry):
        self.raft.propose_and_wait("n0", entry)
'''
    assert "REP004" not in _rules(forwarding)


def test_rep005_environment_dependence_fires():
    env = '''
import os
class C:
    def _apply(self, nid, entry, index):
        return os.environ["HOME"]
'''
    io = '''
class C:
    def _apply(self, nid, entry, index):
        with open("/tmp/x") as f:
            return f.read()
'''
    assert "REP005" in _rules(env)
    assert "REP005" in _rules(io)


def test_repo_lints_clean_with_real_apply_cone():
    nfiles, cone, vs = replint_run(["src/repro"])
    assert vs == [], [str(v) for v in vs]
    assert nfiles > 50
    # The cone is rooted at the real replicated ops and spans the close
    # cascade — spot-check the load-bearing members.
    for member in (
        "HAColonyCluster._apply",
        "ColoniesServer.apply_assign",
        "ColoniesServer.apply_close",
        "ColoniesServer.close_process",
        "ColoniesServer._fail_descendants",
    ):
        assert member in cone, member


def test_replicated_ops_literal_matches_server_api():
    assert set(REPLICATED_OPS) == {"assign", "close"}
    for op, op_spec in REPLICATED_OPS.items():
        assert {"ts", "opid"} <= set(op_spec["required"])
        # msgid joined opid/ts with the idempotency plane (ROBUSTNESS.md):
        # the client's key is fixed on the leader so a re-proposed entry
        # replays identically on every replica.
        assert set(op_spec["leader_stamped"]) == {"opid", "ts", "msgid"}
    # collect_ops (what replmap renders) parses the same literal.
    with open("src/repro/core/cluster.py", encoding="utf-8") as fh:
        parsed = collect_ops([("cluster.py", fh.read())])
    assert parsed == REPLICATED_OPS


def test_replmap_matches_committed_doc():
    from repro.analysis.replmap import _split, generate

    with open("REPLICATION.md", encoding="utf-8") as fh:
        _head, section, _tail = _split(fh.read())
    assert section.strip() == generate(["src/repro"]).strip()


# ---------------------------------------------------------------------------
# statehash: digests and journals
# ---------------------------------------------------------------------------


def test_colony_digest_is_incremental_and_order_independent():
    rows = [
        ("p1", "waiting", "", 0, False, True, 0, 0),
        ("p2", "running", "ex1", 1, False, False, 10, 0),
        ("p3", "successful", "ex2", 0, False, False, 5, 9),
    ]
    fwd, rev = ColonyDigest(), ColonyDigest()
    for r in rows:
        fwd.observe(r[0], r)
    for r in reversed(rows):
        rev.observe(r[0], r)
    assert fwd.digest() == rev.digest()
    # Updating one row replaces its contribution (not XOR-accumulates).
    fwd.observe("p1", ("p1", "running", "ex9", 0, False, False, 3, 0))
    rev.observe("p1", ("p1", "running", "ex9", 0, False, False, 3, 0))
    assert fwd.digest() == rev.digest()
    # Reverting the update restores the original digest exactly.
    before = ColonyDigest()
    for r in rows:
        before.observe(r[0], r)
    fwd.observe("p1", rows[0])
    assert fwd.digest() == before.digest()
    # forget removes the contribution.
    fwd.forget("p3")
    two = ColonyDigest()
    for r in rows[:2]:
        two.observe(r[0], r)
    assert fwd.digest() == two.digest()


def test_incremental_digest_matches_full_recompute(colony):
    client, srv = colony["client"], colony["server"]
    ex = ExecutorBase(client, "dev", "dg-w", "worker",
                      colony_prvkey=colony["colony_prv"])
    pids = [client.submit(spec(), colony["colony_prv"])["processid"]
            for _ in range(3)]
    d = ColonyDigest()
    for item in srv.db.replica_state("dev"):
        d.observe(item[0], item)
    assert d.digest() == full_colony_digest(srv.db, "dev")
    pd = client.assign("dev", 2.0, ex.prvkey)
    client.close(pd["processid"], ["done"], ex.prvkey)
    # Incrementally fold only the changed row; must equal a full rescan.
    for item in srv.db.replica_state("dev"):
        if item[0] == pd["processid"]:
            d.observe(item[0], item)
    assert d.digest() == full_colony_digest(srv.db, "dev")
    assert len(d) == len(pids)


def test_journal_detects_skewed_replica_at_right_index():
    j = ClusterJournal()
    entries = [{"op": "assign", "opid": f"o{i}", "ts": i} for i in range(5)]
    for i, e in enumerate(entries):
        j.record("n0", i, e, f"effect{i}")
    # n1 agrees up to index 2, then applies a different effect at 3.
    for i, e in enumerate(entries):
        effect = f"effect{i}" if i != 3 else "SKEWED"
        j.record("n1", i, e, effect)
    assert j.divergence is not None
    assert "index 3" in str(j.divergence)
    with pytest.raises(ReplicationDivergenceError):
        j.check()
    # Chaining poisons every later index too: the divergence reported is
    # still the FIRST one even though index 4 also mismatched.
    assert "index 4" not in str(j.divergence)


def test_journal_divergent_entry_at_same_index_detected():
    j = ClusterJournal()
    j.record("n0", 0, {"op": "assign", "opid": "a"}, None)
    j.record("n1", 0, {"op": "assign", "opid": "b"}, None)
    with pytest.raises(ReplicationDivergenceError):
        j.check()


def test_journal_identical_replicas_are_clean():
    j = ClusterJournal()
    for nid in ("n0", "n1", "n2"):
        for i in range(10):
            j.record(nid, i, {"op": "assign", "opid": f"o{i}"}, f"e{i}")
    j.check()
    assert j.nodes() == ["n0", "n1", "n2"]
    assert j.entries("n0") == j.entries("n1") == j.entries("n2")


def test_threaded_cluster_cross_checks_node_effects(repl_check):
    """An apply whose effect depends on which node ran it must trip the
    journal cross-check on the first shared index."""
    cluster = ThreadedRaftCluster(3, lambda nid, e, i: f"state-of-{nid}", seed=7)
    assert cluster.journal is not None
    cluster.start()
    try:
        deadline = time.time() + 10
        leader = None
        while time.time() < deadline and leader is None:
            leader = cluster.leader_id()
            time.sleep(0.02)
        assert leader is not None
        with pytest.raises(ReplicationDivergenceError):
            while time.time() < deadline:
                cluster.propose_and_wait(leader, {"op": "x", "n": 1})
                time.sleep(0.05)
                cluster.check_divergence()
            raise AssertionError("divergent applies never detected")
    finally:
        cluster.stop()


def test_flag_off_means_no_journal():
    prev = statehash.is_enabled()
    statehash.enable(False)
    try:
        cluster = ThreadedRaftCluster(3, lambda nid, e, i: nid, seed=8)
        assert cluster.journal is None
    finally:
        statehash.enable(prev)


# ---------------------------------------------------------------------------
# satellite regressions: deterministic RNG seeding + commit condvar
# ---------------------------------------------------------------------------


def test_same_node_id_draws_identical_election_jitter():
    a = RaftNode("n0", ["n0", "n1", "n2"], send=lambda m: None)
    b = RaftNode("n0", ["n0", "n1", "n2"], send=lambda m: None)
    assert [a.rng.randint(150, 300) for _ in range(32)] == [
        b.rng.randint(150, 300) for _ in range(32)
    ]
    # Distinct ids still diverge (different election timing per node).
    c = RaftNode("n1", ["n0", "n1", "n2"], send=lambda m: None)
    assert [a.rng.randint(150, 300) for _ in range(32)] != [
        c.rng.randint(150, 300) for _ in range(32)
    ]


def test_propose_and_wait_wakes_on_commit_not_poll():
    cluster = ThreadedRaftCluster(3, seed=9)
    cluster.start()
    try:
        deadline = time.time() + 10
        leader = None
        while time.time() < deadline and leader is None:
            leader = cluster.leader_id()
            time.sleep(0.02)
        assert leader is not None
        node = cluster.nodes[leader]
        idx = cluster.propose_and_wait(leader, {"op": "noop"})
        assert node.last_applied >= idx
    finally:
        cluster.stop()


# ---------------------------------------------------------------------------
# HA end-to-end under REPRO_REPL_CHECK=1
# ---------------------------------------------------------------------------


def _ha_cluster(server_keys, colony_keys, seed):
    server_prv, server_id = server_keys
    colony_prv, colony_id = colony_keys
    cluster = HAColonyCluster(server_id, replicas=3, seed=seed)
    cluster.start(failsafe_interval=0.2)
    assert cluster.wait_for_leader(10)
    client = Colonies(InProcTransport(cluster.servers))
    client.add_colony("dev", colony_id, server_prv)
    return cluster, client, colony_prv


def test_ha_close_is_replicated_and_replay_safe(repl_check, server_keys, colony_keys):
    """Close goes through the Raft log with a leader-stamped ts; the
    double-apply harness verifies its CAS on every entry."""
    cluster, client, colony_prv = _ha_cluster(server_keys, colony_keys, seed=21)
    try:
        ex = ExecutorBase(client, "dev", "cl-w", "worker", colony_prvkey=colony_prv)
        p = client.submit(spec(), colony_prv)
        pd = client.assign("dev", 5.0, ex.prvkey)
        assert pd["processid"] == p["processid"]
        client.close(p["processid"], ["out"], ex.prvkey)
        done = client.get_process(p["processid"], colony_prv)
        assert done["state"] == "successful" and done["out"] == ["out"]
        assert done["endtime"] > 0
        # A second close of the same process loses the CAS.
        with pytest.raises(ConflictError):
            client.close(p["processid"], ["again"], ex.prvkey)
        cluster.raft.check_divergence()
        # Both ops were journaled (assign + close on at least the leader).
        journal = cluster.raft.journal
        assert journal is not None
        lengths = [len(journal.entries(n)) for n in journal.nodes()]
        assert max(lengths) >= 2
    finally:
        cluster.stop()


def test_double_apply_harness_catches_non_idempotent_apply(
    repl_check, server_keys, colony_keys
):
    """Strip the CAS out of the assign apply: the digest fixpoint check
    must record a divergence, surfaced by propose_and_wait."""
    cluster, client, colony_prv = _ha_cluster(server_keys, colony_keys, seed=22)
    try:
        ex = ExecutorBase(client, "dev", "bad-w", "worker", colony_prvkey=colony_prv)
        p = client.submit(spec(), colony_prv)

        def non_idempotent_apply(op):
            cur = cluster.db.get_process(op["processid"])
            cur.retries += 1  # no CAS: every replay mutates again
            cluster.db.update_process(cur)

        cluster.servers[0].apply_assign = non_idempotent_apply
        op = {
            "op": "assign",
            "opid": new_id(),
            "processid": p["processid"],
            "executorid": ex.executorid,
            "ts": now_ns(),
        }
        leader = cluster.raft.leader_id()
        with pytest.raises(ReplicationDivergenceError) as ei:
            cluster.raft.propose_and_wait(leader, op)
            cluster.raft.check_divergence()
        assert "not idempotent" in str(ei.value)
    finally:
        cluster.stop()


def test_ha_chaos_failover_journals_byte_identical(
    repl_check, server_keys, colony_keys
):
    """Acceptance criterion: 3-replica kill/revive failover under
    REPRO_REPL_CHECK=1 completes with byte-identical apply journals."""
    cluster, client, colony_prv = _ha_cluster(server_keys, colony_keys, seed=23)
    try:
        ex = ExecutorBase(client, "dev", "chaos-w", "worker",
                          colony_prvkey=colony_prv)
        ex.register_function("echo", lambda ctx, *a: list(a))
        ex.start(poll_timeout=0.3)

        p1 = client.submit(spec(args=[1]), colony_prv)
        assert client.wait(p1["processid"], colony_prv, timeout=10)[
            "state"] == "successful"

        lid = cluster.raft.leader_id()
        cluster.kill_server(int(lid[1:]))
        p2 = client.submit(spec(args=[2]), colony_prv)
        assert client.wait(p2["processid"], colony_prv, timeout=20)[
            "state"] == "successful"
        cluster.revive_server(int(lid[1:]))
        p3 = client.submit(spec(args=[3]), colony_prv)
        assert client.wait(p3["processid"], colony_prv, timeout=20)[
            "state"] == "successful"
        ex.stop()

        # Wait for the revived replica to catch up, then compare the
        # journals byte for byte: every node applied the same entries
        # with the same effects at every index.
        journal = cluster.raft.journal
        assert journal is not None
        commit = max(n.commit_index for n in cluster.raft.nodes.values())
        deadline = time.time() + 20
        while time.time() < deadline:
            if all(n.last_applied >= commit
                   for n in cluster.raft.nodes.values()):
                break
            time.sleep(0.05)
        journal.check()
        entries = [journal.entries(n) for n in sorted(journal.nodes())]
        assert len(entries) == 3
        assert entries[0] == entries[1] == entries[2]
        assert len(entries[0]) >= 6  # ≥3 assigns + ≥3 closes, all replicated
    finally:
        cluster.stop()
