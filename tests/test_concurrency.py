"""Concurrency-contract analysis: lock-order detector, contracts, lint.

Three layers under test (src/repro/analysis):
  * the runtime detector catches *seeded* violations (cycle, cross-shard
    nesting, blocking under the leaf lock, condition-wait under a lock);
  * @requires_lock / @no_locks_held raise on seeded contract breaches and
    pass on the real call paths;
  * the full broker (submit/assign/close/addchild/failsafe, two colonies,
    many threads) runs clean — zero recorded violations.
"""

import threading
import time

import pytest

from repro.analysis import locktrack
from repro.analysis.contracts import LockContractError, no_locks_held, requires_lock
from repro.analysis.lint import lint_source
from repro.core import (
    Colonies,
    Crypto,
    ExecutorBase,
    FunctionSpec,
    InProcTransport,
    MemoryDatabase,
)
from repro.core.cluster import standalone_server


@pytest.fixture()
def tracking():
    """Detector on, clean slate; restore prior mode and wipe seeded noise."""
    prev = locktrack.is_enabled()
    locktrack.enable(True)
    locktrack.reset()
    yield
    locktrack.reset()
    locktrack.enable(prev)


def _kinds():
    return [v["kind"] for v in locktrack.violations()]


# ---------------------------------------------------------------------------
# Seeded-violation proofs: the detector actually fires
# ---------------------------------------------------------------------------


def test_detector_catches_lock_order_cycle(tracking):
    a = locktrack.TrackedRLock("alpha")
    b = locktrack.TrackedRLock("beta")
    with a:
        with b:  # edge alpha -> beta
            pass
    assert _kinds() == []
    with b:
        with a:  # edge beta -> alpha closes the cycle
            pass
    assert "lock-order-cycle" in _kinds()


def test_detector_catches_cross_shard_nesting(tracking):
    s1 = locktrack.TrackedRLock("shard:c1")
    s2 = locktrack.TrackedRLock("shard:c2")
    with s1:
        with s2:
            pass
    assert "cross-instance" in _kinds()


def test_detector_catches_acquire_under_leaf(tracking):
    g = locktrack.TrackedRLock("glock")
    other = locktrack.TrackedRLock("shard:x")
    with g:
        with other:
            pass
    assert "acquire-under-leaf" in _kinds()


def test_detector_catches_wait_under_lock(tracking):
    held = locktrack.TrackedRLock("shard:w")
    cv = threading.Condition(locktrack.make_lock("queuecv:w:worker"))
    with held:
        with cv:
            cv.wait(timeout=0.01)
    assert "wait-under-lock" in _kinds()


def test_declared_wait_allowance_suppresses_only_that_pairing(tracking):
    """allow_wait("raft", "assignlocal") (raft.py) lets propose_and_wait
    park on commit_cv under the leader-local assign lock; any other held
    family still fires."""
    import repro.core.raft  # noqa: F401  — registers the allowance

    allowed = locktrack.TrackedRLock("assignlocal:dev")
    cv = threading.Condition(locktrack.make_lock("raft:n0"))
    with allowed:
        with cv:
            cv.wait(timeout=0.01)
    assert "wait-under-lock" not in _kinds()
    other = locktrack.TrackedRLock("shard:dev")
    with other:
        with cv:
            cv.wait(timeout=0.01)
    assert "wait-under-lock" in _kinds()


def test_reentrant_acquire_is_not_a_violation(tracking):
    s = locktrack.TrackedRLock("shard:re")
    with s:
        with s:  # re-entrant on the SAME instance: fine
            pass
    assert _kinds() == []


def test_condition_wait_keeps_held_set_accurate(tracking):
    """After a Condition.wait() round-trip the lock is held again exactly
    as before (the _release_save/_acquire_restore protocol)."""
    lk = locktrack.TrackedRLock("queuecv:acc:worker")
    cv = threading.Condition(lk)
    with cv:
        assert lk.held_by_current_thread()
        cv.wait(timeout=0.01)
        assert lk.held_by_current_thread()
    assert not lk.held_by_current_thread()
    assert _kinds() == []


# ---------------------------------------------------------------------------
# Contract decorators
# ---------------------------------------------------------------------------


class _FakeShard:
    def __init__(self, name="shard:z"):
        self.lock = locktrack.TrackedRLock(name)


def test_requires_lock_raises_without_lock(tracking):
    @requires_lock("shard")
    def touch(s):
        return "ok"

    s = _FakeShard()
    with pytest.raises(LockContractError):
        touch(s)
    with s.lock:
        assert touch(s) == "ok"


def test_requires_lock_fires_on_real_database_method(tracking):
    """database.py's decorated internals enforce the comment-contract."""
    db = MemoryDatabase()
    shard = db._cfs("dev")
    with pytest.raises(LockContractError):
        db._cfs_list_locked(shard, "/a")
    with shard.lock:
        assert db._cfs_list_locked(shard, "/a") == []


def test_no_locks_held_raises_when_holding(tracking):
    @no_locks_held()
    def block():
        return "ok"

    @no_locks_held("shard")
    def block_db_only():
        return "ok"

    s = _FakeShard()
    assert block() == "ok"
    with s.lock:
        with pytest.raises(LockContractError):
            block()
        with pytest.raises(LockContractError):
            block_db_only()
    other = locktrack.TrackedRLock("assignlocal:c9")
    with other:
        # family filter: assignlocal is legitimately held across Raft waits
        assert block_db_only() == "ok"


def test_decorators_pass_through_when_disabled():
    assert not locktrack.is_enabled() or True  # env may force tracking on
    prev = locktrack.is_enabled()
    locktrack.enable(False)
    try:

        @requires_lock("shard")
        def touch(s):
            return "ok"

        assert touch(_FakeShard()) == "ok"  # no lock held, no check
    finally:
        locktrack.enable(prev)


# ---------------------------------------------------------------------------
# Static lint: seeded sources trip each rule
# ---------------------------------------------------------------------------


def _rules(src):
    return sorted({v.rule for v in lint_source(src, "seeded.py")})


def test_lint_flags_kv_list_scan():
    assert _rules("def tick(self):\n    return self.db.kv_list('crons')\n") == [
        "LNT001"
    ]
    # ... but not inside migration code
    assert _rules("def _migrate_x(self):\n    return self.db.kv_list('crons')\n") == []


def test_lint_flags_blocking_under_glock():
    src = (
        "import time\n"
        "def f(self):\n"
        "    with self._glock:\n"
        "        time.sleep(1)\n"
    )
    assert "LNT002" in _rules(src)
    src2 = "def f(self, s):\n    with self._glock:\n        with s.lock:\n            pass\n"
    assert "LNT002" in _rules(src2)


def test_lint_flags_bare_except_and_mutable_default():
    assert _rules("try:\n    pass\nexcept:\n    pass\n") == ["LNT003"]
    assert _rules("def f(x=[]):\n    pass\n") == ["LNT004"]


def test_lint_flags_missing_shard_contract():
    src = "def _mutate(self, s: _ColonyShard) -> None:\n    s.procs.clear()\n"
    assert _rules(src) == ["LNT005"]
    ok = (
        "@requires_lock('shard')\n"
        "def _mutate(self, s: _ColonyShard) -> None:\n"
        "    s.procs.clear()\n"
    )
    assert _rules(ok) == []


def test_lint_repo_is_clean():
    import os

    from repro.analysis import lint

    root = os.path.join(os.path.dirname(__file__), "..")
    paths = [
        os.path.join(root, p)
        for p in lint.DEFAULT_PATHS
        if os.path.exists(os.path.join(root, p))
    ]
    nfiles, vs = lint.run(paths)
    assert nfiles > 0
    assert [str(v) for v in vs] == []


# ---------------------------------------------------------------------------
# Hold-time recording: per-family stats, long-hold warnings, wait exemption
# ---------------------------------------------------------------------------


@pytest.fixture()
def hold_warn(tracking):
    """Yields set_hold_warn_ms; restores the configured threshold after."""
    prev = locktrack._REG.hold_warn_ns
    yield locktrack.set_hold_warn_ms
    locktrack._REG.hold_warn_ns = prev


def test_hold_times_recorded_per_family(tracking):
    s = locktrack.TrackedRLock("shard:hold")
    with s:
        time.sleep(0.02)
    with s:
        pass
    fam = locktrack.hold_stats()["shard"]
    assert fam["count"] == 2
    assert fam["max_ns"] >= 15_000_000
    assert 0 < fam["mean_ns"] <= fam["max_ns"]
    assert fam["max_lock"] == "shard:hold"


def test_reentrant_hold_timed_from_outermost_acquire(tracking):
    s = locktrack.TrackedRLock("shard:re-hold")
    with s:
        with s:  # inner re-acquire must not split or restart the hold
            time.sleep(0.01)
    fam = locktrack.hold_stats()["shard"]
    assert fam["count"] == 1
    assert fam["max_ns"] >= 8_000_000


def test_long_hold_warns_but_is_not_a_violation(hold_warn):
    hold_warn(5)
    g = locktrack.TrackedRLock("glock")
    with g:
        time.sleep(0.02)
    ws = locktrack.hold_warnings()
    assert len(ws) == 1 and ws[0]["lock"] == "glock"
    assert ws[0]["held_ns"] >= 5_000_000
    # long holds are a perf signal, never a correctness failure: a slow
    # CI box must not trip the lock gate
    assert locktrack.violations() == []


def test_condition_wait_parked_time_is_not_billed(hold_warn):
    hold_warn(30)
    cv = threading.Condition(locktrack.make_lock("queuecv:hold:w"))
    with cv:
        cv.wait(timeout=0.1)  # lock released while parked
    assert locktrack.hold_warnings() == []
    fam = locktrack.hold_stats()["queuecv"]
    # the pre-wait and post-wait segments are two short holds
    assert fam["count"] == 2
    assert fam["max_ns"] < 30_000_000


# ---------------------------------------------------------------------------
# Multi-thread broker stress under the detector: zero violations
# ---------------------------------------------------------------------------


def _spec(colony, etype="worker", **kw):
    d = {
        "conditions": {"colonyname": colony, "executortype": etype},
        "funcname": "echo",
        "maxexectime": 60,
    }
    d.update(kw)
    return FunctionSpec.from_dict(d)


def test_multithread_stress_runs_clean(tracking):
    """submit/assign/close/addchild/failsafe across 2 colonies, detector on.

    The server, database, and every lock in them are created while
    tracking is enabled, so each acquisition on every thread feeds the
    order graph; the assertion is simply that nothing fired.
    """
    server_prv = Crypto.prvkey()
    server_id = Crypto.id(server_prv)
    colony_prv = Crypto.prvkey()
    colony_id = Crypto.id(colony_prv)

    srv = standalone_server(server_id, MemoryDatabase())
    client = Colonies(InProcTransport([srv]))
    colonies = ("c1", "c2")
    for cname in colonies:
        client.add_colony(cname, colony_id, server_prv)
    # Fast failsafe tick: scans run concurrently with the traffic below.
    srv.start_background(failsafe_interval=0.05)

    stop = threading.Event()
    errors: list[BaseException] = []

    def guard(fn):
        def run():
            try:
                while not stop.is_set():
                    fn()
            except BaseException as e:  # noqa: BLE001 — surfaced via `errors`
                errors.append(e)

        return run

    executors = []
    threads = []
    for cname in colonies:
        for i in range(2):
            ex = ExecutorBase(
                client, cname, f"w{i}", "worker", colony_prvkey=colony_prv
            )
            n_children = [0]

            def echo(ctx, *args, _ex=ex, _n=n_children):
                # Every few processes, grow the DAG from inside execution.
                _n[0] += 1
                if _n[0] % 5 == 0 and not ctx.process.parents:
                    ctx.client.add_child(
                        ctx.process.processid,
                        _spec(ctx.process.colonyname),
                        _ex.prvkey,
                    )
                return list(args)

            ex.register_function("echo", echo)
            executors.append(ex)
            threads.append(threading.Thread(target=guard(lambda e=ex: e.step(0.1))))

    def submitter(cname):
        def once():
            client.submit(_spec(cname, args=["x"]), colony_prv)
            # A short-deadline process the failsafe will reset or fail.
            client.submit(_spec(cname, maxexectime=1, maxretries=0), colony_prv)
            # One nobody can run: exercises maxwaittime expiry.
            client.submit(
                _spec(cname, etype="ghost", maxwaittime=1), colony_prv
            )
            time.sleep(0.01)

        return once

    for cname in colonies:
        threads.append(threading.Thread(target=guard(submitter(cname))))

    for t in threads:
        t.start()
    time.sleep(2.5)
    stop.set()
    for t in threads:
        t.join(timeout=5)
    srv.stop()

    assert not errors, errors
    assert sum(ex.processed for ex in executors) > 0
    assert locktrack.violations() == []
