"""The stateless maxexectime/maxwaittime failsafe (paper §3.4)."""

import time

import pytest

from repro.core import Colonies, ExecutorBase, FunctionSpec, InProcTransport
from repro.core.errors import ConflictError


def spec(**kw):
    d = {
        "conditions": {"colonyname": "dev", "executortype": "worker"},
        "funcname": "echo",
    }
    d.update(kw)
    return FunctionSpec.from_dict(d)


def test_expired_process_is_reset(colony):
    """A crashed executor's process goes back to the queue (scale-down-by-kill)."""
    client, srv = colony["client"], colony["server"]
    ex = ExecutorBase(client, "dev", "w-crash", "worker", colony_prvkey=colony["colony_prv"])
    p = client.submit(spec(maxexectime=1, maxretries=3), colony["colony_prv"])
    # executor takes the process... and vanishes without closing
    pd = client.assign("dev", 2.0, ex.prvkey)
    assert pd["processid"] == p["processid"]
    assert client.get_process(p["processid"], colony["colony_prv"])["state"] == "running"
    time.sleep(1.1)
    counters = srv.failsafe_scan()
    assert counters["reset"] == 1
    reset = client.get_process(p["processid"], colony["colony_prv"])
    assert reset["state"] == "waiting" and reset["retries"] == 1
    # a healthy executor picks it up and completes
    ex2 = ExecutorBase(client, "dev", "w-heal", "worker", colony_prvkey=colony["colony_prv"])
    ex2.register_function("echo", lambda ctx: ["recovered"])
    assert ex2.step(2.0)
    done = client.get_process(p["processid"], colony["colony_prv"])
    assert done["state"] == "successful" and done["out"] == ["recovered"]


def test_maxretries_exhausted_fails(colony):
    client, srv = colony["client"], colony["server"]
    ex = ExecutorBase(client, "dev", "w-mr", "worker", colony_prvkey=colony["colony_prv"])
    p = client.submit(spec(maxexectime=1, maxretries=0), colony["colony_prv"])
    client.assign("dev", 2.0, ex.prvkey)
    time.sleep(1.1)
    counters = srv.failsafe_scan()
    assert counters["failed"] == 1
    done = client.get_process(p["processid"], colony["colony_prv"])
    assert done["state"] == "failed" and "maxretries" in done["errors"][0]


def test_stale_executor_close_rejected(colony):
    """Paper §4.1: 'The previous executor then receives an error when trying
    to send a close request' after the failsafe re-assigned its process."""
    client, srv = colony["client"], colony["server"]
    ex1 = ExecutorBase(client, "dev", "w-slow", "worker", colony_prvkey=colony["colony_prv"])
    p = client.submit(spec(maxexectime=1, maxretries=3), colony["colony_prv"])
    pd = client.assign("dev", 2.0, ex1.prvkey)
    time.sleep(1.1)
    srv.failsafe_scan()  # lease expired -> back to queue
    ex2 = ExecutorBase(client, "dev", "w-fast", "worker", colony_prvkey=colony["colony_prv"])
    pd2 = client.assign("dev", 2.0, ex2.prvkey)
    assert pd2["processid"] == p["processid"]
    with pytest.raises(ConflictError):
        client.close(p["processid"], ["stale result"], ex1.prvkey)
    client.close(p["processid"], ["fresh result"], ex2.prvkey)
    assert client.get_process(p["processid"], colony["colony_prv"])["out"] == ["fresh result"]


def test_close_racing_failsafe_reset_is_rejected(colony, monkeypatch):
    """Deterministic close/failsafe interleaving: the failsafe fires in the
    window between ``_h_close``'s ownership precheck and the state mutation.
    The close must fail with ConflictError and the reset must survive —
    on the unsynchronized seed path the stale close silently overwrote the
    re-queued process (losing the retry)."""
    client, srv = colony["client"], colony["server"]
    ex1 = ExecutorBase(client, "dev", "w-race", "worker", colony_prvkey=colony["colony_prv"])
    p = client.submit(spec(maxexectime=1, maxretries=3), colony["colony_prv"])
    pd = client.assign("dev", 2.0, ex1.prvkey)
    assert pd["processid"] == p["processid"]
    time.sleep(1.1)  # lease expired; the background failsafe hasn't run yet

    real_close = srv.close_process

    def close_after_failsafe(proc, succeeded, output, errors, *a, **kw):
        # Simulates the racy schedule: _h_close already validated ownership,
        # then the failsafe scanner resets the process, then close proceeds.
        counters = srv.failsafe_scan()
        assert counters["reset"] == 1
        return real_close(proc, succeeded, output, errors, *a, **kw)

    monkeypatch.setattr(srv, "close_process", close_after_failsafe)
    with pytest.raises(ConflictError):
        client.close(p["processid"], ["stale result"], ex1.prvkey)
    after = client.get_process(p["processid"], colony["colony_prv"])
    assert after["state"] == "waiting" and after["retries"] == 1
    # the re-queued process is still assignable by a healthy executor
    ex2 = ExecutorBase(client, "dev", "w-race2", "worker", colony_prvkey=colony["colony_prv"])
    monkeypatch.setattr(srv, "close_process", real_close)
    pd2 = client.assign("dev", 2.0, ex2.prvkey)
    assert pd2["processid"] == p["processid"]


def test_maxwaittime_expires_queued_process(colony):
    client, srv = colony["client"], colony["server"]
    p = client.submit(spec(maxwaittime=1), colony["colony_prv"])
    time.sleep(1.1)
    counters = srv.failsafe_scan()
    assert counters["waitexpired"] == 1
    done = client.get_process(p["processid"], colony["colony_prv"])
    assert done["state"] == "failed" and "maxwaittime" in done["errors"][0]


def test_background_scanner_recovers_without_manual_scan(colony):
    client, srv = colony["client"], colony["server"]
    srv.start_background(failsafe_interval=0.1)
    ex = ExecutorBase(client, "dev", "w-bg", "worker", colony_prvkey=colony["colony_prv"])
    p = client.submit(spec(maxexectime=1, maxretries=2), colony["colony_prv"])
    client.assign("dev", 2.0, ex.prvkey)  # take it and vanish
    deadline = time.time() + 5
    while time.time() < deadline:
        if client.get_process(p["processid"], colony["colony_prv"])["state"] == "waiting":
            break
        time.sleep(0.05)
    assert client.get_process(p["processid"], colony["colony_prv"])["state"] == "waiting"
