"""End-to-end behaviour of the whole meta-OS (paper §4.1 walkthrough).

This is the paper's own quickstart: register a helloworld executor with a
colony, submit a function specification (Listing 1/5), have it assigned
(Listing 4), and read the result — plus the queue surviving a server
restart (statelessness, §3.4.3) when backed by sqlite.
"""

import pytest

from repro.core import (
    Colonies,
    Crypto,
    ExecutorBase,
    FunctionSpec,
    InProcTransport,
    SqliteDatabase,
)
from repro.core.cluster import standalone_server


def test_paper_quickstart_listing_3_4_5(colony):
    client = colony["client"]
    colonyname = colony["name"]
    # Listing 3: create identity, register + approve executor, add function
    executor_prvkey = Crypto.prvkey()
    executorid = Crypto.id(executor_prvkey)
    client.add_executor(
        {
            "executorname": "helloworld_executor",
            "executorid": executorid,
            "colonyname": colonyname,
            "executortype": "helloworld_executor",
        },
        colony["colony_prv"],
    )
    client.approve_executor(executorid, colony["colony_prv"])
    client.add_function(executorid, colonyname, "helloworld", executor_prvkey)

    # Listing 5: submit the function specification (Listing 1 contents)
    spec = FunctionSpec.from_dict({
        "conditions": {
            "colonyname": colonyname,
            "executortype": "helloworld_executor",
        },
        "funcname": "helloworld",
        "args": [],
        "maxwaittime": 10,
        "maxexectime": 100,
        "maxretries": 3,
        "priority": 1,
    })
    submitted = client.submit(spec, colony["colony_prv"])

    # Listing 4: assign + close
    process = client.assign(colonyname, 10, executor_prvkey)
    assert process["spec"]["funcname"] == "helloworld"
    client.close(process["processid"], ["hello world"], executor_prvkey)

    done = client.get_process(submitted["processid"], colony["colony_prv"])
    assert done["state"] == "successful"
    assert done["out"] == ["hello world"]


def test_queue_survives_server_restart(tmp_path, server_keys, colony_keys):
    """Statelessness (§3.4.3): no in-memory session state — a brand-new
    server process over the same database resumes exactly where the old
    one stopped."""
    server_prv, server_id = server_keys
    colony_prv, colony_id = colony_keys
    db_path = str(tmp_path / "colonies.db")

    srv1 = standalone_server(server_id, SqliteDatabase(db_path))
    client1 = Colonies(InProcTransport([srv1]))
    client1.add_colony("dev", colony_id, server_prv)
    ex = ExecutorBase(client1, "dev", "w1", "worker", colony_prvkey=colony_prv)
    p = client1.submit(
        FunctionSpec.from_dict({
            "conditions": {"colonyname": "dev", "executortype": "worker"},
            "funcname": "echo", "args": ["persisted"],
        }),
        colony_prv,
    )
    srv1.stop()
    del srv1  # server "crashes"

    srv2 = standalone_server(server_id, SqliteDatabase(db_path))
    client2 = Colonies(InProcTransport([srv2]))
    pd = client2.assign("dev", 2.0, ex.prvkey)  # same executor identity
    assert pd["processid"] == p["processid"]
    client2.close(pd["processid"], ["done-after-restart"], ex.prvkey)
    done = client2.get_process(p["processid"], colony_prv)
    assert done["state"] == "successful" and done["out"] == ["done-after-restart"]
    srv2.stop()
