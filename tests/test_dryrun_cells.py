"""Dry-run machinery on a small host-device mesh (subprocess: needs its
own XLA_FLAGS before jax import)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax
    from repro.launch.dryrun_lib import run_cell
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    recs = []
    for arch, shape in {cells}:
        recs.append(run_cell(arch, shape, mesh, cfg_overrides={overrides}))
    print("RESULT::" + json.dumps(recs))
    """
)


def _run_cells(cells, overrides=None):
    script = _SCRIPT.format(cells=repr(cells), overrides=repr(overrides or {}))
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, timeout=1200, cwd=ROOT,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    payload = [l for l in out.stdout.splitlines() if l.startswith("RESULT::")][0]
    return json.loads(payload[len("RESULT::"):])


@pytest.mark.slow
def test_train_prefill_decode_cells_compile():
    recs = _run_cells([
        ("seamless-m4t-large-v2", "train_4k"),
        ("stablelm-3b", "prefill_32k"),
        ("mixtral-8x7b", "decode_32k"),
    ])
    for rec in recs:
        assert rec["status"] == "ok", rec.get("error")
        r = rec["roofline"]
        assert r["flops_per_device"] > 0
        assert r["bytes_per_device"] > 0
        assert r["bottleneck"] in ("compute", "memory", "collective")
        # collective traffic must exist on a sharded mesh
        assert rec["collectives"]["total_bytes"] > 0


@pytest.mark.slow
def test_long_context_skip_policy():
    recs = _run_cells([
        ("qwen2.5-14b", "long_500k"),  # pure attention -> skipped
        ("rwkv6-7b", "long_500k"),  # SSM -> runs
    ])
    assert recs[0]["status"] == "skipped"
    assert "sub-quadratic" in recs[0]["reason"]
    assert recs[1]["status"] == "ok"


@pytest.mark.slow
def test_scan_loops_are_scaled():
    recs = _run_cells([("granite-3-8b", "train_4k")])
    rec = recs[0]
    trips = rec["loop_trip_counts"]
    assert any(v == 40 for v in trips.values()), trips  # 40 scanned layers
