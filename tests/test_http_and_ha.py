"""HTTP transport (long-poll) and the HA Raft cluster end-to-end."""

import time

import pytest

from repro.core import Colonies, Crypto, ExecutorBase, FunctionSpec, InProcTransport
from repro.core.cluster import HAColonyCluster
from repro.core.http_transport import ColoniesHttpServer, HttpTransport


def spec(**kw):
    d = {"conditions": {"colonyname": "dev", "executortype": "worker"},
         "funcname": "echo", "maxexectime": 60}
    d.update(kw)
    return FunctionSpec.from_dict(d)


def test_http_end_to_end(colony):
    http = ColoniesHttpServer(colony["server"])
    http.start()
    try:
        client = Colonies(HttpTransport(http.host, http.port))
        ex = ExecutorBase(client, "dev", "http-w", "worker",
                          colony_prvkey=colony["colony_prv"])
        ex.register_function("echo", lambda ctx, *a: list(a))
        p = client.submit(spec(args=["over-http"]), colony["colony_prv"])
        assert ex.step(2.0)
        done = client.wait(p["processid"], colony["colony_prv"], timeout=5)
        assert done["out"] == ["over-http"]
    finally:
        http.stop()


def test_http_health_and_bad_request(colony):
    import json
    import urllib.request

    http = ColoniesHttpServer(colony["server"])
    http.start()
    try:
        with urllib.request.urlopen(
            f"http://{http.host}:{http.port}/health", timeout=5
        ) as r:
            assert json.loads(r.read())["status"] == "ok"
        req = urllib.request.Request(
            f"http://{http.host}:{http.port}/api", data=b"not json", method="POST"
        )
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(req, timeout=5)
        assert ei.value.code == 400
    finally:
        http.stop()


def test_ha_cluster_failover(server_keys, colony_keys):
    """Fig. 3: kill the leader replica; assigns keep working via failover,
    and every process is assigned exactly once (raft-serialized)."""
    server_prv, server_id = server_keys
    colony_prv, colony_id = colony_keys
    cluster = HAColonyCluster(server_id, replicas=3, seed=11)
    cluster.start(failsafe_interval=0.1)
    try:
        assert cluster.wait_for_leader(10)
        client = Colonies(InProcTransport(cluster.servers))
        client.add_colony("dev", colony_id, server_prv)
        ex = ExecutorBase(client, "dev", "ha-w", "worker", colony_prvkey=colony_prv)
        ex.register_function("echo", lambda ctx, *a: list(a))
        ex.start(poll_timeout=0.3)

        p1 = client.submit(spec(args=[1]), colony_prv)
        assert client.wait(p1["processid"], colony_prv, timeout=10)["state"] == "successful"

        lid = cluster.raft.leader_id()
        cluster.kill_server(int(lid[1:]))
        p2 = client.submit(spec(args=[2]), colony_prv)
        done = client.wait(p2["processid"], colony_prv, timeout=20)
        assert done["state"] == "successful"
        assert cluster.raft.leader_id() != lid
        ex.stop()
    finally:
        cluster.stop()


def test_ha_exactly_once_assignment(server_keys, colony_keys):
    """Two executors racing on the same queue never get the same process."""
    server_prv, server_id = server_keys
    colony_prv, colony_id = colony_keys
    cluster = HAColonyCluster(server_id, replicas=3, seed=12)
    cluster.start(failsafe_interval=0.2)
    try:
        assert cluster.wait_for_leader(10)
        client = Colonies(InProcTransport(cluster.servers))
        client.add_colony("dev", colony_id, server_prv)
        seen: list[str] = []
        ex1 = ExecutorBase(client, "dev", "race-1", "worker", colony_prvkey=colony_prv)
        ex2 = ExecutorBase(client, "dev", "race-2", "worker", colony_prvkey=colony_prv)
        for ex in (ex1, ex2):
            ex.register_function("echo", lambda ctx, pid: seen.append(pid) or [pid])
            ex.start(poll_timeout=0.3)
        pids = []
        for i in range(6):
            p = client.submit(spec(args=[f"p{i}"]), colony_prv)
            pids.append(p["processid"])
        for pid in pids:
            assert client.wait(pid, colony_prv, timeout=20)["state"] == "successful"
        ex1.stop(); ex2.stop()
        assert sorted(seen) == sorted(f"p{i}" for i in range(6))  # no dups
    finally:
        cluster.stop()
