"""CFS meta-filesystem (paper §3.4.5): immutability, snapshots, sync."""

import os

import pytest

from repro.core.errors import ConflictError, NotFoundError
from repro.core.fs import CFSClient, LocalStorage, MemoryStorage, checksum


@pytest.fixture()
def cfs(colony):
    return CFSClient(colony["client"], MemoryStorage(), colony["colony_prv"])


def test_upload_download_roundtrip(colony, cfs):
    cfs.upload_bytes("dev", "/data", "a.bin", b"\x00\x01\x02")
    assert cfs.download_bytes("dev", "/data", "a.bin") == b"\x00\x01\x02"


def test_immutability_revisions(colony, cfs):
    """Re-adding a file creates a new revision; old bytes stay retrievable."""
    m1 = cfs.upload_bytes("dev", "/src", "f.txt", b"v1")
    m2 = cfs.upload_bytes("dev", "/src", "f.txt", b"v2")
    assert m2["revision"] == m1["revision"] + 1
    assert cfs.download_bytes("dev", "/src", "f.txt") == b"v2"  # latest wins
    # the v1 blob still exists (content-addressed, immutable)
    assert cfs.storage.get(m1["storage"]["url"]) == b"v1"


def test_checksum_validation(colony, cfs):
    meta = cfs.upload_bytes("dev", "/src", "c.txt", b"data")
    assert meta["checksum"] == checksum(b"data")
    # corrupt the blob behind CFS's back -> download must fail
    key = meta["storage"]["url"].split("://")[1]
    cfs.storage._blobs[key] = b"tampered"
    with pytest.raises(ConflictError):
        cfs.download_bytes("dev", "/src", "c.txt")


def test_snapshot_pins_revisions(colony, cfs, tmp_path):
    """Queued processes must see frozen inputs (paper: snapshots)."""
    client = colony["client"]
    cfs.upload_bytes("dev", "/code", "main.py", b"print(1)")
    snap = client.create_snapshot("dev", "/code", "s1", colony["colony_prv"])
    cfs.upload_bytes("dev", "/code", "main.py", b"print(2)")  # later revision
    out = tmp_path / "snap"
    cfs.materialize_snapshot("dev", snap["snapshotid"], str(out))
    assert (out / "main.py").read_bytes() == b"print(1)"
    assert cfs.download_bytes("dev", "/code", "main.py") == b"print(2)"


def test_pinned_revision_cannot_be_removed(colony, cfs):
    client = colony["client"]
    meta = cfs.upload_bytes("dev", "/pin", "x.bin", b"x")
    client.create_snapshot("dev", "/pin", "s", colony["colony_prv"])
    with pytest.raises(ConflictError):
        client.remove_file("dev", meta["fileid"], colony["colony_prv"])


def test_dir_sync_roundtrip(colony, cfs, tmp_path):
    src = tmp_path / "up"
    (src / "sub").mkdir(parents=True)
    (src / "a.txt").write_bytes(b"alpha")
    (src / "sub" / "b.txt").write_bytes(b"beta")
    cfs.sync_up("dev", "/tree", str(src))
    dst = tmp_path / "down"
    cfs.sync_down("dev", "/tree", str(dst))
    assert (dst / "a.txt").read_bytes() == b"alpha"
    assert (dst / "sub" / "b.txt").read_bytes() == b"beta"


def test_local_storage_backend(tmp_path):
    store = LocalStorage(str(tmp_path / "blobs"))
    url = store.put(b"payload")
    assert url.startswith("local://")
    assert store.get(url) == b"payload"
    # content-addressed: same content, same blob
    assert store.put(b"payload") == url
    with pytest.raises(NotFoundError):
        store.get("local://" + "0" * 64)


def test_missing_file(colony, cfs):
    with pytest.raises(NotFoundError):
        cfs.download_bytes("dev", "/nope", "missing.txt")


def test_getfiles_root_label_sees_whole_tree(colony, cfs, tmp_path):
    """getfiles('/') must list every subdirectory, not just root-level files.

    Seed bug: the prefix test used ``label + "/"`` which is ``"//"`` for
    the root, so the root listing silently dropped all nested labels (and
    ``sync_down`` of the root materialized nothing below it).
    """
    client = colony["client"]
    cfs.upload_bytes("dev", "/", "root.txt", b"r")
    cfs.upload_bytes("dev", "/a", "a.txt", b"a")
    cfs.upload_bytes("dev", "/a/b", "b.txt", b"b")
    files = client.get_files("dev", "/", colony["colony_prv"])
    assert [(f["label"], f["name"]) for f in files] == [
        ("/", "root.txt"), ("/a", "a.txt"), ("/a/b", "b.txt"),
    ]
    dst = tmp_path / "down"
    cfs.sync_down("dev", "/", str(dst))
    assert (dst / "root.txt").read_bytes() == b"r"
    assert (dst / "a" / "a.txt").read_bytes() == b"a"
    assert (dst / "a" / "b" / "b.txt").read_bytes() == b"b"


def test_snapshot_with_tombstoned_file_skips_missing(colony, cfs, tmp_path):
    """A snapshot referencing a vanished revision (backfilled/inconsistent
    table) must flag it, not hand clients None entries that TypeError in
    materialize_snapshot."""
    client = colony["client"]
    cfs.upload_bytes("dev", "/tomb", "keep.txt", b"k")
    gone = cfs.upload_bytes("dev", "/tomb", "gone.txt", b"g")
    snap = client.create_snapshot("dev", "/tomb", "s", colony["colony_prv"])
    # drop one revision behind the pin refcounts' back
    shard = colony["server"].db._cfs("dev")
    with shard.lock:
        shard.files.pop(gone["fileid"])
    got = client.get_snapshot("dev", snap["snapshotid"], colony["colony_prv"])
    assert [f["name"] for f in got["files"]] == ["keep.txt"]
    assert got["missing"] == [gone["fileid"]]
    out = tmp_path / "mat"
    written = cfs.materialize_snapshot("dev", snap["snapshotid"], str(out))
    assert [os.path.basename(w) for w in written] == ["keep.txt"]


def test_snapshot_listing_and_removal(colony, cfs):
    client = colony["client"]
    cfs.upload_bytes("dev", "/s2", "f", b"z")
    snap = client.create_snapshot("dev", "/s2", "tmp", colony["colony_prv"])
    got = client.get_snapshot("dev", snap["snapshotid"], colony["colony_prv"])
    assert got["files"][0]["name"] == "f"
    client.remove_snapshot("dev", snap["snapshotid"], colony["colony_prv"])
    with pytest.raises(NotFoundError):
        client.get_snapshot("dev", snap["snapshotid"], colony["colony_prv"])


def test_get_snapshots_lists_whole_colony(colony, cfs):
    """Per-colony snapshot listing RPC — indexed, oldest first."""
    client = colony["client"]
    cfs.upload_bytes("dev", "/list/a", "fa", b"a")
    cfs.upload_bytes("dev", "/list/b", "fb", b"b")
    s1 = client.create_snapshot("dev", "/list/a", "first", colony["colony_prv"])
    s2 = client.create_snapshot("dev", "/list/b", "second", colony["colony_prv"])
    listed = client.get_snapshots("dev", colony["colony_prv"])
    ids = [s["snapshotid"] for s in listed]
    assert ids.index(s1["snapshotid"]) < ids.index(s2["snapshotid"])
    names = {s["snapshotid"]: s["name"] for s in listed}
    assert names[s1["snapshotid"]] == "first"
    client.remove_snapshot("dev", s1["snapshotid"], colony["colony_prv"])
    left = [s["snapshotid"] for s in client.get_snapshots("dev", colony["colony_prv"])]
    assert s1["snapshotid"] not in left and s2["snapshotid"] in left


# ---------------------------------------------------------------------------
# Bugfix sweep regressions (see CHANGES.md: blob-plane PR)
# ---------------------------------------------------------------------------


def test_add_file_requires_storage_reference(colony):
    """Seed bug: addfile accepted entries with no/empty storage dict, so
    every later download died with a bare KeyError instead of failing at
    the RPC boundary."""
    from repro.core.errors import ValidationError

    client = colony["client"]
    base = {
        "colonyname": "dev",
        "label": "/val",
        "name": "f.bin",
        "size": 1,
        "checksum": checksum(b"x"),
    }
    for bad in (
        {},  # storage key absent
        {"storage": None},
        {"storage": {}},
        {"storage": {"backend": "mem"}},  # url missing
        {"storage": {"url": "mem://abc"}},  # backend missing
        {"storage": {"backend": "", "url": "mem://abc"}},
        {"storage": {"backend": "mem", "url": ""}},
        {"storage": {"backend": 7, "url": "mem://abc"}},
    ):
        with pytest.raises(ValidationError):
            client.add_file({**base, **bad}, colony["colony_prv"])
    # the well-formed entry still lands
    ok = client.add_file(
        {**base, "storage": {"backend": "mem", "url": "mem://abc"}},
        colony["colony_prv"],
    )
    assert ok["revision"] == 1


def test_add_file_rejects_separator_names(colony, cfs):
    from repro.core.errors import ValidationError

    for name in ("..", ".", "a/b", "..\\evil"):
        with pytest.raises(ValidationError):
            cfs.upload_bytes("dev", "/names", name, b"x")


def test_sync_down_rejects_path_traversal(colony, cfs, tmp_path):
    """Seed bug: sync_down joined server-supplied names straight into
    localdir, so a row named ``../../escape`` (injected below the RPC
    validation, e.g. by a compromised replica) wrote outside the target
    directory."""
    from repro.core.errors import ValidationError

    evil = {
        "fileid": "f" * 32,
        "colonyname": "dev",
        "label": "/trav",
        "name": "../../escape.txt",
        "size": 4,
        "checksum": checksum(b"evil"),
        "storage": {"backend": "mem", "url": cfs.storage.put(b"evil")},
        "added": 1,
        "addedby": "test",
    }
    colony["server"].db.cfs_add_file(evil)
    dst = tmp_path / "jail" / "down"
    with pytest.raises(ValidationError):
        cfs.sync_down("dev", "/trav", str(dst))
    assert not (tmp_path / "escape.txt").exists()
    assert not (tmp_path / "jail" / "escape.txt").exists()


def test_materialize_snapshot_rejects_traversal_label(colony, cfs, tmp_path):
    from repro.core.errors import ValidationError

    cfs.upload_bytes("dev", "/trav2", "ok.txt", b"fine")
    snap = colony["client"].create_snapshot("dev", "/trav2", "s", colony["colony_prv"])
    evil = {
        "fileid": "e" * 32,
        "colonyname": "dev",
        "label": "/trav2/../..",  # traversal smuggled in the label
        "name": "pwn.txt",
        "size": 4,
        "checksum": checksum(b"evil"),
        "storage": {"backend": "mem", "url": cfs.storage.put(b"evil")},
        "added": 1,
        "addedby": "test",
    }
    colony["server"].db.cfs_add_file(evil)
    snap2 = colony["client"].create_snapshot("dev", "/trav2", "s2", colony["colony_prv"])
    out = tmp_path / "snapjail"
    # the pre-existing clean snapshot still materializes
    cfs.materialize_snapshot("dev", snap["snapshotid"], str(out))
    assert (out / "ok.txt").read_bytes() == b"fine"
    with pytest.raises(ValidationError):
        cfs.materialize_snapshot("dev", snap2["snapshotid"], str(out))
    assert not (tmp_path / "pwn.txt").exists()


def test_sync_down_crash_leaves_no_torn_file(colony, cfs, tmp_path, monkeypatch):
    """Seed bug: destinations were written in place, so a crash mid-write
    left a torn file under the final name — and a re-run saw it as
    already synced. Atomic tmp+replace must leave nothing behind."""
    import builtins

    cfs.upload_bytes("dev", "/atomic", "f.bin", b"A" * 4096)
    dst = tmp_path / "down"
    real_open = builtins.open

    def torn_open(path, mode="r", *a, **kw):
        if "w" in str(mode) and "b" in str(mode) and str(path).startswith(str(dst)):
            f = real_open(path, mode, *a, **kw)

            class Torn:
                def __enter__(self):
                    return self

                def __exit__(self, *exc):
                    f.close()
                    return False

                def write(self, data):
                    f.write(data[: len(data) // 2])
                    f.flush()
                    raise OSError("disk died mid-write")

            return Torn()
        return real_open(path, mode, *a, **kw)

    monkeypatch.setattr(builtins, "open", torn_open)
    with pytest.raises(OSError):
        cfs.sync_down("dev", "/atomic", str(dst))
    monkeypatch.undo()
    # no torn file under the final name, no tmp litter
    assert not (dst / "f.bin").exists()
    assert [p.name for p in dst.iterdir()] == []
    # a clean re-run converges
    cfs.sync_down("dev", "/atomic", str(dst))
    assert (dst / "f.bin").read_bytes() == b"A" * 4096


def test_storage_get_verifies_content_address(tmp_path):
    """Seed bug: backends returned whatever bytes sat under the key, so
    corruption at rest propagated silently; the content-address contract
    now raises ConflictError at the storage layer itself."""
    mem = MemoryStorage()
    url = mem.put(b"good")
    key = url.split("://")[1]
    mem._blobs[key] = b"bad"
    with pytest.raises(ConflictError):
        mem.get(url)

    loc = LocalStorage(str(tmp_path / "blobs"))
    url = loc.put(b"good")
    key = url.split("://")[1]
    (tmp_path / "blobs" / key).write_bytes(b"bad")
    with pytest.raises(ConflictError):
        loc.get(url)


def test_storage_quarantine_frees_key_keeps_bytes(tmp_path):
    mem = MemoryStorage()
    key = mem.put(b"suspect").split("://")[1]
    mem.quarantine(key)
    with pytest.raises(NotFoundError):
        mem.get(f"mem://{key}")
    assert mem._quarantined[key] == b"suspect"
    # re-put after quarantine works (slot freed)
    assert mem.put(b"suspect").endswith(key)

    loc = LocalStorage(str(tmp_path / "q"))
    key = loc.put(b"suspect").split("://")[1]
    loc.quarantine(key)
    with pytest.raises(NotFoundError):
        loc.get(f"local://{key}")
    assert loc.put(b"suspect").endswith(key)
    assert loc.get(f"local://{key}") == b"suspect"
