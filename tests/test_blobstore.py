"""Sharded, self-healing blob plane (STORAGE.md).

Covers the ShardedStorage contract (placement determinism, R-way puts
tolerating R−1 shard failures, get rotation, read-repair + quarantine,
scrub), the blob.* fault sites, the CFSClient retry integration, the
colonystats surfacing, and the executor fs sync directives end-to-end.
"""

import pytest

from repro.core import Colonies, InProcTransport, RetryPolicy
from repro.core.blobstore import VNODES, ShardedStorage, aggregate_stats
from repro.core.errors import (
    ConflictError,
    NotFoundError,
    TransportError,
    ValidationError,
)
from repro.core.fs import CFSClient, LocalStorage, MemoryStorage, checksum
from repro.runtime import faults
from repro.runtime.faults import FaultPlan, FaultRule

FAST_RETRY = RetryPolicy(base_s=0.001, cap_s=0.01, deadline_s=5.0, budget=8, seed=7)


def make_store(n=3, replicas=2):
    shards = [MemoryStorage() for _ in range(n)]
    return ShardedStorage(shards, replicas=replicas), shards


def dead_shard(idx):
    """A plan that makes shard ``idx`` unreachable for every blob op."""
    return FaultPlan(
        [
            FaultRule("blob.put", "crash", match={"shard": idx}, times=None),
            FaultRule("blob.get", "crash", match={"shard": idx}, times=None),
        ]
    )


# ---------------------------------------------------------------------------
# Placement (consistent-hash ring)
# ---------------------------------------------------------------------------


class TestPlacement:
    def test_replicas_are_distinct_and_deterministic(self):
        store, _ = make_store(5, replicas=3)
        for i in range(50):
            key = checksum(str(i).encode())
            reps = store.replicas_for(key)
            assert len(reps) == 3 and len(set(reps)) == 3
            assert reps == store.replicas_for(key)  # stable

    def test_identical_rings_across_instances(self):
        """Same shard count ⇒ same ring ⇒ same placement (no RNG, no clock)."""
        a, _ = make_store(4, replicas=2)
        b, _ = make_store(4, replicas=2)
        for i in range(20):
            key = checksum(str(i).encode())
            assert a.replicas_for(key) == b.replicas_for(key)

    def test_vnodes_spread_keys_across_all_shards(self):
        store, _ = make_store(3, replicas=1)
        owners = {store.replicas_for(checksum(str(i).encode()))[0] for i in range(200)}
        assert owners == {0, 1, 2}

    def test_replication_capped_at_shard_count(self):
        store, _ = make_store(2, replicas=5)
        assert store.replicas == 2

    def test_rejects_degenerate_configs(self):
        with pytest.raises(ValueError):
            ShardedStorage([], replicas=1)
        with pytest.raises(ValueError):
            ShardedStorage([MemoryStorage()], replicas=0)


# ---------------------------------------------------------------------------
# Put/get semantics
# ---------------------------------------------------------------------------


class TestPutGet:
    def test_put_writes_all_replicas(self):
        store, shards = make_store(3, replicas=2)
        url = store.put(b"hello")
        key = checksum(b"hello")
        assert url == f"shard://{key}"
        holders = [i for i, s in enumerate(shards) if key in s._blobs]
        assert sorted(holders) == sorted(store.replicas_for(key))
        assert store.get(url) == b"hello"
        assert store.replica_count(key) == 2

    def test_put_tolerates_r_minus_1_failures(self):
        store, shards = make_store(3, replicas=2)
        data = b"survives one dead shard"
        key = checksum(data)
        dead = store.replicas_for(key)[0]
        with faults.active(dead_shard(dead)):
            url = store.put(data)
        assert key not in shards[dead]._blobs  # the dead replica missed it
        assert store.get(url) == data
        assert store.stats()["put_failures"] == 1

    def test_put_with_zero_replicas_raises_transport_error(self):
        store, _ = make_store(3, replicas=2)
        plan = FaultPlan([FaultRule("blob.put", "crash", times=None)])
        with faults.active(plan), pytest.raises(TransportError):
            store.put(b"nowhere to land")
        assert store.stats()["put_failures"] == 2  # both replicas tried

    def test_get_rotates_past_missing_replica(self):
        store, shards = make_store(3, replicas=2)
        data = b"rotate me"
        key = checksum(data)
        url = store.put(data)
        first = store.replicas_for(key)[0]
        del shards[first]._blobs[key]
        assert store.get(url) == data
        assert store.stats()["missing"] == 1

    def test_get_rotates_past_unreachable_replica(self):
        store, _ = make_store(3, replicas=2)
        data = b"shard down"
        key = checksum(data)
        url = store.put(data)
        with faults.active(dead_shard(store.replicas_for(key)[0])):
            assert store.get(url) == data
        assert store.stats()["get_failures"] == 1

    def test_get_missing_everywhere_is_not_found(self):
        store, _ = make_store(3, replicas=2)
        with pytest.raises(NotFoundError):
            store.get("shard://" + "0" * 64)

    def test_get_all_replicas_unreachable_is_transport_error(self):
        """Transient absence must NOT read as NotFound — the caller's
        retry policy retries TransportError but trusts NotFoundError."""
        store, _ = make_store(3, replicas=2)
        url = store.put(b"temporarily dark")
        plan = FaultPlan([FaultRule("blob.get", "crash", times=None)])
        with faults.active(plan), pytest.raises(TransportError):
            store.get(url)
        assert store.get(url) == b"temporarily dark"  # back after the outage


# ---------------------------------------------------------------------------
# Read-repair, quarantine, scrub
# ---------------------------------------------------------------------------


class TestSelfHealing:
    def test_read_repair_rewrites_missing_replica(self):
        store, shards = make_store(3, replicas=2)
        data = b"heal me"
        key = checksum(data)
        url = store.put(data)
        first = store.replicas_for(key)[0]
        del shards[first]._blobs[key]
        assert store.replica_count(key) == 1
        store.get(url)  # observes the hole, repairs it
        assert store.replica_count(key) == 2
        assert key in shards[first]._blobs
        st = store.stats()
        assert st["repairs"] == 1 and st["per_shard"][first]["repairs"] == 1

    def test_read_repair_quarantines_corrupt_replica(self):
        store, shards = make_store(3, replicas=2)
        data = b"bitrot victim"
        key = checksum(data)
        url = store.put(data)
        first = store.replicas_for(key)[0]
        shards[first]._blobs[key] = b"bitrot"  # corrupt at rest
        assert store.get(url) == data  # healthy copy wins
        # the bad bytes were moved aside, not destroyed, then repaired
        assert shards[first]._quarantined[key] == b"bitrot"
        assert shards[first]._blobs[key] == data
        st = store.stats()
        assert st["corrupt"] == 1 and st["quarantined"] == 1 and st["repairs"] == 1
        assert store.quarantine_log == [(first, key)]

    def test_repair_failure_is_counted_not_fatal(self):
        store, shards = make_store(3, replicas=2)
        data = b"repair blocked"
        key = checksum(data)
        url = store.put(data)
        first, second = store.replicas_for(key)
        del shards[first]._blobs[key]
        # the broken replica's shard accepts gets but refuses the repair put
        plan = FaultPlan([FaultRule("blob.put", "crash", match={"shard": first}, times=None)])
        with faults.active(plan):
            assert store.get(url) == data
        st = store.stats()
        assert st["repair_failures"] == 1 and st["repairs"] == 0
        assert store.replica_count(key) == 1  # still degraded, still serving

    def test_scrub_restores_replication_after_shard_outage(self):
        """The revived-shard path: writes land while one shard is dark,
        scrub backfills every under-replicated key."""
        store, _ = make_store(3, replicas=2)
        urls = {}
        with faults.active(dead_shard(1)):
            for i in range(12):
                data = f"blob-{i}".encode()
                urls[store.put(data)] = data
        degraded = [u for u in urls if store.replica_count(u.split("://")[1]) < 2]
        assert degraded  # shard 1 is first-or-second replica for some keys
        report = store.scrub()  # shard 1 is back (plan uninstalled)
        assert report["lost"] == 0 and report["repaired"] == len(degraded)
        for url, data in urls.items():
            assert store.replica_count(url.split("://")[1]) == 2
            assert store.get(url) == data

    def test_scrub_counts_lost_keys(self):
        """Every replica corrupt ⇒ the key is listed but unhealable."""
        store, shards = make_store(3, replicas=2)
        key = checksum(b"doomed")
        store.put(b"doomed")
        for s in shards:
            if key in s._blobs:
                s._blobs[key] = b"rot"
        assert store.scrub()["lost"] == 1

    def test_keys_is_union_of_reachable_shards(self):
        store, _ = make_store(3, replicas=1)
        keys = {store.put(f"k{i}".encode()).split("://")[1] for i in range(9)}
        assert set(store.keys()) == keys


# ---------------------------------------------------------------------------
# Local-backend parity
# ---------------------------------------------------------------------------


class TestLocalShards:
    def test_roundtrip_and_repair_over_local_storage(self, tmp_path):
        shards = [LocalStorage(str(tmp_path / f"s{i}")) for i in range(3)]
        store = ShardedStorage(shards, replicas=2)
        data = b"bytes on disk"
        key = checksum(data)
        url = store.put(data)
        first = store.replicas_for(key)[0]
        # corrupt the on-disk copy behind the store's back
        (tmp_path / f"s{first}" / key).write_bytes(b"garbage")
        assert store.get(url) == data
        assert store.replica_count(key) == 2  # repaired in place
        # the quarantined copy survives with a dotted suffix (≠ a key)
        q = [p for p in (tmp_path / f"s{first}").iterdir() if ".quarantined-" in p.name]
        assert len(q) == 1 and q[0].read_bytes() == b"garbage"
        assert key in shards[first].keys() and q[0].name not in shards[first].keys()


# ---------------------------------------------------------------------------
# CFSClient retry integration
# ---------------------------------------------------------------------------


class TestCFSClientRetry:
    def test_upload_retries_through_total_outage(self, colony):
        store, _ = make_store(3, replicas=2)
        cfs = CFSClient(colony["client"], store, colony["colony_prv"], retry=FAST_RETRY)
        # every replica unreachable for the first 2 shard-puts: attempt 1
        # reaches zero replicas (TransportError), the retry succeeds.
        plan = FaultPlan([FaultRule("blob.put", "crash", times=2)])
        with faults.active(plan):
            meta = cfs.upload_bytes("dev", "/retry", "a.bin", b"eventually")
        assert plan.fired("blob.put") == 2
        assert cfs.download_bytes("dev", "/retry", "a.bin") == b"eventually"
        assert meta["storage"]["backend"] == "shard"

    def test_download_retries_through_total_outage(self, colony):
        store, _ = make_store(3, replicas=2)
        cfs = CFSClient(colony["client"], store, colony["colony_prv"], retry=FAST_RETRY)
        cfs.upload_bytes("dev", "/retry2", "b.bin", b"come back")
        plan = FaultPlan([FaultRule("blob.get", "crash", times=2)])
        with faults.active(plan):
            assert cfs.download_bytes("dev", "/retry2", "b.bin") == b"come back"
        assert plan.fired("blob.get") == 2

    def test_retry_budget_exhaustion_surfaces_transport_error(self, colony):
        store, _ = make_store(3, replicas=2)
        tight = RetryPolicy(base_s=0.001, cap_s=0.002, deadline_s=5.0, budget=2, seed=1)
        cfs = CFSClient(colony["client"], store, colony["colony_prv"], retry=tight)
        plan = FaultPlan([FaultRule("blob.put", "crash", times=None)])
        with faults.active(plan), pytest.raises(TransportError):
            cfs.upload_bytes("dev", "/retry3", "c.bin", b"never lands")

    def test_not_found_is_not_retried(self, colony):
        store, _ = make_store(3, replicas=2)
        cfs = CFSClient(colony["client"], store, colony["colony_prv"], retry=FAST_RETRY)
        meta = cfs.upload_bytes("dev", "/retry4", "d.bin", b"then gone")
        for s in store.shards:
            s._blobs.clear()
        before = store.stats()["gets"]
        with pytest.raises(NotFoundError):
            cfs.download_bytes("dev", "/retry4", "d.bin")
        # one rotation over the replicas, no retry rounds on a hard miss
        assert store.stats()["gets"] == before


# ---------------------------------------------------------------------------
# colonystats surfacing
# ---------------------------------------------------------------------------


class TestStatsSurfacing:
    def test_aggregate_stats_sums_live_stores(self):
        a, _ = make_store(3, replicas=2)
        b, _ = make_store(2, replicas=2)
        base = aggregate_stats()
        a.put(b"one")
        b.put(b"two")
        agg = aggregate_stats()
        assert agg["puts"] - base["puts"] == 4  # 2 replicas × 2 stores
        assert agg["stores"] >= 2

    def test_blob_counters_reach_colonystats_rpc(self, colony):
        store, _ = make_store(3, replicas=2)
        cfs = CFSClient(colony["client"], store, colony["colony_prv"], retry=FAST_RETRY)
        before = colony["client"].stats("dev", colony["colony_prv"])["blob"]
        cfs.upload_bytes("dev", "/statsblob", "s.bin", b"counted")
        after = colony["client"].stats("dev", colony["colony_prv"])["blob"]
        assert after["puts"] - before["puts"] == 2
        assert after["put_bytes"] - before["put_bytes"] == 2 * len(b"counted")


# ---------------------------------------------------------------------------
# Executor fs sync directives (end-to-end)
# ---------------------------------------------------------------------------


@pytest.fixture()
def sharded_cfs(colony):
    store, shards = make_store(3, replicas=2)
    cfs = CFSClient(colony["client"], store, colony["colony_prv"], retry=FAST_RETRY)
    return cfs, store, shards


class TestExecutorSyncDirectives:
    def _executor(self, colony, store, tmp_path, handler):
        from repro.runtime.jax_executor import JaxExecutorBase

        ex = JaxExecutorBase(
            Colonies(InProcTransport([colony["server"]], retry=FAST_RETRY)),
            "dev",
            "fs-worker",
            "fsw",
            storage=store,
            colony_prvkey=colony["colony_prv"],
            blob_retry=FAST_RETRY,
            workdir_root=str(tmp_path / "work"),
        )
        ex.register_function("consume", handler)
        return ex

    def _spec(self, fs):
        return {
            "conditions": {"colonyname": "dev", "executortype": "fsw"},
            "funcname": "consume",
            "maxexectime": 30,
            "fs": fs,
        }

    def test_snapshot_and_dirs_sync_roundtrip(self, colony, sharded_cfs, tmp_path):
        cfs, store, _ = sharded_cfs
        client = colony["client"]
        cfs.upload_bytes("dev", "/in", "data.txt", b"pinned input")
        snap = client.create_snapshot("dev", "/in", "s1", colony["colony_prv"])
        cfs.upload_bytes("dev", "/in", "data.txt", b"LATER revision")  # must not leak in

        def consume(ctx):
            import os as _os

            src = _os.path.join(ctx.workdir, "in", "data.txt")
            with open(src, "rb") as f:
                data = f.read()
            out = _os.path.join(ctx.workdir, "out")
            _os.makedirs(out, exist_ok=True)
            with open(_os.path.join(out, "result.txt"), "wb") as f:
                f.write(data.upper())
            return [len(data)]

        ex = self._executor(colony, store, tmp_path, consume)
        fs = {
            "mount": "/cfs",
            "snapshots": [{"snapshotid": snap["snapshotid"], "label": "/in", "dir": "/cfs/in"}],
            "dirs": [{"label": "/out", "dir": "/cfs/out", "upload": True}],
        }
        p = client.submit(self._spec(fs), colony["colony_prv"])
        assert ex.step(timeout=2.0)
        done = client.get_process(p["processid"], colony["colony_prv"])
        assert done["state"] == "successful", done.get("errors")
        assert done["out"] == [len(b"pinned input")]
        # the upload directive published the handler's output as CFS files
        assert cfs.download_bytes("dev", "/out", "result.txt") == b"PINNED INPUT"

    def test_sync_survives_one_dead_shard(self, colony, sharded_cfs, tmp_path):
        """The ISSUE gate: executor sync must ride out transient shard
        loss via the CFSClient retry policy + replica rotation."""
        cfs, store, _ = sharded_cfs
        client = colony["client"]
        cfs.upload_bytes("dev", "/in2", "a.bin", b"alpha")
        cfs.upload_bytes("dev", "/in2", "b.bin", b"beta")
        seen = {}

        def consume(ctx):
            import os as _os

            d = _os.path.join(ctx.workdir, "in2")
            for fn in sorted(_os.listdir(d)):
                with open(_os.path.join(d, fn), "rb") as f:
                    seen[fn] = f.read()
            return [sorted(seen)]

        ex = self._executor(colony, store, tmp_path, consume)
        fs = {"mount": "/cfs", "dirs": [{"label": "/in2", "dir": "/cfs/in2", "upload": False}]}
        p = client.submit(self._spec(fs), colony["colony_prv"])
        with faults.active(dead_shard(0)):
            assert ex.step(timeout=2.0)
        done = client.get_process(p["processid"], colony["colony_prv"])
        assert done["state"] == "successful", done.get("errors")
        assert seen == {"a.bin": b"alpha", "b.bin": b"beta"}

    def test_malicious_directive_dir_fails_the_process(self, colony, sharded_cfs, tmp_path):
        cfs, store, _ = sharded_cfs
        client = colony["client"]
        cfs.upload_bytes("dev", "/in3", "x.bin", b"x")
        ex = self._executor(colony, store, tmp_path, lambda ctx: [])
        fs = {"mount": "/cfs", "dirs": [{"label": "/in3", "dir": "/cfs/../../etc", "upload": False}]}
        p = client.submit(self._spec(fs), colony["colony_prv"])
        assert ex.step(timeout=2.0)
        done = client.get_process(p["processid"], colony["colony_prv"])
        assert done["state"] == "failed"
        assert any("unsafe fs directive" in e for e in done["errors"])
        # nothing escaped the sandbox root
        escaped = tmp_path.parent / "etc"
        assert not escaped.exists()
