"""End-to-end RPC fault tolerance (ROBUSTNESS.md).

The fault matrix the ISSUE demands: {drop, duplicate, reset,
crash-after-commit} x {submit, close, addchild, assign}, on both
database backends, asserting exactly-once effects — one process per
submit, one terminal transition per close — plus units for the fault
plane, retry policy, msgid signature coverage, the executor's
pending-close journal, run_forever backoff, wait() deadline honoring,
and the failsafe error counter.
"""

import threading
import time

import pytest

from repro.core import (
    Colonies,
    InProcTransport,
    MemoryDatabase,
    RetryPolicy,
    SqliteDatabase,
    TransportError,
)
from repro.core.client import _ERROR_TYPES
from repro.core.cluster import standalone_server
from repro.core.crypto import Crypto
from repro.core.errors import ColoniesError, TimeoutError_
from repro.core.executor import ExecutorBase
from repro.core.process import new_id
from repro.core.retry import send_with_retry
from repro.core.security import sign_envelope
from repro.runtime import faults
from repro.runtime.faults import FaultInjected, FaultPlan, FaultRule

SPEC = {"funcname": "echo", "conditions": {"colonyname": "dev", "executortype": "cli"}}

# A tight policy so injected faults retry in milliseconds, not seconds.
FAST_RETRY = RetryPolicy(base_s=0.001, cap_s=0.01, deadline_s=5.0, budget=8, seed=7)


def _rig(db, server_prv=None):
    """Standalone server + signed client/executor keys on the given db."""
    server_prv = server_prv or Crypto.prvkey()
    colony_prv = Crypto.prvkey()
    exec_prv = Crypto.prvkey()
    srv = standalone_server(Crypto.id(server_prv), db)
    client = Colonies(InProcTransport([srv], retry=FAST_RETRY))
    client.add_colony("dev", Crypto.id(colony_prv), server_prv)
    client.add_executor(
        {
            "executorname": "e1",
            "executorid": Crypto.id(exec_prv),
            "colonyname": "dev",
            "executortype": "cli",
        },
        colony_prv,
    )
    client.approve_executor(Crypto.id(exec_prv), colony_prv)
    return srv, client, exec_prv


@pytest.fixture(params=["memory", "sqlite"])
def rig(request):
    db = MemoryDatabase() if request.param == "memory" else SqliteDatabase()
    srv, client, exec_prv = _rig(db)
    yield {"server": srv, "client": client, "prvkey": exec_prv}
    srv.stop()


# ---------------------------------------------------------------------------
# Fault plane units
# ---------------------------------------------------------------------------


class TestFaultPlane:
    def test_zero_cost_when_unset(self):
        assert faults.hit("transport.send") is None

    def test_scheduling_after_times(self):
        plan = FaultPlan([FaultRule("db.commit", "drop", after=1, times=2)])
        with faults.active(plan):
            faults.hit("db.commit")  # skipped (after=1)
            for _ in range(2):
                with pytest.raises(FaultInjected):
                    faults.hit("db.commit")
            faults.hit("db.commit")  # times exhausted
        assert plan.fired("db.commit") == 2

    def test_payloadtype_filter_and_duplicate(self):
        plan = FaultPlan(
            [FaultRule("transport.send", "duplicate", payloadtype="close")]
        )
        with faults.active(plan):
            assert faults.hit("transport.send", payloadtype="submitfunctionspec") is None
            assert faults.hit("transport.send", payloadtype="close") == "duplicate"

    def test_seeded_probability_deterministic(self):
        def fire_pattern(seed):
            plan = FaultPlan(
                [FaultRule("raft.tick", "delay", delay_s=0, prob=0.5, times=None)],
                seed=seed,
            )
            with faults.active(plan):
                for _ in range(32):
                    faults.hit("raft.tick")
            return [a for _s, a, _c in plan.log]

        assert fire_pattern(3) == fire_pattern(3)
        assert fire_pattern(3) != fire_pattern(4)

    def test_unknown_site_rejected(self):
        with pytest.raises(ValueError):
            FaultRule("nonexistent.site", "drop")
        with pytest.raises(ValueError):
            FaultRule("db.commit", "explode")

    def test_install_is_exclusive(self):
        plan = FaultPlan()
        with faults.active(plan):
            with pytest.raises(RuntimeError):
                faults.install(FaultPlan())
        assert faults.current() is None


class TestRetryPolicy:
    def test_retries_until_success(self):
        calls = []

        def attempt():
            calls.append(1)
            if len(calls) < 3:
                return {"error": "transport: down", "status": 503}
            return {"result": "ok"}

        resp = send_with_retry(attempt, FAST_RETRY)
        assert resp == {"result": "ok"}
        assert len(calls) == 3

    def test_budget_exhaustion_returns_last_error(self):
        calls = []

        def attempt():
            calls.append(1)
            return {"error": "transport: down", "status": 503}

        resp = send_with_retry(attempt, RetryPolicy(base_s=0.001, budget=3, seed=1))
        assert resp["status"] == 503
        assert len(calls) == 3

    def test_application_errors_not_retried(self):
        calls = []

        def attempt():
            calls.append(1)
            return {"error": "nope", "status": 403}

        assert send_with_retry(attempt, FAST_RETRY)["status"] == 403
        assert len(calls) == 1

    def test_delays_are_capped_and_jittered(self):
        it = RetryPolicy(base_s=0.01, cap_s=0.05, seed=9).delays()
        ds = [it.next_delay() for _ in range(50)]
        assert all(0.01 <= d <= 0.05 for d in ds)
        assert len(set(ds)) > 1  # decorrelated, not a fixed ladder

    def test_503_maps_to_transport_error(self):
        assert _ERROR_TYPES[503] is TransportError

    def test_no_policy_means_single_attempt(self):
        calls = []

        def attempt():
            calls.append(1)
            return {"error": "transport: down", "status": 503}

        send_with_retry(attempt, None)
        assert len(calls) == 1


# ---------------------------------------------------------------------------
# msgid protocol
# ---------------------------------------------------------------------------


class TestMsgidProtocol:
    def test_msgid_is_signature_covered(self, rig):
        srv = rig["server"]
        env = sign_envelope(
            "submitfunctionspec", {"spec": SPEC}, rig["prvkey"], msgid=new_id()
        )
        tampered = dict(env)
        tampered["msgid"] = new_id()
        resp = srv.handle(tampered)
        # Recovered identity changes under tamper -> zero-trust rejection.
        assert resp.get("status") == 403

    def test_replay_returns_recorded_reply(self, rig):
        srv = rig["server"]
        env = sign_envelope(
            "submitfunctionspec", {"spec": SPEC}, rig["prvkey"], msgid=new_id()
        )
        r1 = srv.handle(env)
        r2 = srv.handle(env)
        assert r2.get("replayed") is True
        assert r1["result"]["processid"] == r2["result"]["processid"]
        procs = rig["client"].get_processes("dev", rig["prvkey"])
        assert len(procs) == 1

    def test_unkeyed_envelope_still_works(self, rig):
        # Back-compat: old clients that stamp no msgid sign the old string.
        env = sign_envelope("colonystats", {"colonyname": "dev"}, rig["prvkey"])
        assert "msgid" not in env
        assert "result" in rig["server"].handle(env)

    def test_dedup_records_are_per_identity(self, rig):
        # Same msgid under a different signer is a different operation:
        # the dedup key is identity-scoped, so an attacker replaying a
        # captured msgid with their own key cannot read the victim's reply.
        srv = rig["server"]
        m = new_id()
        e1 = sign_envelope("submitfunctionspec", {"spec": SPEC}, rig["prvkey"], msgid=m)
        assert "result" in srv.handle(e1)
        prv2 = Crypto.prvkey()  # not a colony member
        e2 = sign_envelope("submitfunctionspec", {"spec": SPEC}, prv2, msgid=m)
        resp = srv.handle(e2)
        assert resp.get("replayed") is None  # not a replay — freshly authorized
        assert resp.get("status") == 403


# ---------------------------------------------------------------------------
# The fault matrix: {drop, duplicate, reset, crash-after-commit} x
# {submit, close, addchild, assign} — exactly-once effects on both backends.
# ---------------------------------------------------------------------------

FAULTS = {
    # request lost before the server saw it: effect happens on the retry
    "drop": FaultRule("transport.send", "drop"),
    # delivered twice by the transport: second delivery must replay
    "duplicate": FaultRule("transport.send", "duplicate"),
    # reply lost after the server committed: retry must replay
    "reset": FaultRule("transport.recv", "reset"),
    # server dies after commit+dedup-record, before replying
    "crash": FaultRule("server.post_commit", "crash"),
}


def _submit_running(client, prvkey):
    """Submit + assign one process so close/addchild have a target."""
    p = client.submit(SPEC, prvkey)
    a = client.assign("dev", 2.0, prvkey)
    assert a["processid"] == p["processid"]
    return p["processid"]


@pytest.mark.parametrize("fault", sorted(FAULTS))
class TestFaultMatrix:
    def _plan(self, fault, ptype):
        r = FAULTS[fault]
        return FaultPlan([FaultRule(r.site, r.action, payloadtype=ptype)])

    def test_submit_exactly_once(self, rig, fault):
        client, prvkey = rig["client"], rig["prvkey"]
        with faults.active(self._plan(fault, "submitfunctionspec")) as plan:
            p = client.submit(SPEC, prvkey)
        assert plan.fired() == 1
        procs = client.get_processes("dev", prvkey)
        assert [q["processid"] for q in procs] == [p["processid"]]

    def test_close_exactly_once(self, rig, fault):
        client, prvkey = rig["client"], rig["prvkey"]
        pid = _submit_running(client, prvkey)
        with faults.active(self._plan(fault, "close")) as plan:
            closed = client.close(pid, ["out"], prvkey)
        assert plan.fired() == 1
        assert closed["state"] == "successful"
        final = client.get_process(pid, prvkey)
        assert final["state"] == "successful"
        assert final["out"] == ["out"]
        stats = client.stats("dev", prvkey)
        assert stats["successful"] == 1 and stats["failed"] == 0

    def test_addchild_exactly_once(self, rig, fault):
        client, prvkey = rig["client"], rig["prvkey"]
        pid = _submit_running(client, prvkey)
        with faults.active(self._plan(fault, "addchild")) as plan:
            child = client.add_child(pid, SPEC, prvkey)
        assert plan.fired() == 1
        parent = client.get_process(pid, prvkey)
        assert parent["children"] == [child["processid"]]
        procs = client.get_processes("dev", prvkey)
        assert len(procs) == 2

    def test_assign_exactly_once(self, rig, fault):
        client, prvkey = rig["client"], rig["prvkey"]
        p = client.submit(SPEC, prvkey)
        with faults.active(self._plan(fault, "assign")) as plan:
            a = client.assign("dev", 2.0, prvkey)
        assert plan.fired() == 1
        assert a["processid"] == p["processid"]
        # The single process is RUNNING and assigned to us exactly once.
        stats = client.stats("dev", prvkey)
        assert stats["running"] == 1 and stats["waiting"] == 0


class TestCrashBeforeCommit:
    """pre-dispatch and db.commit faults: no effect happened, the retry
    must EXECUTE (not replay) and still end with exactly one process."""

    @pytest.mark.parametrize("site", ["server.pre_dispatch", "db.commit"])
    def test_submit(self, rig, site):
        client, prvkey = rig["client"], rig["prvkey"]
        plan = FaultPlan([FaultRule(site, "crash", times=1)])
        with faults.active(plan):
            p = client.submit(SPEC, prvkey)
        assert plan.fired() == 1
        procs = client.get_processes("dev", prvkey)
        assert [q["processid"] for q in procs] == [p["processid"]]


# ---------------------------------------------------------------------------
# Executor hardening
# ---------------------------------------------------------------------------


class TestPendingCloseJournal:
    def test_close_journaled_and_flushed(self):
        server_prv = Crypto.prvkey()
        colony_prv = Crypto.prvkey()
        srv = standalone_server(Crypto.id(server_prv))
        client = Colonies(InProcTransport([srv]))  # NO transport retry
        client.add_colony("dev", Crypto.id(colony_prv), server_prv)
        ex = ExecutorBase(client, "dev", "worker", "cli", colony_prvkey=colony_prv)
        ex.register_function("echo", lambda ctx, *a: list(a))
        client.submit(
            {"funcname": "echo", "args": [1], "conditions": {"colonyname": "dev", "executortype": "cli"}},
            ex.prvkey,
        )
        # Every close attempt dies at the transport until the plan drains.
        plan = FaultPlan(
            [FaultRule("transport.send", "drop", payloadtype="close", times=2)]
        )
        with faults.active(plan):
            ran = ex.step(2.0)
        assert ran
        assert ex.processed == 0  # not yet delivered
        assert ex.flush_pending_closes(force=True) == 0
        assert ex.processed == 1
        p = client.get_processes("dev", ex.prvkey, state="successful")
        assert len(p) == 1 and p[0]["out"] == [1]

    def test_journal_reuses_msgid_no_conflict(self):
        """First close COMMITS but the reply is lost; the journaled retry
        must replay via dedup instead of raising ConflictError."""
        server_prv = Crypto.prvkey()
        colony_prv = Crypto.prvkey()
        srv = standalone_server(Crypto.id(server_prv))
        client = Colonies(InProcTransport([srv]))
        client.add_colony("dev", Crypto.id(colony_prv), server_prv)
        ex = ExecutorBase(client, "dev", "worker", "cli", colony_prvkey=colony_prv)
        ex.register_function("echo", lambda ctx, *a: list(a))
        client.submit(
            {"funcname": "echo", "args": [2], "conditions": {"colonyname": "dev", "executortype": "cli"}},
            ex.prvkey,
        )
        plan = FaultPlan(
            [FaultRule("transport.recv", "reset", payloadtype="close", times=1)]
        )
        with faults.active(plan):
            ex.step(2.0)
        assert ex.flush_pending_closes(force=True) == 0
        assert ex.processed == 1 and ex.failed == 0
        stats = client.stats("dev", ex.prvkey)
        assert stats["successful"] == 1

    def test_stop_drains_journal(self):
        server_prv = Crypto.prvkey()
        colony_prv = Crypto.prvkey()
        srv = standalone_server(Crypto.id(server_prv))
        client = Colonies(InProcTransport([srv]))
        client.add_colony("dev", Crypto.id(colony_prv), server_prv)
        ex = ExecutorBase(client, "dev", "worker", "cli", colony_prvkey=colony_prv)
        ex.register_function("echo", lambda ctx, *a: list(a))
        client.submit(
            {"funcname": "echo", "args": [3], "conditions": {"colonyname": "dev", "executortype": "cli"}},
            ex.prvkey,
        )
        plan = FaultPlan(
            [FaultRule("transport.send", "drop", payloadtype="close", times=3)]
        )
        with faults.active(plan):
            ex.step(2.0)
            assert ex.processed == 0
            ex.stop()  # graceful drain delivers the journaled close
        assert ex.processed == 1
        assert client.stats("dev", ex.prvkey)["successful"] == 1


class _CountingDownTransport:
    """Permanently down: every send fails retryably, counting calls."""

    def __init__(self):
        self.calls = 0

    def send(self, envelope, timeout=None):
        self.calls += 1
        return {"error": "transport: connection refused", "status": 503}


class TestRunForeverBackoff:
    def test_backoff_reduces_call_rate(self):
        transport = _CountingDownTransport()
        client = Colonies(transport, insecure=True)
        ex = ExecutorBase(client, "dev", "worker", "cli")
        ex.start(poll_timeout=0.01)
        time.sleep(0.6)
        ex.stop()
        # The seed's fixed 0.05s wait would allow ~12 calls in 0.6s; the
        # capped exponential backoff (0.05 * 2^n, jittered) must stay well
        # under that.
        assert 1 <= transport.calls <= 7, transport.calls

    def test_backoff_is_capped(self):
        transport = _CountingDownTransport()
        client = Colonies(transport, insecure=True)
        ex = ExecutorBase(client, "dev", "worker", "cli")
        assert ex._error_backoff(1) <= 0.05
        assert ex._error_backoff(100) <= 2.0  # PENDING_BACKOFF_CAP_S


# ---------------------------------------------------------------------------
# Satellites: wait() deadline, failsafe_errors counter
# ---------------------------------------------------------------------------


class _HangingTransport:
    """Honors the per-request timeout arg; hangs up to it, then 503s."""

    def __init__(self):
        self.timeouts = []

    def send(self, envelope, timeout=90.0):
        self.timeouts.append(timeout)
        time.sleep(min(timeout, 0.05))
        return {"error": "transport: read timed out", "status": 503}


class TestWaitDeadline:
    def test_wait_honors_deadline_against_hung_transport(self):
        client = Colonies(_HangingTransport(), insecure=True)
        t0 = time.monotonic()
        with pytest.raises(TimeoutError_) as ei:
            client.wait("pid", Crypto.prvkey(), timeout=0.3, poll=0.01)
        elapsed = time.monotonic() - t0
        assert elapsed < 2.0  # seed behaviour: 90s hang per poll
        # surfaces the last non-timeout error, not a generic message
        assert "read timed out" in str(ei.value)

    def test_wait_passes_remaining_budget_as_poll_timeout(self):
        tr = _HangingTransport()
        client = Colonies(tr, insecure=True)
        with pytest.raises(TimeoutError_):
            client.wait("pid", Crypto.prvkey(), timeout=0.2, poll=0.01)
        assert tr.timeouts and all(t <= 0.21 for t in tr.timeouts)

    def test_wait_still_returns_terminal_process(self, rig):
        client, prvkey = rig["client"], rig["prvkey"]
        pid = _submit_running(client, prvkey)
        client.close(pid, [], prvkey)
        assert client.wait(pid, prvkey, timeout=2.0)["state"] == "successful"


class TestFailsafeErrorCounter:
    def test_counter_surfaces_via_stats(self, rig):
        srv, client, prvkey = rig["server"], rig["client"], rig["prvkey"]

        class _Boom:
            def handlers(self):
                return {}

            def tick(self):
                raise RuntimeError("tick exploded")

        srv.extensions.append(_Boom())
        srv.start_background(failsafe_interval=0.01)
        deadline = time.monotonic() + 2.0
        while time.monotonic() < deadline:
            if client.stats("dev", prvkey)["failsafe_errors"] >= 2:
                break
            time.sleep(0.02)
        stats = client.stats("dev", prvkey)
        assert stats["failsafe_errors"] >= 2  # loop survived and counted


# ---------------------------------------------------------------------------
# Satellite: dedup durability across a broker restart (sqlite backend)
# ---------------------------------------------------------------------------


class TestDedupRestartDurability:
    def test_sqlite_dedup_survives_restart(self, tmp_path):
        """The rpc_dedup row is committed with the op, so a keyed msgid
        replayed against a RESTARTED broker (fresh process, same
        database file) must return the recorded reply — the classic
        crash-after-commit-before-reply window crossed with a reboot."""
        path = str(tmp_path / "colonies.db")
        server_prv = Crypto.prvkey()
        srv, client, exec_prv = _rig(SqliteDatabase(path), server_prv=server_prv)
        env = sign_envelope(
            "submitfunctionspec", {"spec": SPEC}, exec_prv, msgid=new_id()
        )
        r1 = srv.handle(env)
        assert "result" in r1 and r1.get("replayed") is None
        srv.stop()

        # Reboot: same identity, same database file, empty in-memory state.
        srv2 = standalone_server(Crypto.id(server_prv), SqliteDatabase(path))
        try:
            r2 = srv2.handle(env)  # byte-identical replay of the envelope
            assert r2.get("replayed") is True
            assert r2["result"]["processid"] == r1["result"]["processid"]
            client2 = Colonies(InProcTransport([srv2]))
            procs = client2.get_processes("dev", exec_prv)
            assert [p["processid"] for p in procs] == [r1["result"]["processid"]]
        finally:
            srv2.stop()
