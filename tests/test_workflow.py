"""Workflow DAGs (paper §3.4.2, Tables 3-4, Fig. 4) + dynamic children."""

import pytest

from repro.core import ExecutorBase, FunctionSpec, WorkflowSpec
from repro.core.errors import ValidationError


def node(name, func, deps, etype="worker", **kw):
    d = {
        "nodename": name,
        "funcname": func,
        "conditions": {"executortype": etype, "dependencies": deps},
    }
    d.update(kw)
    return d


def make_worker(colony, handlers, name="wf-w", etype="worker"):
    ex = ExecutorBase(colony["client"], "dev", name, etype, colony_prvkey=colony["colony_prv"])
    for fname, fn in handlers.items():
        ex.register_function(fname, fn)
    return ex


def run_until_done(colony, ex_list, workflowid_proc, timeout=10.0):
    import time

    client = colony["client"]
    deadline = time.time() + timeout
    while time.time() < deadline:
        for ex in ex_list:
            ex.step(0.1)
        p = client.get_process(workflowid_proc, colony["colony_prv"])
        if p["state"] in ("successful", "failed"):
            return p
    raise AssertionError("workflow did not finish")


def test_diamond_dataflow_tables_1_to_4(colony):
    """The paper's worked example: gen_nums -> square x2 -> sum == 13."""
    client = colony["client"]
    handlers = {
        "gen_nums": lambda ctx: [2, 3],
        "square": lambda ctx: [ctx.inputs[0] ** 2],
        "sum": lambda ctx: [sum(ctx.inputs)],
    }
    ex = make_worker(colony, handlers, name="wf-diamond")
    # square nodes each consume one parent output index? The paper's F2/F3
    # each square one value; here t2 squares inputs[0] of its own parent slice.
    wf = WorkflowSpec.from_dict({
        "colonyname": "dev",
        "functionspecs": [
            node("t1", "gen_nums", []),
            node("t2", "square", ["t1"]),
            node("t3", "square3", ["t1"]),
            node("t4", "sum", ["t2", "t3"]),
        ],
    })
    ex.register_function("square3", lambda ctx: [ctx.inputs[1] ** 2])
    r = client.submit_workflow(wf, colony["colony_prv"])
    last = r["processes"][-1]["processid"]
    done = run_until_done(colony, [ex], last)
    assert done["state"] == "successful"
    assert done["out"] == [13]  # 2^2 + 3^2
    assert done["in"] == [4, 9]  # Table 4 dataflow


def test_parallel_branches_run_on_different_executors(colony):
    """Fig. 4: after t1 closes, t2/t3 are assignable simultaneously."""
    client = colony["client"]
    seen = []
    h = {
        "a": lambda ctx: seen.append("a") or ["a"],
        "b": lambda ctx: seen.append("b") or ["b"],
        "c": lambda ctx: seen.append("c") or ["c"],
    }
    e1 = make_worker(colony, h, name="wf-p1")
    e2 = make_worker(colony, h, name="wf-p2")
    wf = WorkflowSpec.from_dict({
        "colonyname": "dev",
        "functionspecs": [
            node("t1", "a", []),
            node("t2", "b", ["t1"]),
            node("t3", "c", ["t1"]),
        ],
    })
    r = client.submit_workflow(wf, colony["colony_prv"])
    procs = {p["spec"]["nodename"]: p for p in r["processes"]}
    # children are blocked until the parent closes
    assert procs["t2"]["waitforparents"] and procs["t3"]["waitforparents"]
    run_until_done(colony, [e1, e2], procs["t2"]["processid"])
    run_until_done(colony, [e1, e2], procs["t3"]["processid"])
    assert set(seen) == {"a", "b", "c"}


def test_failed_parent_fails_descendants(colony):
    client = colony["client"]
    h = {"boom": lambda ctx: (_ for _ in ()).throw(RuntimeError("boom")),
         "never": lambda ctx: ["never"]}
    ex = make_worker(colony, h, name="wf-fail")
    wf = WorkflowSpec.from_dict({
        "colonyname": "dev",
        "functionspecs": [
            node("t1", "boom", []),
            node("t2", "never", ["t1"]),
            node("t3", "never", ["t2"]),
        ],
    })
    r = client.submit_workflow(wf, colony["colony_prv"])
    procs = {p["spec"]["nodename"]: p for p in r["processes"]}
    done = run_until_done(colony, [ex], procs["t3"]["processid"])
    assert done["state"] == "failed"
    t2 = client.get_process(procs["t2"]["processid"], colony["colony_prv"])
    assert t2["state"] == "failed"


def test_dynamic_children_mapreduce(colony):
    """Paper §3.4.2: the assigned executor extends the DAG on the fly."""
    client = colony["client"]

    def mapper(ctx, n):
        for i in range(n):
            ctx.add_child(
                {
                    "nodename": f"chunk-{i}",
                    "funcname": "process_chunk",
                    "args": [i],
                    "conditions": {"executortype": "worker"},
                },
            )
        return [n]

    h = {"map": mapper, "process_chunk": lambda ctx, i: [i * 10]}
    ex = make_worker(colony, h, name="wf-mr")
    p = client.submit(
        FunctionSpec.from_dict({
            "conditions": {"colonyname": "dev", "executortype": "worker"},
            "funcname": "map",
            "args": [3],
        }),
        colony["colony_prv"],
    )
    for _ in range(6):
        ex.step(0.3)
    parent = client.get_process(p["processid"], colony["colony_prv"])
    assert parent["state"] == "successful" and len(parent["children"]) == 3
    outs = []
    for cid in parent["children"]:
        c = client.get_process(cid, colony["colony_prv"])
        assert c["state"] == "successful"
        outs += c["out"]
    assert sorted(outs) == [0, 10, 20]


def test_add_child_close_race_keeps_dag_edge(colony):
    """A close interleaving inside _h_add_child's check→append window must
    not strand the child: the handler has to take the colony lock and
    CAS-revalidate, so the close either waits for the edge or conflicts.

    Deterministic interleave: pause add_child at its first db write (the
    child insert), let a concurrent close(parent) run, then resume. On
    the unlocked seed code the close slips into the window, closes the
    parent without seeing the child, and the waitforparent child is never
    released."""
    import threading

    client, srv = colony["client"], colony["server"]
    ex = ExecutorBase(client, "dev", "race-w", "worker",
                      colony_prvkey=colony["colony_prv"])
    parent = client.submit(
        FunctionSpec.from_dict({
            "conditions": {"colonyname": "dev", "executortype": "worker"},
            "funcname": "map", "maxexectime": 300,
        }),
        colony["colony_prv"],
    )
    assigned = client.assign("dev", 2.0, ex.prvkey)
    assert assigned["processid"] == parent["processid"]

    db = srv.db
    real_add = db.add_process
    in_window, resume = threading.Event(), threading.Event()
    fired = []

    def paused_add(proc):
        if not fired and proc.processid != parent["processid"]:
            fired.append(True)
            in_window.set()
            resume.wait(2.0)
        real_add(proc)

    db.add_process = paused_add
    try:
        t_child = threading.Thread(target=client.add_child, args=(
            parent["processid"],
            {"conditions": {"executortype": "worker"}, "funcname": "child"},
            ex.prvkey, True))
        t_close = threading.Thread(
            target=lambda: client.close(parent["processid"], [1], ex.prvkey))
        t_child.start()
        assert in_window.wait(2.0)
        t_close.start()
        t_close.join(0.3)  # on seed code the close completes inside the window
        resume.set()
        t_child.join(3.0)
        t_close.join(3.0)
    finally:
        db.add_process = real_add

    p = client.get_process(parent["processid"], colony["colony_prv"])
    assert p["state"] == "successful" and len(p["children"]) == 1
    child = client.get_process(p["children"][0], colony["colony_prv"])
    # the close saw the edge and released the child (lost-edge bug: stays True)
    assert not child["waitforparents"]


def test_workflow_state_empty():
    """An empty process list is vacuously complete, not forever 'waiting'."""
    from repro.core.workflow import workflow_state

    assert workflow_state([]) == "successful"


def test_workflow_validation():
    with pytest.raises(ValidationError):  # unknown dependency
        WorkflowSpec.from_dict(
            {"functionspecs": [node("a", "f", ["ghost"])]}
        ).validate()
    with pytest.raises(ValidationError):  # cycle
        WorkflowSpec.from_dict(
            {"functionspecs": [node("a", "f", ["b"]), node("b", "f", ["a"])]}
        ).validate()
    with pytest.raises(ValidationError):  # duplicate node names
        WorkflowSpec.from_dict(
            {"functionspecs": [node("a", "f", []), node("a", "g", [])]}
        ).validate()


def test_listing6_json_format():
    """The paper's Listing 6 workflow JSON parses as-is (bare list)."""
    js = """[
      {"nodename": "task_a", "funcname": "echo",
       "conditions": {"executortype": "t1", "dependencies": []}},
      {"nodename": "task_b", "funcname": "echo",
       "conditions": {"executortype": "t2", "dependencies": ["task_a"]}},
      {"nodename": "task_c", "funcname": "echo",
       "conditions": {"executortype": "t3", "dependencies": ["task_a"]}},
      {"nodename": "task_d", "funcname": "echo",
       "conditions": {"executortype": "t4", "dependencies": ["task_b", "task_c"]}}
    ]"""
    wf = WorkflowSpec.from_json(js)
    assert len(wf.specs) == 4
    wf.validate()
