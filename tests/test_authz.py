"""Zero-trust authorization analysis (SECURITY.md).

Four layers under test (src/repro/analysis):
  * authlint catches *seeded* broken handlers, one per AUT rule, and the
    repo itself lints clean with zero suppressions;
  * the runtime auth-fact contracts (REPRO_AUTH_CHECK=1) pass on the real
    RPC surface and catch a deliberately bypassing handler;
  * the generated permission matrix in SECURITY.md matches the code;
  * the satellite planes: the first-class users table (both backends,
    listusers RPC, kv migration) and the hardened unverified-envelope
    opt-in.
"""

import os
import textwrap

import pytest

from repro.analysis import authtrack
from repro.analysis.authlint import lint_source
from repro.analysis.authlint import run as authlint_run
from repro.analysis.authtrack import ANY_COLONY, AuthContractError, requires_auth
from repro.core import (
    Colonies,
    Crypto,
    ExecutorBase,
    FunctionSpec,
    InProcTransport,
    MemoryDatabase,
    SqliteDatabase,
)
from repro.core.cluster import standalone_server
from repro.core.errors import AuthError
from repro.core.security import open_envelope, sign_envelope

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _rules(src):
    return [v.rule for v in lint_source(textwrap.dedent(src), "fixture.py")]


# ---------------------------------------------------------------------------
# Seeded-violation proofs: every AUT rule actually fires
# ---------------------------------------------------------------------------


def test_authlint_catches_missing_auth():
    """AUT001: handler touches the db, never establishes any auth fact."""
    rules = _rules(
        """
        class S:
            def _h_peek(self, identity, payload):
                return self.db.kv_get("misc", payload["key"])
        """
    )
    assert rules == ["AUT001"]


def test_authlint_catches_missing_auth_interprocedurally():
    """AUT001 through a helper: the db touch hides one call deep."""
    rules = _rules(
        """
        class S:
            def _lookup(self, key):
                return self.db.kv_get("misc", key)

            def _h_peek(self, identity, payload):
                return self._lookup(payload["key"])
        """
    )
    assert "AUT001" in rules


def test_authlint_catches_confused_deputy():
    """AUT002: membership verified for one colony, db acts on another."""
    rules = _rules(
        """
        class S:
            def _h_swap(self, identity, payload):
                self._require_member(identity, payload["colonyname"])
                return self.db.list_executors(payload["other"])
        """
    )
    assert "AUT002" in rules


def test_authlint_catches_unverified_envelope():
    """AUT003: both verify=False and verify_signatures=False literals."""
    rules = _rules(
        """
        from repro.core.security import open_envelope
        from repro.core.server import ColoniesServer

        identity, ptype, payload = open_envelope(env, verify=False)
        srv = ColoniesServer("sid", verify_signatures=False)
        """
    )
    assert rules == ["AUT003", "AUT003"]


def test_authlint_catches_fetch_before_auth():
    """AUT004: a listing (not an id-keyed fetch) precedes the auth fact."""
    rules = _rules(
        """
        class S:
            def _h_eager(self, identity, payload):
                rows = self.db.list_processes(payload["colonyname"], "waiting", 10)
                self._require_member(identity, payload["colonyname"])
                return rows
        """
    )
    assert "AUT004" in rules


def test_authlint_accepts_fetch_then_authorize():
    """The legitimate pattern: id-keyed fetch names the colony, then the
    check, then writes keyed by the same fetched colony."""
    rules = _rules(
        """
        class S:
            def _h_run(self, identity, payload):
                entry = self.db.cron_get(payload["cronid"])
                self._require_member(identity, entry["colonyname"])
                self.db.cron_put(entry)
                return entry
        """
    )
    assert rules == []


def test_authlint_resolves_colony_through_assignment_and_get():
    """Canonicalization: `c = payload.get("colonyname", "")` names the
    same value as `payload["colonyname"]` — no false confused-deputy."""
    rules = _rules(
        """
        class S:
            def _h_list(self, identity, payload):
                c = payload.get("colonyname", "")
                self._require_member(identity, c)
                return self.db.list_executors(payload["colonyname"])
        """
    )
    assert rules == []


def test_authlint_server_owner_covers_any_colony():
    rules = _rules(
        """
        class S:
            def _h_admin(self, identity, payload):
                self._require_server_owner(identity)
                return self.db.list_executors(payload["colonyname"])
        """
    )
    assert rules == []


def test_authlint_repo_is_clean():
    """The whole linted tree passes with zero suppressions, and every
    registered handler was seen and role-annotated."""
    paths = [os.path.join(REPO_ROOT, "src", "repro")]
    examples = os.path.join(REPO_ROOT, "examples")
    if os.path.exists(examples):
        paths.append(examples)
    nfiles, handlers, violations = authlint_run(paths)
    assert violations == []
    registered = [h for h in handlers if h.ptypes]
    assert nfiles > 20 and len(registered) >= 30
    assert all(h.role for h in registered)


def test_authmap_matches_security_md(monkeypatch):
    """CI drift gate: the committed permission matrix is what the handler
    tables imply."""
    from repro.analysis import authmap

    monkeypatch.chdir(REPO_ROOT)
    assert authmap.main(["--check"]) == 0


def test_authmap_refuses_failing_tree(tmp_path):
    from repro.analysis import authmap

    bad = tmp_path / "bad.py"
    bad.write_text(
        textwrap.dedent(
            """
            class S:
                def _h_leak(self, identity, payload):
                    return self.db.kv_get("misc", payload["key"])
            """
        )
    )
    with pytest.raises(SystemExit):
        authmap.generate([str(bad)])


# ---------------------------------------------------------------------------
# Runtime auth-fact contracts (REPRO_AUTH_CHECK=1)
# ---------------------------------------------------------------------------


@pytest.fixture()
def auth_checking():
    """Contracts on; restore the prior mode afterwards."""
    prev = authtrack.is_enabled()
    authtrack.enable(True)
    yield
    authtrack.enable(prev)


def test_contracts_pass_on_real_rpc_surface(colony, auth_checking):
    """Submit/assign/close plus listings, users, and stats all run with
    the guards armed — every handler records the facts it needs."""
    client = colony["client"]
    ex = ExecutorBase(
        client, colony["name"], "w-authz", "worker", colony_prvkey=colony["colony_prv"]
    )
    ex.register_function("echo", lambda ctx, *a: list(a))
    spec = FunctionSpec.from_dict(
        {
            "conditions": {"colonyname": colony["name"], "executortype": "worker"},
            "funcname": "echo",
            "args": ["hi"],
            "maxexectime": 60,
        }
    )
    p = client.submit(spec, colony["colony_prv"])
    assert ex.step(timeout=2.0)
    done = client.get_process(p["processid"], colony["colony_prv"])
    assert done["state"] == "successful" and done["out"] == ["hi"]

    user_prv = Crypto.prvkey()
    client.add_user(colony["name"], Crypto.id(user_prv), "alice", colony["colony_prv"])
    # The registered user is a member: it may list, as may the owner.
    assert [u["username"] for u in client.list_users(colony["name"], user_prv)] == [
        "alice"
    ]
    assert client.list_executors(colony["name"], colony["colony_prv"])
    assert client.stats(colony["name"], colony["colony_prv"])["successful"] >= 1


def test_bypassing_handler_raises_contract_error(colony, auth_checking):
    """A handler that skips its _require_* check dies on the db guard."""
    srv = colony["server"]
    srv._handlers["rogue"] = lambda identity, payload: srv.db.list_executors("dev")
    env = sign_envelope("rogue", {}, colony["colony_prv"])
    with pytest.raises(AuthContractError):
        srv.handle(env)


def test_wrong_colony_fact_raises_contract_error(colony, auth_checking):
    """Runtime confused deputy: authorized for dev, acted on dev2."""
    srv = colony["server"]
    colony["client"].add_colony("dev2", Crypto.id(Crypto.prvkey()), colony["server_prv"])

    def rogue(identity, payload):
        srv._require_member(identity, "dev")
        return srv.db.list_executors("dev2")

    srv._handlers["rogue"] = rogue
    env = sign_envelope("rogue", {}, colony["colony_prv"])
    with pytest.raises(AuthContractError):
        srv.handle(env)


def test_requires_auth_pins_the_role(auth_checking):
    @requires_auth("executor")
    def internal():
        return "ok"

    assert internal() == "ok"  # outside any request scope: inert
    with authtrack.request_scope():
        with pytest.raises(AuthContractError):
            internal()
        authtrack.record("id1", "dev", "member")
        with pytest.raises(AuthContractError):
            internal()  # member does not satisfy executor
        authtrack.record("id1", "dev", "executor")
        assert internal() == "ok"


def test_server_fact_satisfies_any_colony(auth_checking):
    with authtrack.request_scope():
        authtrack.record("srv", ANY_COLONY, "server")
        assert authtrack.has_fact("anything", "member")
        assert authtrack.has_fact("other", "owner")


def test_guards_inert_outside_request_scope(auth_checking):
    """Background ticks / direct db use have no request identity: the
    guards must not fire there even with checking enabled."""
    db = MemoryDatabase()
    db.user_put({"userid": "u1", "colonyname": "dev", "name": "n"})
    assert [u["userid"] for u in db.user_list("dev")] == ["u1"]
    with authtrack.request_scope():
        with pytest.raises(AuthContractError):
            db.user_list("dev")


def test_facts_are_request_scoped(auth_checking):
    with authtrack.request_scope():
        authtrack.record("id1", "dev", "member")
        assert authtrack.facts()
    assert authtrack.facts() == ()


# ---------------------------------------------------------------------------
# Users: first-class indexed table + listusers RPC
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("make_db", [MemoryDatabase, SqliteDatabase])
def test_user_table_roundtrip(make_db):
    db = make_db()
    db.user_put({"userid": "u1", "colonyname": "dev", "name": "bob"})
    db.user_put({"userid": "u2", "colonyname": "dev", "name": "alice"})
    db.user_put({"userid": "u3", "colonyname": "ops", "name": "eve"})
    assert db.user_get("u1")["name"] == "bob"
    assert db.user_get("missing") is None
    # per-colony listing, sorted by name
    assert [u["userid"] for u in db.user_list("dev")] == ["u2", "u1"]
    # re-put moves the user between colonies (single source of truth)
    db.user_put({"userid": "u1", "colonyname": "ops", "name": "bob"})
    assert [u["userid"] for u in db.user_list("dev")] == ["u2"]
    assert sorted(u["userid"] for u in db.user_list("ops")) == ["u1", "u3"]
    db.user_del("u2")
    assert db.user_get("u2") is None
    assert db.user_list("dev") == []


def test_listusers_rpc_and_membership(colony):
    client = colony["client"]
    user_prv = Crypto.prvkey()
    client.add_user(colony["name"], Crypto.id(user_prv), "alice", colony["colony_prv"])
    # owner and the registered user itself may list; a stranger may not
    assert [u["username"] for u in client.list_users(colony["name"], colony["colony_prv"])] == ["alice"]
    assert [u["username"] for u in client.list_users(colony["name"], user_prv)] == ["alice"]
    with pytest.raises(AuthError):
        client.list_users(colony["name"], Crypto.prvkey())
    # a registered user is a member but NOT an executor: it may submit
    # but never be assigned work
    spec = {
        "conditions": {"colonyname": colony["name"], "executortype": "worker"},
        "funcname": "echo",
        "maxexectime": 60,
    }
    client.submit(spec, user_prv)
    with pytest.raises(AuthError):
        client.assign(colony["name"], 0.1, user_prv)


def test_sqlite_migration_lifts_user_kv_rows(tmp_path):
    """Seed databases stored users as kv JSON keyed by identity; opening
    the file lifts them into the indexed users table."""
    path = str(tmp_path / "old.db")
    old = SqliteDatabase(path)
    old.kv_put(
        "users",
        "u-legacy",
        {"userid": "u-legacy", "colonyname": "dev", "username": "legacy"},
    )
    db = SqliteDatabase(path)  # migration runs on open
    assert db.user_get("u-legacy")["username"] == "legacy"
    assert [u["userid"] for u in db.user_list("dev")] == ["u-legacy"]
    # single source of truth: the kv rows are gone
    assert db.kv_list("users") == []


# ---------------------------------------------------------------------------
# Hardened unverified-envelope path
# ---------------------------------------------------------------------------


def test_open_envelope_unverified_requires_opt_in():
    env = {"payloadtype": "t", "payload": "", "identity": "abc"}
    with pytest.raises(AuthError):
        open_envelope(env, verify=False)
    ident, ptype, _payload = open_envelope(env, verify=False, allow_unverified=True)
    assert (ident, ptype) == ("abc", "t")


def test_external_dispatch_always_verifies(server_keys):
    """Even a verify_signatures=False server (in-proc benchmark mode)
    rejects unsigned envelopes that crossed a network trust boundary."""
    server_prv, server_id = server_keys
    srv = standalone_server(server_id, verify_signatures=False)
    try:
        insecure = Colonies(InProcTransport([srv]), insecure=True)
        owner_id = Crypto.id(Crypto.prvkey())
        insecure.add_colony("bench", owner_id, server_prv)
        env = {
            "payloadtype": "colonystats",
            "payload": '{"colonyname":"bench"}',
            "identity": owner_id,  # bare claim, no signature
        }
        resp = srv.handle(env, external=True)
        assert resp.get("status") == 403 and "signature" in resp["error"]
        # the same envelope is fine on the in-process path
        assert "result" in srv.handle(env)
    finally:
        srv.stop()
