"""Per-architecture smoke tests (assignment requirement): reduced config,
one forward + one train step on CPU, asserting shapes + finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, TrainConfig, get_config
from repro.data.pipeline import SyntheticTokens
from repro.models import count_params, forward, init_params, model_spec
from repro.train.train_step import init_state, make_train_step

B, S = 2, 16


def _batch(cfg, seed=0):
    return {
        k: jnp.asarray(v)
        for k, v in SyntheticTokens(cfg, B, S, seed=seed).batch_at(0).items()
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, "smoke").copy(param_dtype="float32", compute_dtype="float32")
    spec = model_spec(cfg)
    params = init_params(jax.random.key(0), spec, jnp.float32)
    batch = _batch(cfg)

    logits, aux = forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: non-finite logits"

    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=4)
    state = init_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    state, metrics = step(state, batch)
    assert int(state["step"]) == 1
    for k, v in metrics.items():
        assert np.isfinite(float(v)), f"{arch}: metric {k} not finite"
    assert float(metrics["ce"]) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """Pin the exact assigned hyper-parameters (source: public pool)."""
    cfg = get_config(arch, "full")
    expected = {
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    }[arch]
    layers = cfg.num_layers + cfg.dense_prefix_layers
    assert (layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.d_ff,
            cfg.vocab_size) == expected


def test_full_param_counts_sane():
    """Total parameters land near the published sizes."""
    targets = {
        "starcoder2-15b": (15e9, 17e9),
        "qwen2.5-14b": (14e9, 16e9),
        "stablelm-3b": (2.5e9, 3.2e9),
        "granite-3-8b": (7.5e9, 9e9),
        "jamba-1.5-large-398b": (380e9, 410e9),
        "rwkv6-7b": (7e9, 8e9),
        "mixtral-8x7b": (45e9, 48e9),
        "deepseek-v3-671b": (660e9, 685e9),
    }
    for arch, (lo, hi) in targets.items():
        n = count_params(model_spec(get_config(arch, "full")))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B outside [{lo/1e9},{hi/1e9}]"


def test_moe_aux_losses_reported():
    cfg = get_config("mixtral-8x7b", "smoke").copy(
        param_dtype="float32", compute_dtype="float32"
    )
    params = init_params(jax.random.key(0), model_spec(cfg), jnp.float32)
    _, aux = forward(params, cfg, _batch(cfg))
    assert float(aux["lb_loss"]) > 0  # load-balance stats flow out
