"""Zero-trust crypto layer (paper §3.4.6)."""

import pytest

try:  # optional dependency — only the property test below needs it
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = None

from repro.core.crypto import Crypto, N, Signature


def test_sign_recover_roundtrip():
    prv = Crypto.prvkey()
    ident = Crypto.id(prv)
    sig = Crypto.sign("hello world", prv)
    assert Crypto.recover("hello world", sig) == ident
    assert Crypto.verify("hello world", sig, ident)


def test_different_message_fails():
    prv = Crypto.prvkey()
    sig = Crypto.sign("msg-a", prv)
    assert not Crypto.verify("msg-b", sig, Crypto.id(prv))


def test_tampered_signature_fails():
    prv = Crypto.prvkey()
    ident = Crypto.id(prv)
    sig = Crypto.sign("payload", prv)
    raw = bytearray(bytes.fromhex(sig))
    raw[7] ^= 0xFF
    assert not Crypto.verify("payload", raw.hex(), ident)


def test_wrong_identity_fails():
    prv1, prv2 = Crypto.prvkey(), Crypto.prvkey()
    sig = Crypto.sign("payload", prv1)
    assert not Crypto.verify("payload", sig, Crypto.id(prv2))


def test_signature_is_deterministic():
    """RFC6979 nonces: same (key, msg) -> same signature (stateless protocol)."""
    prv = Crypto.prvkey()
    assert Crypto.sign(b"x", prv) == Crypto.sign(b"x", prv)


def test_signature_wire_format():
    prv = Crypto.prvkey()
    sig = Signature.from_hex(Crypto.sign(b"x", prv))
    assert 1 <= sig.r < N and 1 <= sig.s <= N // 2 and sig.v in (0, 1)


def test_malformed_signature_rejected():
    with pytest.raises(ValueError):
        Signature.from_hex("00" * 10)
    assert not Crypto.verify(b"x", "00" * 65, "ab" * 32)


if given is not None:

    @settings(max_examples=10, deadline=None)
    @given(
        st.binary(min_size=0, max_size=200), st.integers(min_value=1, max_value=N - 1)
    )
    def test_property_recover_matches_identity(msg, d):
        prv = d.to_bytes(32, "big").hex()
        sig = Crypto.sign(msg, prv)
        assert Crypto.recover(msg, sig) == Crypto.id(prv)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_recover_matches_identity():
        pass
