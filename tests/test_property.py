"""Property-based tests (hypothesis) on system invariants."""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import Crypto, FunctionSpec, MemoryDatabase, SqliteDatabase
from repro.core.process import PRIORITY_NS_PER_LEVEL, Process, priority_time
from repro.launch.hlo_analysis import analyze_hlo


# ---------------------------------------------------------------------------
# Eq. (1) priority-time ordering
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(
    st.lists(
        st.tuples(st.integers(0, 10**18), st.integers(0, 5)),
        min_size=2, max_size=20,
    )
)
def test_priority_dominates_within_a_day(subs):
    """A process with priority p+1 submitted within 24h of a priority-p
    process always sorts ahead of it (Eq. 1: one level == one day)."""
    for ts, pr in subs:
        later = ts + PRIORITY_NS_PER_LEVEL - 1  # < one day later
        assert priority_time(later, pr + 1) < priority_time(ts, pr)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 10**15), st.integers(0, 3)), min_size=1, max_size=12))
def test_db_backends_agree_on_queue_order(subs):
    """MemoryDatabase and SqliteDatabase pop candidates in the same order."""
    dbs = [MemoryDatabase(), SqliteDatabase()]
    procs = []
    for i, (ts, pr) in enumerate(subs):
        spec = FunctionSpec.from_dict({
            "conditions": {"colonyname": "c", "executortype": "w"},
            "funcname": "f", "priority": pr,
        })
        p = Process.create(spec, submission_ns=ts * 1000 + i)  # unique ts
        procs.append(p)
    orders = []
    for db in dbs:
        for p in procs:
            db.add_process(Process.from_dict(p.to_dict()))
        order = [q.processid for q in db.candidates("c", "w", "any", limit=50)]
        orders.append(order)
    assert orders[0] == orders[1]
    # and the order is exactly ascending priority_time
    want = [p.processid for p in sorted(procs, key=lambda p: (p.priority_time, p.processid))]
    got_sorted = sorted(orders[0], key=lambda pid: want.index(pid))
    # candidates returns priority_time order; ties (same pt) may differ by id
    pts = {p.processid: p.priority_time for p in procs}
    assert [pts[x] for x in orders[0]] == sorted(pts[x] for x in orders[0])


# ---------------------------------------------------------------------------
# process serialization
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    st.integers(0, 10**18), st.integers(0, 5),
    st.integers(-1, 1000), st.integers(0, 9),
    st.text(st.characters(codec="ascii", exclude_characters='\x00'), max_size=20),
)
def test_process_json_roundtrip(ts, pr, mexec, retries, fname):
    spec = FunctionSpec.from_dict({
        "conditions": {"colonyname": "c", "executortype": "w"},
        "funcname": fname, "priority": pr, "maxexectime": mexec,
    })
    p = Process.create(spec, submission_ns=ts)
    p.retries = retries
    q = Process.from_json(p.to_json())
    assert q.to_dict() == p.to_dict()


# ---------------------------------------------------------------------------
# HLO analyzer invariants
# ---------------------------------------------------------------------------

_HLO_TEMPLATE = """
HloModule test

%body (p: (s32[], f32[{n},{n}])) -> (s32[], f32[{n},{n}]) {{
  %p = (s32[], f32[{n},{n}]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[{n},{n}] get-tuple-element(%p), index=1
  %d = f32[{n},{n}] dot(%g1, %g1), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}
  %c1 = s32[] constant(1)
  %a = s32[] add(%g0, %c1)
  ROOT %t = (s32[], f32[{n},{n}]) tuple(%a, %d)
}}

%cond (p: (s32[], f32[{n},{n}])) -> pred[] {{
  %p = (s32[], f32[{n},{n}]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %lim = s32[] constant({trips})
  ROOT %lt = pred[] compare(%g0, %lim), direction=LT
}}

ENTRY %main (x: f32[{n},{n}]) -> f32[{n},{n}] {{
  %x = f32[{n},{n}] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[{n},{n}]) tuple(%zero, %x)
  %w = (s32[], f32[{n},{n}]) while(%init), condition=%cond, body=%body, backend_config={{"known_trip_count":{{"n":"{trips}"}}}}
  ROOT %out = f32[{n},{n}] get-tuple-element(%w), index=1
}}
"""


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([4, 8, 16]), trips=st.integers(1, 64))
def test_hlo_loop_scaling_is_linear(n, trips):
    """dot flops inside a while body scale exactly by the trip count."""
    a1 = analyze_hlo(_HLO_TEMPLATE.format(n=n, trips=trips))
    a2 = analyze_hlo(_HLO_TEMPLATE.format(n=n, trips=2 * trips))
    assert a1["dot_flops"] == 2.0 * n * n * n * trips
    assert a2["dot_flops"] == 2.0 * a1["dot_flops"]


def test_crypto_identity_is_stable():
    prv = Crypto.prvkey()
    assert Crypto.id(prv) == Crypto.id(prv)
    assert len(Crypto.id(prv)) == 64  # SHA3-256 hex
