"""Raft consensus (paper §3.4.1): elections, failover, log safety."""

import pytest

try:  # optional dependency — only the property test below needs it
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = None

from repro.core.raft import LEADER, SimRaftCluster


def test_single_leader_elected():
    sim = SimRaftCluster(3, seed=1)
    leader = sim.run_until_leader()
    assert leader is not None
    assert len(sim.leaders()) == 1


def test_failover_elects_new_leader():
    sim = SimRaftCluster(3, seed=2)
    l1 = sim.run_until_leader()
    sim.kill(l1)
    for _ in range(600):
        sim.step()
        fresh = [l for l in sim.leaders() if l != l1]
        if fresh:
            break
    assert fresh, "no new leader after killing the old one"


def test_partitioned_leader_steps_down():
    """Check-quorum: a leader cut off from the majority must not keep
    serving assigns (it would double-assign)."""
    sim = SimRaftCluster(3, seed=3)
    l1 = sim.run_until_leader()
    sim.kill(l1)
    for _ in range(800):
        sim.step()
    assert not sim.nodes[l1].is_leader(), "stale leader kept leadership"


def test_heal_rejoins_cluster():
    sim = SimRaftCluster(3, seed=4)
    l1 = sim.run_until_leader()
    sim.kill(l1)
    for _ in range(600):
        sim.step()
    sim.revive(l1)
    for _ in range(600):
        sim.step()
    leaders = sim.leaders()
    assert len(leaders) == 1
    # the revived node recognises the current term's leader
    terms = {n.current_term for n in sim.nodes.values()}
    assert len(terms) == 1


def test_log_replication_and_apply():
    applied: dict[str, list] = {}
    sim = SimRaftCluster(
        3, apply_fn=lambda nid, e, i: applied.setdefault(nid, []).append((i, e["v"])),
        seed=5,
    )
    leader = sim.run_until_leader()
    for v in range(5):
        assert sim.nodes[leader].propose({"v": v}) is not None
        for _ in range(20):
            sim.step()
    # all nodes applied the same sequence
    seqs = {nid: tuple(v) for nid, v in applied.items()}
    assert len(seqs) == 3
    assert len(set(seqs.values())) == 1
    assert [v for _, v in applied[leader]] == [0, 1, 2, 3, 4]


def test_committed_entries_survive_failover():
    applied: dict[str, list] = {}
    sim = SimRaftCluster(
        3, apply_fn=lambda nid, e, i: applied.setdefault(nid, []).append(e["v"]),
        seed=6,
    )
    l1 = sim.run_until_leader()
    sim.nodes[l1].propose({"v": "committed"})
    for _ in range(60):
        sim.step()
    sim.kill(l1)
    for _ in range(800):
        sim.step()
    l2 = [l for l in sim.leaders() if l != l1]
    assert l2, "no new leader"
    sim.nodes[l2[0]].propose({"v": "after-failover"})
    for _ in range(60):
        sim.step()
    assert applied[l2[0]] == ["committed", "after-failover"]


if given is not None:

    @settings(max_examples=8, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        drop=st.floats(0.0, 0.3),
    )
    def test_property_election_safety_under_message_loss(seed, drop):
        """At most one leader per term, even with lossy links."""
        sim = SimRaftCluster(5, seed=seed)
        sim.net.drop_prob = drop
        leaders_by_term: dict[int, set[str]] = {}
        for _ in range(400):
            sim.step()
            for term, ls in sim.leaders_of_term().items():
                leaders_by_term.setdefault(term, set()).update(ls)
        for term, ls in leaders_by_term.items():
            assert len(ls) <= 1, f"two leaders in term {term}: {ls}"

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_election_safety_under_message_loss():
        pass
