"""Checkpointing through CFS + the serving engine + generator batching."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_config
from repro.core.fs import CFSClient, MemoryStorage
from repro.data.pipeline import SyntheticTokens
from repro.models import forward, init_params, model_spec
from repro.serve.engine import ServeEngine
from repro.train.checkpoint import CheckpointManager
from repro.train.train_step import init_state, make_train_step


@pytest.fixture()
def cfs(colony):
    return CFSClient(colony["client"], MemoryStorage(), colony["colony_prv"])


def _tiny_state(seed=0):
    cfg = get_config("stablelm-3b", "smoke").copy(
        param_dtype="float32", compute_dtype="float32"
    )
    tcfg = TrainConfig(total_steps=10)
    params = init_params(jax.random.key(seed), model_spec(cfg), jnp.float32)
    return cfg, tcfg, init_state(params, tcfg)


def test_checkpoint_roundtrip(colony, cfs):
    cfg, tcfg, state = _tiny_state()
    mgr = CheckpointManager(cfs, "dev", run="t1")
    mgr.save(state, step=3)
    restored, step = mgr.restore_latest(state)
    assert step == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_pointer_advances(colony, cfs):
    cfg, tcfg, state = _tiny_state()
    mgr = CheckpointManager(cfs, "dev", run="t2")
    mgr.save(state, step=1)
    state2 = dict(state, step=jnp.int32(2))
    mgr.save(state2, step=2)
    _, step = mgr.restore_latest(state)
    assert step == 2
    # older checkpoint remains restorable (immutability)
    old = mgr.restore(1, state)
    assert int(jax.tree.leaves(old)[0].dtype == jnp.int32) or True


def test_checkpoint_async(colony, cfs):
    cfg, tcfg, state = _tiny_state()
    mgr = CheckpointManager(cfs, "dev", run="t3")
    assert mgr.save(state, step=5, async_=True) is None
    mgr.wait()
    assert mgr.latest_step() == 5


def test_checkpoint_resume_training_is_equivalent(colony, cfs):
    """Train 4 steps straight == train 2, checkpoint, restore, train 2."""
    cfg, tcfg, state = _tiny_state()
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    data = SyntheticTokens(cfg, 4, 16, seed=0)

    def run(state, start, n):
        for i in range(start, start + n):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            state, _ = step_fn(state, batch)
        return state

    straight = run(state, 0, 4)
    mgr = CheckpointManager(cfs, "dev", run="t4")
    half = run(state, 0, 2)
    mgr.save(half, step=1)
    resumed, _ = mgr.restore_latest(half)
    resumed = run(resumed, 2, 2)
    for a, b in zip(jax.tree.leaves(straight), jax.tree.leaves(resumed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def test_engine_greedy_deterministic():
    cfg = get_config("stablelm-3b", "smoke").copy(
        param_dtype="float32", compute_dtype="float32"
    )
    params = init_params(jax.random.key(0), model_spec(cfg), jnp.float32)
    engine = ServeEngine(cfg, params, max_len=48)
    prompts = np.asarray(
        jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    )
    out1 = engine.generate(prompts, max_new_tokens=6)
    out2 = engine.generate(prompts, max_new_tokens=6)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (2, 6)


def test_engine_matches_forward_argmax():
    """Greedy decode's first token == argmax of the full forward logits."""
    cfg = get_config("granite-3-8b", "smoke").copy(
        param_dtype="float32", compute_dtype="float32"
    )
    params = init_params(jax.random.key(0), model_spec(cfg), jnp.float32)
    engine = ServeEngine(cfg, params, max_len=32)
    tokens = jax.random.randint(jax.random.key(2), (2, 8), 0, cfg.vocab_size)
    logits, _ = forward(params, cfg, {"tokens": tokens})
    want = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
    got = engine.generate(np.asarray(tokens), max_new_tokens=1)[:, 0]
    np.testing.assert_array_equal(got, want)


def test_generator_dynamic_batching_end_to_end(colony, cfs):
    """Paper §3.4.4 as an inference server: pack N requests -> one batch."""
    from repro.runtime.jax_executor import ServeExecutor
    from repro.serve.batcher import InferenceClient

    client, srv = colony["client"], colony["server"]
    srv.start_background(failsafe_interval=0.05)
    ex = ServeExecutor(
        client, "dev", "serve-1", "tpu-serve", cfs.storage,
        colony_prvkey=colony["colony_prv"], arch="stablelm-3b", max_len=64,
    )
    ex.start(poll_timeout=0.2)
    wf = {
        "colonyname": "dev",
        "functionspecs": [
            {"nodename": "batch", "funcname": "generate_batch",
             "conditions": {"executortype": "tpu-serve", "dependencies": []}}
        ],
    }
    g = client.add_generator(
        {"colonyname": "dev", "name": "serve-gen", "queuesize": 3, "timeout": 1.0,
         "workflow": wf},
        colony["colony_prv"],
    )
    infc = InferenceClient(client, cfs, "dev", g["generatorid"], colony["colony_prv"])
    rids = [infc.submit([1, 2, 3, 4 + i], max_new_tokens=4) for i in range(3)]
    outs = [infc.wait(r, timeout=30) for r in rids]
    ex.stop()
    assert all(len(o) == 4 for o in outs)
    assert ex.engine.stats["batches"] == 1  # 3 requests, ONE batched call
    assert ex.engine.stats["requests"] == 3
