import os
import sys

# smoke tests must see exactly 1 device (the dry-run sets 512 itself,
# in a separate process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest

from repro.core import Colonies, ColoniesServer, Crypto, InProcTransport, MemoryDatabase
from repro.core.cluster import standalone_server


def pytest_sessionfinish(session, exitstatus):
    """Under REPRO_LOCK_CHECK=1, any recorded lock-order violation fails
    the whole run — the detector is a CI gate, not just a logger."""
    if os.environ.get("REPRO_LOCK_CHECK", "") in ("", "0"):
        return
    from repro.analysis import locktrack

    vs = locktrack.violations()
    if vs:
        print(f"\nREPRO_LOCK_CHECK: {len(vs)} violation(s):", file=sys.stderr)
        for v in vs:
            print(f"  [{v['kind']}] ({v['thread']}) {v['msg']}", file=sys.stderr)
        session.exitstatus = 3


@pytest.fixture(scope="session")
def server_keys():
    prv = Crypto.prvkey()
    return prv, Crypto.id(prv)


@pytest.fixture(scope="session")
def colony_keys():
    prv = Crypto.prvkey()
    return prv, Crypto.id(prv)


@pytest.fixture()
def colony(server_keys, colony_keys):
    """A standalone server with a registered 'dev' colony + SDK client."""
    server_prv, server_id = server_keys
    colony_prv, colony_id = colony_keys
    srv = standalone_server(server_id)
    client = Colonies(InProcTransport([srv]))
    client.add_colony("dev", colony_id, server_prv)
    yield {
        "server": srv,
        "client": client,
        "server_prv": server_prv,
        "colony_prv": colony_prv,
        "name": "dev",
    }
    srv.stop()
