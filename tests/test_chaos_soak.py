"""Chaos soak gate (ROBUSTNESS.md): a 3-replica HA cluster under a
seeded probabilistic FaultPlan (transport resets + drops) AND a
ChaosMonkey partitioning raft replicas, driven by retrying clients with
idempotency-keyed RPCs. Every submitted process must reach a terminal
state exactly once, with zero replication divergence.

Run by ``scripts/verify.sh`` as ``REPRO_REPL_CHECK=1 pytest
tests/test_chaos_soak.py``; the repl fixture below also arms the digest
harness when the env var is absent, so a bare run checks the same
contracts.
"""

import threading
import time

import pytest

from repro.analysis import statehash
from repro.core import Colonies, ExecutorBase, InProcTransport, RetryPolicy
from repro.core.cluster import HAColonyCluster
from repro.core.crypto import Crypto
from repro.runtime import faults
from repro.runtime.chaos import ChaosMonkey

# Generous budget/deadline: during a leader election every replica
# answers 421 for up to a second or two, and the soak must ride it out
# rather than surface NotLeaderError to the test thread.
SOAK_RETRY = RetryPolicy(base_s=0.01, cap_s=0.3, deadline_s=20.0, budget=64, seed=3)

N_PROCESSES = 24
SOAK_DEADLINE_S = 45.0


def spec(i):
    return {
        "conditions": {"colonyname": "dev", "executortype": "worker"},
        "funcname": "echo",
        "args": [i],
        "maxexectime": 5,
        "maxretries": 3,
    }


@pytest.fixture()
def repl_check():
    prev = statehash.is_enabled()
    statehash.enable(True)
    yield
    statehash.enable(prev)


@pytest.fixture()
def ha(repl_check):
    server_prv = Crypto.prvkey()
    colony_prv = Crypto.prvkey()
    cluster = HAColonyCluster(Crypto.id(server_prv), replicas=3, seed=31)
    cluster.start(failsafe_interval=0.2)
    assert cluster.wait_for_leader(10)
    client = Colonies(InProcTransport(cluster.servers, retry=SOAK_RETRY))
    client.add_colony("dev", Crypto.id(colony_prv), server_prv)
    try:
        yield cluster, client, colony_prv
    finally:
        cluster.stop()


def _fresh_client(cluster):
    """Each actor gets its own transport: retry state and the 421
    preferred-replica hint are per-connection, like real sockets."""
    return Colonies(InProcTransport(cluster.servers, retry=SOAK_RETRY))


# ---------------------------------------------------------------------------
# HA fault matrix: the reply-loss window crossed with replication
# ---------------------------------------------------------------------------


class TestHAFaultMatrix:
    """Reset-after-commit-before-reply against the replicated broker:
    the retry must replay the recorded reply (not re-propose the op),
    and the double-apply digest harness must stay clean."""

    def test_submit_reply_lost_yields_one_process(self, ha):
        cluster, client, colony_prv = ha
        plan = faults.FaultPlan(
            [
                faults.FaultRule(
                    "transport.recv",
                    "reset",
                    payloadtype="submitfunctionspec",
                )
            ]
        )
        with faults.active(plan):
            p = client.submit(spec(1), colony_prv)
        assert plan.fired() == 1
        procs = client.get_processes("dev", colony_prv)
        assert [q["processid"] for q in procs] == [p["processid"]]
        cluster.raft.check_divergence()

    def test_close_reply_lost_closes_exactly_once(self, ha):
        cluster, client, colony_prv = ha
        ex = ExecutorBase(
            _fresh_client(cluster), "dev", "m-w", "worker", colony_prvkey=colony_prv
        )
        p = client.submit(spec(1), colony_prv)
        pd = ex.client.assign("dev", 5.0, ex.prvkey)
        assert pd["processid"] == p["processid"]
        plan = faults.FaultPlan(
            [faults.FaultRule("transport.recv", "reset", payloadtype="close")]
        )
        with faults.active(plan):
            # The transport retries; the replay returns the recorded
            # reply instead of raising ConflictError at the second close.
            ex.client.close(p["processid"], ["out"], ex.prvkey)
        assert plan.fired() == 1
        done = client.get_process(p["processid"], colony_prv)
        assert done["state"] == "successful" and done["out"] == ["out"]
        cluster.raft.check_divergence()
        # Exactly one close entry made it into the Raft log, and it
        # carries the client's idempotency key (REPLICATION.md matrix).
        lid = cluster.raft.leader_id()
        closes = [
            le.entry
            for le in cluster.raft.nodes[lid].log
            if le.entry.get("op") == "close"
        ]
        assert len(closes) == 1
        assert closes[0]["msgid"]


# ---------------------------------------------------------------------------
# The soak
# ---------------------------------------------------------------------------


def test_chaos_soak_every_process_terminal_exactly_once(ha):
    cluster, client, colony_prv = ha

    # Probabilistic infrastructure failure for the whole soak: ~8% of
    # replies are lost after commit, ~4% of requests never arrive.
    plan = faults.FaultPlan(
        [
            faults.FaultRule(
                "transport.recv", "reset", times=None, prob=0.08
            ),
            faults.FaultRule(
                "transport.send", "drop", times=None, prob=0.04
            ),
        ],
        seed=1234,
    )

    # ChaosMonkey partitions one raft replica at a time (kill the next,
    # revive the previous), forcing elections mid-traffic.
    state = {"down": None, "next": 0}
    guard = threading.Lock()

    def kill():
        with guard:
            if state["down"] is not None:
                cluster.revive_server(state["down"])
            state["down"] = state["next"]
            state["next"] = (state["next"] + 1) % 3
            cluster.kill_server(state["down"])

    monkey = ChaosMonkey(kill, lambda: None, interval=(0.6, 1.2), seed=5)

    executors = [
        ExecutorBase(
            _fresh_client(cluster), "dev", f"soak-{i}", "worker",
            colony_prvkey=colony_prv,
        )
        for i in range(2)
    ]
    for ex in executors:
        ex.register_function("echo", lambda ctx, *a: list(a))

    pids = []
    with faults.active(plan):
        for ex in executors:
            ex.start(poll_timeout=0.3)
        monkey.start()
        try:
            for i in range(N_PROCESSES):
                pids.append(client.submit(spec(i), colony_prv)["processid"])
            deadline = time.time() + SOAK_DEADLINE_S
            remaining = set(pids)
            while remaining and time.time() < deadline:
                done = {
                    pid
                    for pid in remaining
                    if client.get_process(pid, colony_prv)["state"]
                    in ("successful", "failed")
                }
                remaining -= done
                if remaining:
                    time.sleep(0.2)
        finally:
            monkey.stop()
            with guard:
                if state["down"] is not None:
                    cluster.revive_server(state["down"])
                    state["down"] = None
            for ex in executors:
                ex.stop()

    assert not remaining, (
        f"{len(remaining)} of {N_PROCESSES} processes never reached a"
        f" terminal state (faults fired: {plan.fired()},"
        f" monkey kills: {monkey.kills})"
    )

    # Exactly once: every submitted pid is terminal, no duplicates exist.
    procs = client.get_processes("dev", colony_prv)
    assert sorted(q["processid"] for q in procs) == sorted(pids)
    states = {q["processid"]: q["state"] for q in procs}
    assert all(s in ("successful", "failed") for s in states.values())

    # The soak only proves something if the chaos actually happened.
    assert plan.fired() >= 5, f"fault plan barely fired ({plan.fired()})"
    assert monkey.kills >= 1

    # Replication stayed convergent under partitions + replayed RPCs.
    journal = cluster.raft.journal
    assert journal is not None
    commit = max(n.commit_index for n in cluster.raft.nodes.values())
    catchup = time.time() + 20
    while time.time() < catchup:
        if all(n.last_applied >= commit for n in cluster.raft.nodes.values()):
            break
        time.sleep(0.05)
    cluster.raft.check_divergence()
    journal.check()

    # The brokers' failsafe loops never crashed silently.
    stats = client.stats("dev", colony_prv)
    assert stats["failsafe_errors"] == 0


# ---------------------------------------------------------------------------
# Blob-plane chaos: one storage shard dies mid-soak (STORAGE.md gate)
# ---------------------------------------------------------------------------


def test_blob_soak_shard_death_snapshots_stay_byte_identical(colony, tmp_path):
    """Kill one of three blob shards mid-soak with a seeded FaultPlan:
    every snapshot taken before, during, and after the outage must still
    materialize byte-identical, and a scrub after the revive must
    restore full replication (verified through the repair counters)."""
    from repro.core.blobstore import ShardedStorage
    from repro.core.fs import CFSClient, MemoryStorage

    client, colony_prv = colony["client"], colony["colony_prv"]
    store = ShardedStorage([MemoryStorage() for _ in range(3)], replicas=2)
    cfs = CFSClient(
        client, store, colony_prv,
        retry=RetryPolicy(base_s=0.001, cap_s=0.01, deadline_s=5.0, budget=16, seed=11),
    )

    expected: dict[str, bytes] = {}  # name -> bytes at snapshot time
    snapshots: list[tuple[str, dict[str, bytes]]] = []

    def upload_round(round_no, n=6):
        for i in range(n):
            data = f"round-{round_no} blob-{i} ".encode() * (i + 1)
            name = f"r{round_no}-{i}.bin"
            cfs.upload_bytes("dev", "/soakblob", name, data)
            expected[name] = data
        snap = client.create_snapshot("dev", "/soakblob", f"s{round_no}", colony_prv)
        snapshots.append((snap["snapshotid"], dict(expected)))

    def check_all_snapshots(tag):
        for j, (sid, files) in enumerate(snapshots):
            out = tmp_path / tag / f"snap{j}"
            cfs.materialize_snapshot("dev", sid, str(out))
            got = {p.name: p.read_bytes() for p in out.iterdir()}
            assert got == files, f"snapshot {j} diverged ({tag})"

    upload_round(0)

    # Shard 1 dies: every put/get against it fails, plus a seeded 10%
    # transient flake on shard 2's gets — some keys briefly lose BOTH
    # replicas and only the CFSClient retry rides it out.
    plan = faults.FaultPlan(
        [
            faults.FaultRule("blob.put", "crash", match={"shard": 1}, times=None),
            faults.FaultRule("blob.get", "crash", match={"shard": 1}, times=None),
            faults.FaultRule(
                "blob.get", "crash", match={"shard": 2}, times=None, prob=0.1
            ),
        ],
        seed=77,
    )
    with faults.active(plan):
        upload_round(1)
        upload_round(2)
        check_all_snapshots("during")
    assert plan.fired() >= 5, f"blob chaos barely fired ({plan.fired()})"

    # Shard 1 is back. The outage left under-replicated keys behind;
    # scrub is the anti-entropy pass that heals them all.
    degraded = [k for k in store.keys() if store.replica_count(k) < 2]
    assert degraded, "the outage should have left under-replicated keys"
    report = store.scrub()
    assert report["lost"] == 0
    assert report["repaired"] >= len(degraded) > 0
    assert all(store.replica_count(k) == 2 for k in store.keys())
    st = store.stats()
    assert st["repairs"] >= report["repaired"]
    assert st["put_failures"] > 0 and st["per_shard"][1]["puts"] > 0

    check_all_snapshots("after")
