"""Cron (paper §3.4.3) and generators (paper §3.4.4)."""

import time

import pytest

from repro.core import ExecutorBase
from repro.core.cron import next_cron_deadline_ns
from repro.core.errors import ValidationError


WF = {
    "colonyname": "dev",
    "functionspecs": [
        {"nodename": "tick", "funcname": "tick",
         "conditions": {"executortype": "worker", "dependencies": []}}
    ],
}


def test_cron_interval_fires(colony):
    client, srv = colony["client"], colony["server"]
    srv.start_background(failsafe_interval=0.05)
    ran = []
    ex = ExecutorBase(client, "dev", "cron-w", "worker", colony_prvkey=colony["colony_prv"])
    ex.register_function("tick", lambda ctx, **kw: ran.append(1) or [1])
    ex.start(poll_timeout=0.2)
    c = client.add_cron(
        {"colonyname": "dev", "name": "c1", "interval": 0.2, "workflow": WF},
        colony["colony_prv"],
    )
    time.sleep(1.2)
    ex.stop()
    crons = client.get_crons("dev", colony["colony_prv"])
    assert crons[0]["runs"] >= 3
    assert len(ran) >= 3
    client.remove_cron(c["cronid"], colony["colony_prv"])
    assert client.get_crons("dev", colony["colony_prv"]) == []


def test_cron_two_step_protocol_is_stateless(colony):
    """Deadlines live in the table: a scan after the deadline fires exactly once."""
    client, srv = colony["client"], colony["server"]
    cron_ext = srv.extensions[0]
    client.add_cron(
        {"colonyname": "dev", "name": "c2", "interval": 0.1, "workflow": WF},
        colony["colony_prv"],
    )
    assert cron_ext.tick() == 0  # deadline not reached yet
    time.sleep(0.15)
    assert cron_ext.tick() == 1  # fires
    assert cron_ext.tick() == 0  # next deadline re-armed


def test_cron_expression_parser():
    # every minute
    base = 1_700_000_000 * 10**9
    nxt = next_cron_deadline_ns("* * * * *", base)
    assert nxt > base and (nxt // 10**9) % 60 == 0
    # */5 minutes
    nxt5 = next_cron_deadline_ns("*/5 * * * *", base)
    assert (nxt5 // 10**9 // 60) % 5 == 0
    with pytest.raises(ValidationError):
        next_cron_deadline_ns("* * *", base)  # wrong arity
    with pytest.raises(ValidationError):
        next_cron_deadline_ns("99 * * * *", base)  # out of range


def test_cron_range_step_anchors_at_range_start():
    """11-20/5 is {11, 16} (anchored at 11), not the field-minimum-anchored
    {15, 20} the seed produced."""
    from repro.core.cron import _parse_field

    assert _parse_field("11-20/5", 0, 59) == {11, 16}
    assert _parse_field("*/15", 0, 59) == {0, 15, 30, 45}
    assert _parse_field("3-10/3,30", 0, 59) == {3, 6, 9, 30}
    # Vixie: a lone number with a step runs to the field max
    assert _parse_field("5/15", 0, 59) == {5, 20, 35, 50}
    assert _parse_field("7", 0, 59) == {7}
    with pytest.raises(ValidationError):
        _parse_field("1-5/0", 0, 59)


def test_cron_dow_is_sunday_zero():
    """Standard cron: 0 (and 7) = Sunday. The seed matched Python's
    tm_wday convention, firing '* * * * 0' on Mondays."""
    import time

    base = 1_700_000_000 * 10**9
    nxt = next_cron_deadline_ns("0 0 * * 0", base)
    st = time.localtime(nxt // 10**9)
    assert st.tm_wday == 6  # Python weekday 6 == Sunday
    assert st.tm_hour == 0 and st.tm_min == 0
    # 7 is accepted as Sunday too
    assert next_cron_deadline_ns("0 0 * * 7", base) == nxt
    # Saturday-Sunday range wraps through 7
    sat_sun = next_cron_deadline_ns("0 0 * * 6-7", base)
    assert time.localtime(sat_sun // 10**9).tm_wday in (5, 6)


def test_cron_dom_dow_or_rule():
    """Vixie cron: with BOTH day fields restricted, either may match —
    '0 0 13 * 5' fires every 13th and every Friday, not just Friday-the-13th."""
    import time

    base = 1_700_000_000 * 10**9
    t = base
    fires = []
    for _ in range(6):
        t = next_cron_deadline_ns("0 0 13 * 5", t)
        fires.append(time.localtime(t // 10**9))
    assert all(st.tm_mday == 13 or st.tm_wday == 4 for st in fires)
    assert any(st.tm_mday == 13 and st.tm_wday != 4 for st in fires)  # a 13th
    assert any(st.tm_wday == 4 and st.tm_mday != 13 for st in fires)  # a Friday
    # with only one day field restricted, it alone decides
    only_dom = next_cron_deadline_ns("0 0 13 * *", base)
    assert time.localtime(only_dom // 10**9).tm_mday == 13
    # a '*/N' day field counts as a star field (Vixie DOM_STAR/DOW_STAR):
    # the restricted day-of-month ANDs with it instead of OR-ing
    t = next_cron_deadline_ns("0 0 13 * */2", base)
    st = time.localtime(t // 10**9)
    assert st.tm_mday == 13 and (st.tm_wday + 1) % 7 % 2 == 0


def test_generator_threshold(colony):
    client, srv = colony["client"], colony["server"]
    gen_ext = srv.extensions[1]
    g = client.add_generator(
        {"colonyname": "dev", "name": "g1", "queuesize": 3, "workflow": WF},
        colony["colony_prv"],
    )
    client.pack(g["generatorid"], {"x": 1}, colony["colony_prv"])
    client.pack(g["generatorid"], {"x": 2}, colony["colony_prv"])
    assert gen_ext.tick() == 0  # below threshold
    client.pack(g["generatorid"], {"x": 3}, colony["colony_prv"])
    assert gen_ext.tick() == 1  # fires with all 3 args
    procs = client.get_processes("dev", colony["colony_prv"], state="waiting")
    tick_proc = [p for p in procs if p["spec"]["funcname"] == "tick"][-1]
    packed = tick_proc["spec"]["kwargs"]["packed_args"]
    assert packed == [{"x": 1}, {"x": 2}, {"x": 3}]
    gens = client.get_generators("dev", colony["colony_prv"])
    assert gens[0]["pending"] == 0 and gens[0]["runs"] == 1


def test_generator_timeout_flush(colony):
    """Below-threshold packs flush after the timeout (dynamic batching)."""
    client, srv = colony["client"], colony["server"]
    gen_ext = srv.extensions[1]
    g = client.add_generator(
        {"colonyname": "dev", "name": "g2", "queuesize": 100, "timeout": 0.2,
         "workflow": WF},
        colony["colony_prv"],
    )
    client.pack(g["generatorid"], "solo", colony["colony_prv"])
    assert gen_ext.tick() == 0
    time.sleep(0.25)
    assert gen_ext.tick() == 1  # timeout flush


# ---------------------------------------------------------------------------
# First-class cron/generator tables (no kv scans on the leader tick)
# ---------------------------------------------------------------------------


def _cron_entry(cronid, colony, deadline, **kw):
    e = {
        "cronid": cronid,
        "colonyname": colony,
        "name": cronid,
        "interval": 1.0,
        "cronexpr": "",
        "workflow": WF,
        "deadline": deadline,
        "lastrun": 0,
        "runs": 0,
        "lastworkflowid": "",
        "added": deadline,
    }
    e.update(kw)
    return e


@pytest.mark.parametrize("db_factory", [None, "sqlite"])
def test_cron_due_uses_deadline_index(db_factory, tmp_path):
    from repro.core import MemoryDatabase, SqliteDatabase

    db = MemoryDatabase() if db_factory is None else SqliteDatabase(
        str(tmp_path / "c.db")
    )
    db.cron_put(_cron_entry("early", "c1", 100))
    db.cron_put(_cron_entry("late", "c1", 10_000))
    db.cron_put(_cron_entry("other", "c2", 150))
    due = db.cron_due(200)
    assert sorted(e["cronid"] for e in due) == ["early", "other"]
    # removal invalidates (memdb: stale heap entry is dropped lazily)
    db.cron_del("early")
    assert [e["cronid"] for e in db.cron_due(200)] == ["other"]
    # rescheduling re-arms: the old deadline no longer fires
    db.cron_put(_cron_entry("other", "c2", 50_000))
    assert db.cron_due(200) == []
    assert [e["cronid"] for e in db.cron_due(60_000)] == ["late", "other"]


@pytest.mark.parametrize("db_factory", [None, "sqlite"])
def test_cron_generator_listings_are_per_colony(db_factory, tmp_path):
    from repro.core import MemoryDatabase, SqliteDatabase

    db = MemoryDatabase() if db_factory is None else SqliteDatabase(
        str(tmp_path / "l.db")
    )
    db.cron_put(_cron_entry("a", "c1", 1))
    db.cron_put(_cron_entry("b", "c2", 2))
    assert [e["cronid"] for e in db.cron_list("c1")] == ["a"]
    g1 = {"generatorid": "g1", "colonyname": "c1", "queuesize": 2, "added": 1}
    g2 = {"generatorid": "g2", "colonyname": "c2", "queuesize": 2, "added": 2}
    db.generator_put(g1)
    db.generator_put(g2)
    assert [g["generatorid"] for g in db.generator_list("c2")] == ["g2"]
    assert {g["generatorid"] for g in db.generator_all()} == {"g1", "g2"}
    db.generator_del("g1")
    assert db.generator_get("g1") is None
    assert [g["generatorid"] for g in db.generator_all()] == ["g2"]


def test_sqlite_migration_lifts_cron_generator_kv_rows(tmp_path):
    """Seed databases stored crons/generators as kv JSON blobs; opening
    the file lifts them into the indexed tables and drops the kv copies."""
    from repro.core import SqliteDatabase

    path = str(tmp_path / "old.db")
    old = SqliteDatabase(path)
    cron = _cron_entry("legacy-cron", "dev", 123, runs=7)
    gen = {
        "generatorid": "legacy-gen",
        "colonyname": "dev",
        "name": "g",
        "workflow": WF,
        "queuesize": 3,
        "timeout": 0,
        "firstpack": 0,
        "runs": 2,
    }
    old.kv_put("crons", cron["cronid"], cron)
    old.kv_put("generators", gen["generatorid"], gen)

    db = SqliteDatabase(path)  # migration runs on open
    assert db.cron_get("legacy-cron")["runs"] == 7
    assert [e["cronid"] for e in db.cron_list("dev")] == ["legacy-cron"]
    assert [e["cronid"] for e in db.cron_due(200)] == ["legacy-cron"]
    assert db.generator_get("legacy-gen")["queuesize"] == 3
    # single source of truth: the kv rows are gone
    assert db.kv_list("crons") == []
    assert db.kv_list("generators") == []
