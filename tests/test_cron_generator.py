"""Cron (paper §3.4.3) and generators (paper §3.4.4)."""

import time

import pytest

from repro.core import ExecutorBase
from repro.core.cron import next_cron_deadline_ns
from repro.core.errors import ValidationError


WF = {
    "colonyname": "dev",
    "functionspecs": [
        {"nodename": "tick", "funcname": "tick",
         "conditions": {"executortype": "worker", "dependencies": []}}
    ],
}


def test_cron_interval_fires(colony):
    client, srv = colony["client"], colony["server"]
    srv.start_background(failsafe_interval=0.05)
    ran = []
    ex = ExecutorBase(client, "dev", "cron-w", "worker", colony_prvkey=colony["colony_prv"])
    ex.register_function("tick", lambda ctx, **kw: ran.append(1) or [1])
    ex.start(poll_timeout=0.2)
    c = client.add_cron(
        {"colonyname": "dev", "name": "c1", "interval": 0.2, "workflow": WF},
        colony["colony_prv"],
    )
    time.sleep(1.2)
    ex.stop()
    crons = client.get_crons("dev", colony["colony_prv"])
    assert crons[0]["runs"] >= 3
    assert len(ran) >= 3
    client.remove_cron(c["cronid"], colony["colony_prv"])
    assert client.get_crons("dev", colony["colony_prv"]) == []


def test_cron_two_step_protocol_is_stateless(colony):
    """Deadlines live in the table: a scan after the deadline fires exactly once."""
    client, srv = colony["client"], colony["server"]
    cron_ext = srv.extensions[0]
    client.add_cron(
        {"colonyname": "dev", "name": "c2", "interval": 0.1, "workflow": WF},
        colony["colony_prv"],
    )
    assert cron_ext.tick() == 0  # deadline not reached yet
    time.sleep(0.15)
    assert cron_ext.tick() == 1  # fires
    assert cron_ext.tick() == 0  # next deadline re-armed


def test_cron_expression_parser():
    # every minute
    base = 1_700_000_000 * 10**9
    nxt = next_cron_deadline_ns("* * * * *", base)
    assert nxt > base and (nxt // 10**9) % 60 == 0
    # */5 minutes
    nxt5 = next_cron_deadline_ns("*/5 * * * *", base)
    assert (nxt5 // 10**9 // 60) % 5 == 0
    with pytest.raises(ValidationError):
        next_cron_deadline_ns("* * *", base)  # wrong arity
    with pytest.raises(ValidationError):
        next_cron_deadline_ns("99 * * * *", base)  # out of range


def test_generator_threshold(colony):
    client, srv = colony["client"], colony["server"]
    gen_ext = srv.extensions[1]
    g = client.add_generator(
        {"colonyname": "dev", "name": "g1", "queuesize": 3, "workflow": WF},
        colony["colony_prv"],
    )
    client.pack(g["generatorid"], {"x": 1}, colony["colony_prv"])
    client.pack(g["generatorid"], {"x": 2}, colony["colony_prv"])
    assert gen_ext.tick() == 0  # below threshold
    client.pack(g["generatorid"], {"x": 3}, colony["colony_prv"])
    assert gen_ext.tick() == 1  # fires with all 3 args
    procs = client.get_processes("dev", colony["colony_prv"], state="waiting")
    tick_proc = [p for p in procs if p["spec"]["funcname"] == "tick"][-1]
    packed = tick_proc["spec"]["kwargs"]["packed_args"]
    assert packed == [{"x": 1}, {"x": 2}, {"x": 3}]
    gens = client.get_generators("dev", colony["colony_prv"])
    assert gens[0]["pending"] == 0 and gens[0]["runs"] == 1


def test_generator_timeout_flush(colony):
    """Below-threshold packs flush after the timeout (dynamic batching)."""
    client, srv = colony["client"], colony["server"]
    gen_ext = srv.extensions[1]
    g = client.add_generator(
        {"colonyname": "dev", "name": "g2", "queuesize": 100, "timeout": 0.2,
         "workflow": WF},
        colony["colony_prv"],
    )
    client.pack(g["generatorid"], "solo", colony["colony_prv"])
    assert gen_ext.tick() == 0
    time.sleep(0.25)
    assert gen_ext.tick() == 1  # timeout flush
