"""CFS metadata-plane scale regression (mirrors test_broker_scale.py).

The indexed CFS plane must do work bounded by each op's own result —
never by the total number of files the colony has accumulated — and the
memory and sqlite backends must agree on every result.
"""

import pytest

from repro.core import Colonies, Crypto, InProcTransport, MemoryDatabase, SqliteDatabase
from repro.core.cluster import standalone_server
from repro.core.errors import ConflictError

BACKENDS = [MemoryDatabase, SqliteDatabase]


def _entry(i: int, label: str, name: str) -> dict:
    return {
        "fileid": f"f{i:08d}",
        "colonyname": "scale",
        "label": label,
        "name": name,
        "size": 1,
        "checksum": f"{i:064x}",
        "storage": {"backend": "mem", "url": f"mem://{i:064x}"},
        "added": i,
        "addedby": "test",
    }


# ---------------------------------------------------------------------------
# Bounded work per op
# ---------------------------------------------------------------------------


def test_cfs_ops_bounded_at_10k_files():
    """Hot-subtree ops must not walk the 10k cold files (memdb metrics)."""
    db = MemoryDatabase()
    for i in range(10_000):
        db.cfs_add_file(_entry(i, f"/bulk/s{i % 64:02d}", f"c{i:06d}"))
    for i in range(20):
        db.cfs_add_file(_entry(100_000 + i, "/hot", f"h{i:04d}"))

    db.metrics["cfs_nodes_visited"] = 0
    files = db.cfs_list("scale", "/hot")
    assert len(files) == 20
    assert db.metrics["cfs_nodes_visited"] <= 2  # the /hot node, nothing else

    db.metrics["cfs_nodes_visited"] = 0
    head = db.cfs_head("scale", "/hot", "h0010")
    assert head is not None and head["revision"] == 1
    assert db.metrics["cfs_nodes_visited"] == 0  # head index, no tree walk

    snap = db.cfs_create_snapshot(
        {"snapshotid": "s1", "colonyname": "scale", "name": "s", "label": "/hot"}
    )
    assert len(snap["fileids"]) == 20

    # removal pin check is a refcount read, not a snapshot scan
    assert db.cfs_pin_count("scale", snap["fileids"][0]) == 1
    with pytest.raises(ConflictError):
        db.cfs_remove_file("scale", snap["fileids"][0])


def test_cfs_root_listing_visits_only_live_labels():
    """A root listing walks the label tree, not every file revision."""
    db = MemoryDatabase()
    for i in range(200):
        # 50 revisions per (label, name): the walk touches heads only
        db.cfs_add_file(_entry(i, f"/r/l{i % 4}", "f"))
    db.metrics["cfs_nodes_visited"] = 0
    files = db.cfs_list("scale", "/")
    assert len(files) == 4
    assert all(f["revision"] == 50 for f in files)
    assert db.metrics["cfs_nodes_visited"] <= 6  # "/", "/r", 4 leaf labels


# ---------------------------------------------------------------------------
# Backend agreement (contract test through the full RPC surface)
# ---------------------------------------------------------------------------


def _mkserver(db):
    server_prv, colony_prv = Crypto.prvkey(), Crypto.prvkey()
    srv = standalone_server(Crypto.id(server_prv), db, verify_signatures=False)
    client = Colonies(InProcTransport([srv]), insecure=True)
    client.add_colony("scale", Crypto.id(colony_prv), server_prv)
    return srv, client, colony_prv


def _norm(e: dict) -> tuple:
    return (e["label"], e["name"], e["revision"], e["checksum"], e["size"])


def _drive(db) -> list:
    """One scripted CFS session; returns a normalized result trace."""
    srv, client, prv = _mkserver(db)
    trace: list = []
    try:
        def add(label, name, i):
            return client.add_file(
                {"colonyname": "scale", "label": label, "name": name, "size": 1,
                 "checksum": f"{i:064x}",
                 "storage": {"backend": "mem", "url": f"mem://{i:064x}"}},
                prv,
            )

        add("/", "root.txt", 1)
        add("/a", "x.txt", 2)
        add("/a", "x.txt", 3)          # second revision
        add("/a/b", "deep.txt", 4)
        add("/ab", "sibling.txt", 5)   # shares the '/a' string prefix, not the subtree
        scratch = add("/scratch", "tmp.txt", 6)

        trace.append([_norm(e) for e in client.get_files("scale", "/", prv)])
        trace.append([_norm(e) for e in client.get_files("scale", "/a", prv)])
        trace.append([_norm(e) for e in client.get_files("scale", "/nope", prv)])
        trace.append(_norm(client.get_file("scale", "/a", "x.txt", prv)))

        snap = client.create_snapshot("scale", "/a", "s1", prv)
        trace.append(len(snap["fileids"]))
        got = client.get_snapshot("scale", snap["snapshotid"], prv)
        trace.append([_norm(e) for e in got["files"]])

        pinned = client.get_file("scale", "/a", "x.txt", prv)
        try:
            client.remove_file("scale", pinned["fileid"], prv)
            trace.append("removed-pinned")
        except ConflictError:
            trace.append("pin-conflict")

        client.remove_file("scale", scratch["fileid"], prv)
        trace.append([_norm(e) for e in client.get_files("scale", "/scratch", prv)])

        client.remove_snapshot("scale", snap["snapshotid"], prv)
        client.remove_file("scale", pinned["fileid"], prv)
        # head falls back to the surviving revision 1
        trace.append(_norm(client.get_file("scale", "/a", "x.txt", prv)))
        trace.append([_norm(e) for e in client.get_files("scale", "/", prv)])
    finally:
        srv.stop()
    return trace


def test_backends_agree_on_cfs_results():
    mem_trace = _drive(MemoryDatabase())
    sql_trace = _drive(SqliteDatabase())
    assert mem_trace == sql_trace
    # spot-check the scripted expectations themselves
    assert mem_trace[2] == []                     # unknown label is empty
    assert mem_trace[3][2] == 2                   # head picked revision 2
    assert mem_trace[6] == "pin-conflict"
    assert mem_trace[8][2] == 1                   # fallback head after removal


# ---------------------------------------------------------------------------
# Revision monotonicity + pin lifecycle, on both backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("db_factory", BACKENDS)
def test_revision_heads_monotonic(db_factory):
    db = db_factory()
    revs = [db.cfs_add_file(_entry(i, "/m", "f"))["revision"] for i in range(5)]
    assert revs == [1, 2, 3, 4, 5]
    head = db.cfs_head("scale", "/m", "f")
    db.cfs_remove_file("scale", head["fileid"])
    assert db.cfs_head("scale", "/m", "f")["revision"] == 4
    assert db.cfs_add_file(_entry(99, "/m", "f"))["revision"] == 5


@pytest.mark.parametrize("db_factory", BACKENDS)
def test_batched_file_lookup_preserves_order_and_gaps(db_factory):
    """cfs_get_files_by_ids: one batch (>500 ids exercises sqlite's
    parameter chunking), results in input order, None where absent."""
    db = db_factory()
    ids = [db.cfs_add_file(_entry(i, "/b", f"f{i:04d}"))["fileid"] for i in range(600)]
    query = [ids[599], "ghost", ids[0], ids[300]]
    got = db.cfs_get_files_by_ids("scale", query)
    assert [e["fileid"] if e else None for e in got] == [
        ids[599], None, ids[0], ids[300],
    ]


@pytest.mark.parametrize("db_factory", BACKENDS)
def test_pin_refcount_lifecycle(db_factory):
    db = db_factory()
    e = db.cfs_add_file(_entry(0, "/p", "f"))
    s1 = db.cfs_create_snapshot(
        {"snapshotid": "s1", "colonyname": "scale", "name": "a", "label": "/p"}
    )
    s2 = db.cfs_create_snapshot(
        {"snapshotid": "s2", "colonyname": "scale", "name": "b", "label": "/p"}
    )
    assert s1["fileids"] == s2["fileids"] == [e["fileid"]]
    assert db.cfs_pin_count("scale", e["fileid"]) == 2
    with pytest.raises(ConflictError):
        db.cfs_remove_file("scale", e["fileid"])
    db.cfs_remove_snapshot("scale", "s1")
    assert db.cfs_pin_count("scale", e["fileid"]) == 1
    with pytest.raises(ConflictError):
        db.cfs_remove_file("scale", e["fileid"])
    db.cfs_remove_snapshot("scale", "s2")
    assert db.cfs_pin_count("scale", e["fileid"]) == 0
    assert db.cfs_remove_file("scale", e["fileid"]) is not None
    assert db.cfs_head("scale", "/p", "f") is None


# ---------------------------------------------------------------------------
# Sqlite migration: seed kv rows -> first-class indexed tables
# ---------------------------------------------------------------------------


def test_sqlite_migration_backfills_from_kv(tmp_path):
    path = str(tmp_path / "cfs.db")
    old = SqliteDatabase(path)
    e = _entry(1, "/mig", "f.txt")
    e["revision"] = 1
    old.kv_put("cfs_files", e["fileid"], e)
    old.kv_put(
        "cfs_snapshots",
        "snap1",
        {"snapshotid": "snap1", "colonyname": "scale", "name": "s",
         "label": "/mig", "fileids": [e["fileid"], "ghost-fileid"], "added": 0},
    )

    db = SqliteDatabase(path)  # migration runs on open
    assert db.cfs_head("scale", "/mig", "f.txt")["fileid"] == e["fileid"]
    assert [f["name"] for f in db.cfs_list("scale", "/")] == ["f.txt"]
    # pins rebuilt from the snapshot body: removal is refused
    assert db.cfs_pin_count("scale", e["fileid"]) == 1
    with pytest.raises(ConflictError):
        db.cfs_remove_file("scale", e["fileid"])
    snap = db.cfs_get_snapshot("scale", "snap1")
    assert snap["fileids"] == [e["fileid"], "ghost-fileid"]
    # the kv copies are gone — single source of truth
    assert old.kv_list("cfs_files") == [] or db.kv_list("cfs_files") == []
    assert db.kv_list("cfs_snapshots") == []


def test_sqlite_migration_resequences_colliding_revisions(tmp_path):
    """The seed computed revisions without a lock, so two kv rows can both
    claim (label, name, revision) N; the migration must keep both files,
    bumping the loser past the head rather than dropping its metadata."""
    path = str(tmp_path / "collide.db")
    old = SqliteDatabase(path)
    for fid in ("aaaa", "bbbb"):
        e = _entry(1, "/dup", "f.txt")
        e["fileid"] = fid
        e["revision"] = 1
        e["checksum"] = fid * 16
        old.kv_put("cfs_files", fid, e)

    db = SqliteDatabase(path)
    files = db.cfs_list("scale", "/dup")
    assert len(files) == 1  # heads only
    with db._lock:
        rows = db._exec(
            "SELECT revision FROM cfs_files WHERE colonyname='scale' AND label='/dup'"
        ).fetchall()
    revs = sorted(r for (r,) in rows)
    assert revs == [1, 2]  # both rows survived, re-sequenced
    assert db.cfs_get_file("scale", "aaaa") is not None
    assert db.cfs_get_file("scale", "bbbb") is not None
    # the re-sequenced body agrees with its table row
    head = db.cfs_head("scale", "/dup", "f.txt")
    assert head["revision"] == 2
