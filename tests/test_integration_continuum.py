"""End-to-end compute-continuum test — the paper's whole story in one DAG:

  prepare_data (edge executor) -> train (tpu-pod, chaos-crashed mid-run,
  failsafe re-assigns, training resumes from the CFS checkpoint) ->
  evaluate -> results visible to the user.

Plus the §Discussion scenario: train on one platform, CFS-sync the model,
serve it on another.
"""

import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Colonies, ExecutorBase, WorkflowSpec
from repro.core.fs import CFSClient, MemoryStorage
from repro.runtime.jax_executor import DataExecutor, ServeExecutor, TrainerExecutor


@pytest.fixture()
def storage():
    return MemoryStorage()


def test_full_pipeline_with_executor_crash(colony, storage):
    client, srv = colony["client"], colony["server"]
    srv.start_background(failsafe_interval=0.1)

    data_ex = DataExecutor(client, "dev", "edge-1", "edge-data", storage,
                           colony_prvkey=colony["colony_prv"])
    # BOTH trainers die at step 3 on their first assignment (simulated
    # crash; the process is never closed), so whichever wins the race
    # crashes exactly once; die_at_step clears after the crash, so the
    # post-failsafe re-assignment completes. maxretries=3 > worst case 2.
    trainer_a = TrainerExecutor(client, "dev", "tpu-a", "tpu-pod", storage,
                                colony_prvkey=colony["colony_prv"], die_at_step=3)
    trainer_b = TrainerExecutor(client, "dev", "tpu-b", "tpu-pod", storage,
                                colony_prvkey=colony["colony_prv"], die_at_step=3)
    for ex in (data_ex, trainer_a, trainer_b):
        ex.start(poll_timeout=0.2)

    wf = WorkflowSpec.from_dict({
        "colonyname": "dev",
        "functionspecs": [
            {"nodename": "prep", "funcname": "prepare_data",
             "kwargs": {"shards": 2, "tokens_per_shard": 256},
             "conditions": {"executortype": "edge-data", "dependencies": []},
             "maxexectime": 30},
            {"nodename": "train", "funcname": "train",
             "kwargs": {"arch": "stablelm-3b", "steps": 4, "batch": 2,
                        "seq_len": 16, "checkpoint_every": 1, "run": "itest"},
             # lease must exceed one attempt's compile+steps (~10s on CPU);
             # crash detection latency = remaining lease after the crash
             "conditions": {"executortype": "tpu-pod", "dependencies": ["prep"]},
             "maxexectime": 60, "maxretries": 5},
            {"nodename": "eval", "funcname": "evaluate",
             "kwargs": {"arch": "stablelm-3b", "batch": 2, "seq_len": 16,
                        "run": "itest"},
             "conditions": {"executortype": "tpu-pod", "dependencies": ["train"]},
             "maxexectime": 30},
        ],
    })
    r = client.submit_workflow(wf, colony["colony_prv"])
    procs = {p["spec"]["nodename"]: p for p in r["processes"]}
    done = client.wait(procs["eval"]["processid"], colony["colony_prv"], timeout=300)
    for ex in (data_ex, trainer_a, trainer_b):
        ex.stop()

    assert done["state"] == "successful", done["errors"]
    assert np.isfinite(done["out"][0]["eval_ce"])
    train_p = client.get_process(procs["train"]["processid"], colony["colony_prv"])
    assert train_p["state"] == "successful"
    assert train_p["retries"] >= 1, "chaos crash should have consumed a retry"
    assert train_p["out"][0]["final_step"] == 3
    # one of the trainers really did take (and lose) the process first
    assert trainer_a.failed + trainer_b.failed >= 1


def test_train_then_serve_handoff(colony, storage):
    """§Discussion: 'train a ML model on an HPC system, then use CFS to
    synchronize the trained model to a cloud environment'."""
    client, srv = colony["client"], colony["server"]
    srv.start_background(failsafe_interval=0.1)
    trainer = TrainerExecutor(client, "dev", "hpc-1", "tpu-pod", storage,
                              colony_prvkey=colony["colony_prv"])
    trainer.start(poll_timeout=0.2)
    from repro.core import FunctionSpec

    p = client.submit(FunctionSpec.from_dict({
        "conditions": {"colonyname": "dev", "executortype": "tpu-pod"},
        "funcname": "train",
        "kwargs": {"arch": "stablelm-3b", "steps": 4, "batch": 2, "seq_len": 16,
                   "checkpoint_every": 2, "run": "handoff"},
        "maxexectime": 60,
    }), colony["colony_prv"])
    done = client.wait(p["processid"], colony["colony_prv"], timeout=120)
    trainer.stop()
    assert done["state"] == "successful"

    # "cloud" executor boots from the CFS checkpoint the trainer wrote
    server = ServeExecutor(client, "dev", "cloud-1", "tpu-serve", storage,
                           colony_prvkey=colony["colony_prv"],
                           arch="stablelm-3b", max_len=64, run="handoff")
    prompts = np.zeros((1, 4), np.int32)
    out = server.engine.generate(prompts, max_new_tokens=3)
    assert out.shape == (1, 3)
