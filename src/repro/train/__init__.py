"""repro.train subpackage."""
