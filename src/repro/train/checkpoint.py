"""Distributed checkpointing through CFS (the paper's data plane).

Checkpoints are the continuum hand-off object: the training executor
saves state into CFS (immutable files + a snapshot pinning the exact
revision set); a restarted — or entirely different — executor restores
from the snapshot. Because CFS files are immutable and snapshots pin
revisions, a checkpoint can never be half-overwritten: restart sees
either the previous complete checkpoint or the new complete one.

Async mode copies leaves to host synchronously (cheap) and uploads in a
background thread, overlapping I/O with the next training steps.
"""

from __future__ import annotations

import io
import json
import threading
from typing import Any

import jax
import numpy as np

from ..core.fs import CFSClient


def _leaf_names(tree: Any) -> list[str]:
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [jax.tree_util.keystr(p) for p, _ in paths]


def _to_bytes(arr: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue()


def _from_bytes(data: bytes) -> np.ndarray:
    return np.load(io.BytesIO(data), allow_pickle=False)


class CheckpointManager:
    def __init__(self, cfs: CFSClient, colony: str, prefix: str = "/checkpoints", run: str = "run0"):
        self.cfs = cfs
        self.colony = colony
        self.prefix = f"{prefix}/{run}"
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # ------------------------------------------------------------------ save
    def save(self, state: Any, step: int, async_: bool = False) -> dict | None:
        """Snapshot the full state pytree at ``step``."""
        leaves = jax.tree.leaves(state)
        names = _leaf_names(state)
        host = [np.asarray(x) for x in leaves]  # device->host copy, synchronous

        def upload() -> dict:
            label = f"{self.prefix}/step-{step}"
            manifest = {"step": step, "leaves": []}
            for i, (name, arr) in enumerate(zip(names, host)):
                fname = f"leaf-{i:05d}.npy"
                self.cfs.upload_bytes(self.colony, label, fname, _to_bytes(arr))
                manifest["leaves"].append(
                    {"name": name, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
                )
            self.cfs.upload_bytes(
                self.colony, label, "manifest.json", json.dumps(manifest).encode()
            )
            snap = self.cfs.client.create_snapshot(
                self.colony, label, f"ckpt-step-{step}", self.cfs.prvkey
            )
            # latest pointer — a new immutable revision, atomically visible
            self.cfs.upload_bytes(
                self.colony,
                self.prefix,
                "latest.json",
                json.dumps({"step": step, "snapshotid": snap["snapshotid"]}).encode(),
            )
            return snap

        if async_:
            self.wait()  # only one in-flight save

            def run() -> None:
                try:
                    upload()
                except Exception as e:  # noqa: BLE001 — surfaced via wait()
                    self._error = e

            self._thread = threading.Thread(target=run, daemon=True)
            self._thread.start()
            return None
        return upload()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # --------------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        try:
            data = self.cfs.download_bytes(self.colony, self.prefix, "latest.json")
        except Exception:  # noqa: BLE001 — no checkpoint yet
            return None
        return json.loads(data)["step"]

    def restore_latest(self, like: Any) -> tuple[Any, int] | None:
        step = self.latest_step()
        if step is None:
            return None
        return self.restore(step, like), step

    def restore(self, step: int, like: Any) -> Any:
        label = f"{self.prefix}/step-{step}"
        manifest = json.loads(self.cfs.download_bytes(self.colony, label, "manifest.json"))
        leaves_like, treedef = jax.tree.flatten(like)
        assert len(manifest["leaves"]) == len(leaves_like), "state structure changed"
        out = []
        for entry, ref in zip(manifest["leaves"], leaves_like):
            arr = _from_bytes(self.cfs.download_bytes(self.colony, label, entry["file"]))
            assert tuple(arr.shape) == tuple(ref.shape), (entry["name"], arr.shape, ref.shape)
            out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
        return jax.tree.unflatten(treedef, out)
