"""Optimizers from scratch (no optax): AdamW and Adafactor.

AdamW keeps fp32 first/second moments (the dominant memory term for the
≥100B configs — which is why those configs can also select Adafactor's
factored second moments). Updates are computed in fp32 and cast back to
the parameter dtype.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import TrainConfig


def lr_schedule(tcfg: TrainConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup then cosine decay to 10%."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(tcfg.warmup_steps, 1), 1.0)
    total = jnp.maximum(tcfg.total_steps - tcfg.warmup_steps, 1)
    progress = jnp.clip((step - tcfg.warmup_steps) / total, 0.0, 1.0)
    cosine = 0.55 + 0.45 * jnp.cos(jnp.pi * progress)
    return tcfg.learning_rate * warm * cosine


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(tree: Any, max_norm: float) -> tuple[Any, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def adamw_update(
    params: Any, grads: Any, opt: dict, step: jnp.ndarray, tcfg: TrainConfig
) -> tuple[Any, dict]:
    lr = lr_schedule(tcfg, step)
    b1, b2, eps, wd = tcfg.beta1, tcfg.beta2, tcfg.eps, tcfg.weight_decay
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt["m"])
    flat_v = treedef.flatten_up_to(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; used by the ≥100B configs)
# ---------------------------------------------------------------------------


def _factored(shape: tuple[int, ...]) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params: Any) -> dict:
    def init(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),  # row stats
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"v": jax.tree.map(init, params, is_leaf=lambda x: hasattr(x, "shape"))}


def adafactor_update(
    params: Any, grads: Any, opt: dict, step: jnp.ndarray, tcfg: TrainConfig
) -> tuple[Any, dict]:
    lr = lr_schedule(tcfg, step)
    t = step.astype(jnp.float32) + 1.0
    beta2 = 1.0 - t**-0.8  # adafactor schedule
    eps = 1e-30
    d = tcfg.grad_clip if tcfg.grad_clip > 0 else 1.0

    def upd(p, g, v):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + eps
        if _factored(p.shape):
            vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            # u = g / (sqrt(vr/mean(vr)) ⊗ sqrt(vc)) — standard factored precond.
            rfac = jax.lax.rsqrt(
                vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps) + eps
            )
            cfac = jax.lax.rsqrt(vc + eps)
            u = g * rfac[..., None] * cfac[..., None, :]
            new_v = {"vr": vr, "vc": vc}
        else:
            vv = beta2 * v["v"] + (1 - beta2) * g2
            u = g * jax.lax.rsqrt(vv + eps)
            new_v = {"v": vv}
        # update clipping (RMS <= d)
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
        u = u / jnp.maximum(1.0, rms / d)
        scale = jnp.maximum(jnp.sqrt(jnp.mean(jnp.square(p.astype(jnp.float32)))), 1e-3)
        new_p = p.astype(jnp.float32) - lr * scale * u - lr * tcfg.weight_decay * p.astype(jnp.float32)
        return new_p.astype(p.dtype), new_v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_v = treedef.flatten_up_to(opt["v"])
    out = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
    return treedef.unflatten([o[0] for o in out]), {
        "v": treedef.unflatten([o[1] for o in out])
    }


def opt_init(params: Any, tcfg: TrainConfig) -> dict:
    if tcfg.optimizer == "adafactor":
        return adafactor_init(params)
    return adamw_init(params)


def opt_update(
    params: Any, grads: Any, opt: dict, step: jnp.ndarray, tcfg: TrainConfig
) -> tuple[Any, dict]:
    if tcfg.optimizer == "adafactor":
        return adafactor_update(params, grads, opt, step, tcfg)
    return adamw_update(params, grads, opt, step, tcfg)
