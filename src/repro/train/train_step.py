"""train_step: causal-LM loss + MoE aux + MTP, microbatched grad accumulation.

The step function is pure (state, batch) -> (state, metrics) and is what
the launcher pjit-compiles on the production mesh. Gradient accumulation
runs as a ``lax.scan`` over microbatches with fp32 accumulators, shrinking
activation peaks by ``microbatches`` at the cost of one extra grad buffer.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, TrainConfig
from ..models.model import forward, mtp_logits
from .optimizer import clip_by_global_norm, lr_schedule, opt_init, opt_update


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray, mask: jnp.ndarray | None = None):
    """Mean next-token CE in fp32. logits: (B,S,V); targets: (B,S) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean(), nll.size
    denom = jnp.maximum(mask.sum(), 1)
    return (nll * mask).sum() / denom, denom


def loss_fn(params: Any, cfg: ModelConfig, tcfg: TrainConfig, batch: dict):
    tokens = batch["tokens"]
    want_hidden = cfg.mtp_depth > 0
    out = forward(params, cfg, batch, return_hidden=want_hidden)
    logits, aux = out[0], out[1]
    targets = tokens[:, 1:]
    ce, _ = cross_entropy(logits[:, :-1], targets)
    loss = ce
    metrics = {"ce": ce}
    if cfg.moe.num_experts > 0:
        moe_loss = (
            cfg.moe.aux_loss_weight * aux["lb_loss"]
            + cfg.moe.router_z_weight * aux["z_loss"]
        )
        loss = loss + moe_loss
        metrics["moe_lb"] = aux["lb_loss"]
        metrics["moe_z"] = aux["z_loss"]
    if want_hidden:
        hidden = out[2]
        # MTP: logits at position t predict token t+2.
        mlogits = mtp_logits(params, cfg, hidden, tokens)  # (B, S-1, V)
        mtp_ce, _ = cross_entropy(mlogits[:, :-1], tokens[:, 2:])
        loss = loss + tcfg.mtp_loss_weight * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    metrics["loss"] = loss
    return loss, metrics


def init_state(params: Any, tcfg: TrainConfig) -> dict:
    return {
        "step": jnp.zeros((), jnp.int32),
        "params": params,
        "opt": opt_init(params, tcfg),
    }


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, tcfg, batch), has_aux=True
        )(params)
        return grads, metrics

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        """When microbatches > 1, ``batch`` leaves arrive PRE-SPLIT as
        (k, B/k, ...) — splitting outside jit keeps the per-microbatch
        batch dim cleanly sharded over (pod, data) instead of forcing a
        GSPMD reshard of an in-step reshape."""
        params = state["params"]
        k = tcfg.microbatches
        if k > 1:
            micro = batch

            def body(acc, mb):
                g, m = grads_of(params, mb)
                acc_g = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32) / k, acc[0], g
                )
                acc_m = jax.tree.map(lambda a, mm: a + mm / k, acc[1], m)
                return (acc_g, acc_m), None

            zeros_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            zeros_m = {
                kk: jnp.zeros((), jnp.float32)
                for kk in _metric_keys(cfg)
            }
            (grads, metrics), _ = jax.lax.scan(body, (zeros_g, zeros_m), micro)
        else:
            grads, metrics = grads_of(params, batch)

        if tcfg.grad_clip > 0:
            grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        else:
            from .optimizer import global_norm

            gnorm = global_norm(grads)
        new_params, new_opt = opt_update(params, grads, state["opt"], state["step"], tcfg)
        new_state = {
            "step": state["step"] + 1,
            "params": new_params,
            "opt": new_opt,
        }
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr_schedule(tcfg, state["step"])
        return new_state, metrics

    return train_step


def _metric_keys(cfg: ModelConfig) -> list[str]:
    keys = ["ce", "loss"]
    if cfg.moe.num_experts > 0:
        keys += ["moe_lb", "moe_z"]
    if cfg.mtp_depth > 0:
        keys += ["mtp_ce"]
    return keys


def make_eval_step(cfg: ModelConfig, tcfg: TrainConfig):
    def eval_step(params: Any, batch: dict) -> dict:
        _, metrics = loss_fn(params, cfg, tcfg, batch)
        return metrics

    return eval_step
