"""qwen2.5-14b [dense] — 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064 — GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B; hf]."""

from .base import ModelConfig

ARCH_ID = "qwen2.5-14b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        source="hf:Qwen/Qwen2.5-0.5B; hf",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        d_ff=13824,
        vocab_size=152064,
        attention="gqa",
        qkv_bias=True,
        rope_theta=1000000.0,
        activation="swiglu",
        norm="rmsnorm",
        sharding_rules="fsdp",
        # 40 heads do not divide the 16-wide model axis (and jit input
        # shardings cannot pad), so this arch runs SEQUENCE-PARALLEL: the
        # residual stream's seq dim is sharded on "model", attention heads
        # and ffn stay unsharded, weights are FSDP-sharded over "data".
        # See EXPERIMENTS.md §Perf (qwen iteration 1): 16x compute
        # parallelism for the price of one x all-gather per layer.
        rules_overrides={
            "heads": None, "kv_heads": None, "ffn": None, "vocab": None,
            "seq": "model", "embed": ("data", "model"),
        },
        q_chunk=256,  # 32768/256 and 4096/256 blocks divide the model axis
    )


def smoke() -> ModelConfig:
    return full().copy(
        num_layers=2,
        d_model=80,
        num_heads=5,
        num_kv_heads=1,
        head_dim=0,
        d_ff=192,
        vocab_size=311,
        sharding_rules="tp",
    )
