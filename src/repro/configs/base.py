"""Model/run configuration shared by all 10 assigned architectures."""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any


@dataclass
class MoEConfig:
    num_experts: int = 0  # routed experts; 0 = dense MLP
    num_shared_experts: int = 0
    top_k: int = 2
    expert_d_ff: int = 0  # per-expert hidden; defaults to d_ff
    capacity_factor: float = 1.25
    group_size: int = 1024  # dispatch group (GShard-style) bounds T*E*C cost
    aux_loss_weight: float = 0.01
    router_z_weight: float = 1e-3
    moe_every: int = 1  # MoE replaces the MLP every k-th layer


@dataclass
class MambaConfig:
    state_dim: int = 16
    conv_width: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclass
class RwkvConfig:
    head_size: int = 64
    decay_lora: int = 64
    mix_lora: int = 32


@dataclass
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | hybrid | ssm | vlm | audio
    source: str = ""  # provenance tag from the assignment pool

    # Core transformer dims
    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0  # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024

    # Attention details
    attention: str = "gqa"  # gqa | mla | none (ssm)
    qkv_bias: bool = False
    use_rope: bool = True  # jamba: no positional encoding
    rope_theta: float = 10000.0
    sliding_window: int = 0  # 0 = full attention
    tied_embeddings: bool = False
    activation: str = "swiglu"  # swiglu | gelu | geglu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5

    # Sub-configs
    moe: MoEConfig = field(default_factory=MoEConfig)
    mamba: MambaConfig = field(default_factory=MambaConfig)
    rwkv: RwkvConfig = field(default_factory=RwkvConfig)
    mla: MLAConfig = field(default_factory=MLAConfig)

    # Hybrid layout (jamba): period-P group, attention at index attn_index
    hybrid_period: int = 0  # 0 = not hybrid
    hybrid_attn_index: int = 4

    # VLM: cross-attention every k-th layer over precomputed patch embeddings
    cross_attn_every: int = 0  # 0 = no cross-attn layers
    vision_embed_dim: int = 1280
    num_patches: int = 1601

    # Audio/enc-dec (seamless): encoder layers + frame-embedding frontend stub
    encoder_layers: int = 0  # 0 = decoder-only
    audio_embed_dim: int = 1024
    max_src_len: int = 4096

    # DeepSeek extras
    mtp_depth: int = 0  # multi-token-prediction blocks (predict t+2)
    dense_prefix_layers: int = 0  # first k layers use a dense MLP (deepseek: 3)
    prefix_d_ff: int = 0  # dense-prefix hidden size (deepseek: 18432)

    # Numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    logits_dtype: str = "float32"

    # Execution strategy
    q_chunk: int = 512  # query-block size for chunked attention (0 = naive)
    scan_layers: bool = True
    remat: str = "full"  # none | full | dots (checkpoint policy per block)
    use_pallas: bool = False  # TPU kernels (validated via interpret on CPU)
    sharding_rules: str = "tp"  # tp | fsdp (see models/sharding.py)
    rules_overrides: dict[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.head_dim == 0 and self.num_heads > 0:
            self.head_dim = self.d_model // self.num_heads
        if self.mamba.dt_rank == 0:
            self.mamba.dt_rank = max(1, (self.d_model + 15) // 16)
        if self.moe.num_experts and self.moe.expert_d_ff == 0:
            self.moe.expert_d_ff = self.d_ff

    # -- derived -----------------------------------------------------------
    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM state, hybrid, or sliding-window."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def copy(self, **kw: Any) -> "ModelConfig":
        return replace(self, **kw)


@dataclass
class ShapeConfig:
    """One (input-shape) cell of the assignment grid."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass
class TrainConfig:
    """Optimizer / loop hyper-parameters (paper-independent substrate)."""

    optimizer: str = "adamw"  # adamw | adafactor
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatches: int = 1  # gradient accumulation
    seed: int = 0
    checkpoint_every: int = 50
    mtp_loss_weight: float = 0.3
