"""stablelm-3b [dense] — 32L d_model=2560 32H (GQA kv=32) d_ff=6912
vocab=50304 [hf:stabilityai/stablelm-2-1_6b; unverified]."""

from .base import ModelConfig

ARCH_ID = "stablelm-3b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        source="hf:stabilityai/stablelm-2-1_6b; unverified",
        num_layers=32,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,  # kv=32 -> plain MHA
        d_ff=6912,
        vocab_size=50304,
        attention="gqa",
        qkv_bias=False,
        rope_theta=10000.0,
        activation="swiglu",
        norm="layernorm",
        sharding_rules="tp",
    )


def smoke() -> ModelConfig:
    return full().copy(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=0,
        d_ff=176,
        vocab_size=256,
    )
