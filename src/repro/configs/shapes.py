"""Assigned input-shape suites and abstract input specs (dry-run plane).

Four cells per architecture (40 total):
  train_4k    : seq 4,096  x global_batch 256  -> train_step
  prefill_32k : seq 32,768 x global_batch 32   -> prefill (serve)
  decode_32k  : 1 new token, KV/state ctx 32,768, batch 128 -> serve_step
  long_500k   : 1 new token, ctx 524,288, batch 1 -> serve_step
                (sub-quadratic archs only: ssm / hybrid / SWA)

``input_specs`` returns weak-type-correct ShapeDtypeStructs — shardable,
zero allocation — matching exactly the pytrees the jitted step functions
take.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.model import abstract_cache
from .base import SHAPES, ModelConfig, ShapeConfig


class CellSkip(Exception):
    """This (arch x shape) cell is skipped by design; .reason says why."""

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


def cell_skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return (
            "long_500k requires sub-quadratic decode; "
            f"{cfg.name} is pure full-attention (see DESIGN.md §Arch-applicability)"
        )
    return None


def _mem_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Cross-attention memory length for vlm/audio archs."""
    if cfg.cross_attn_every > 0:
        return cfg.num_patches
    if cfg.is_encdec:
        return min(cfg.max_src_len, shape.seq_len)
    return 0


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Full-sequence inputs (train / prefill)."""
    b, s = shape.global_batch, shape.seq_len
    cdtype = jnp.dtype(cfg.compute_dtype)
    specs: dict = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.cross_attn_every > 0:
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_patches, cfg.vision_embed_dim), cdtype
        )
    if cfg.is_encdec:
        specs["src_frames"] = jax.ShapeDtypeStruct(
            (b, _mem_len(cfg, shape), cfg.audio_embed_dim), cdtype
        )
    return specs


def decode_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """serve_step inputs: one new token + cache at context length."""
    b, s = shape.global_batch, shape.seq_len
    cache = abstract_cache(cfg, b, s, mem_len=_mem_len(cfg, shape))
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "cache": cache,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig | str) -> dict:
    """Abstract inputs for the given cell; raises CellSkip when inapplicable."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    reason = cell_skip_reason(cfg, shape)
    if reason:
        raise CellSkip(reason)
    if shape.kind in ("train", "prefill"):
        return batch_specs(cfg, shape)
    return decode_specs(cfg, shape)
