"""deepseek-v3-671b [moe] — 61L d_model=7168 128H (GQA kv=128) d_ff=2048
vocab=129280, MoE 256e top-8 — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437; hf].

MLA caches only the 512-rank latent + 64-dim shared RoPE key per token
(decode uses matrix absorption). First 3 layers use a dense
18432-wide MLP (HF config); remaining 58 are MoE. MTP at depth 1.
"""

from .base import MLAConfig, ModelConfig, MoEConfig

ARCH_ID = "deepseek-v3-671b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        source="arXiv:2412.19437; hf",
        num_layers=58,  # + 3 dense-prefix layers = 61 total
        d_model=7168,
        num_heads=128,
        num_kv_heads=128,
        head_dim=128,
        d_ff=2048,
        vocab_size=129280,
        attention="mla",
        rope_theta=10000.0,
        activation="swiglu",
        norm="rmsnorm",
        mla=MLAConfig(
            q_lora_rank=1536,
            kv_lora_rank=512,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            num_experts=256,
            num_shared_experts=1,
            top_k=8,
            expert_d_ff=2048,
            moe_every=1,
            capacity_factor=1.25,
            group_size=2048,
        ),
        mtp_depth=1,
        dense_prefix_layers=3,
        prefix_d_ff=18432,
        sharding_rules="fsdp",
        # 256 experts / 16-wide model axis = 16 experts per shard (clean EP);
        # each expert's 2048-wide hidden is additionally sharded over "data"
        # (2048/16=128), so expert weights are 671B*2B/256 = 5.2 GB/chip
        # WITHOUT FSDP all-gathers inside the microbatch loop — the w_down
        # contraction instead pays one activation-sized all-reduce per MoE
        # layer (EXPERIMENTS.md §Perf deepseek iteration 1).
        rules_overrides={"expert_ffn": "data"},
    )


def smoke() -> ModelConfig:
    return full().copy(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=96,
        vocab_size=271,
        mla=MLAConfig(
            q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
            qk_rope_head_dim=8, v_head_dim=16,
        ),
        moe=MoEConfig(
            num_experts=8, num_shared_experts=1, top_k=2, expert_d_ff=96,
            moe_every=1, capacity_factor=2.0, group_size=64,
        ),
        dense_prefix_layers=1,
        prefix_d_ff=192,
        sharding_rules="tp",
    )
