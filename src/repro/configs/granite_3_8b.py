"""granite-3-8b [dense] — 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155 — GQA [hf:ibm-granite/granite-3.0-2b-base; hf]."""

from .base import ModelConfig

ARCH_ID = "granite-3-8b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        source="hf:ibm-granite/granite-3.0-2b-base; hf",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=12800,
        vocab_size=49155,
        attention="gqa",
        qkv_bias=False,
        rope_theta=10000.0,
        activation="swiglu",
        norm="rmsnorm",
        tied_embeddings=True,  # granite ties input/output embeddings
        sharding_rules="fsdp",
    )


def smoke() -> ModelConfig:
    return full().copy(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=0,
        d_ff=208,
        vocab_size=259,
        sharding_rules="tp",
    )
