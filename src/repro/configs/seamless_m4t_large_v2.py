"""seamless-m4t-large-v2 [audio] — 24L d_model=1024 16H (GQA kv=16)
d_ff=8192 vocab=256206 — enc-dec, multimodal [arXiv:2308.11596; hf].

Backbone only per the assignment: the speech frontend is a STUB —
``input_specs()`` provides precomputed frame embeddings (B, S_src, 1024).
24-layer bidirectional encoder + 24-layer decoder with per-layer
cross-attention.
"""

from .base import ModelConfig

ARCH_ID = "seamless-m4t-large-v2"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="audio",
        source="arXiv:2308.11596; hf",
        num_layers=24,  # decoder
        encoder_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=8192,
        vocab_size=256206,
        attention="gqa",
        activation="gelu",
        norm="layernorm",
        audio_embed_dim=1024,
        max_src_len=4096,
        sharding_rules="tp",
    )


def smoke() -> ModelConfig:
    return full().copy(
        num_layers=2,
        encoder_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=0,
        d_ff=256,
        vocab_size=517,
        audio_embed_dim=32,
        max_src_len=64,
    )
