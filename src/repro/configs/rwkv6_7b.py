"""rwkv6-7b [ssm] — 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536
— Finch, data-dependent decay [arXiv:2404.05892; hf]."""

from .base import ModelConfig, RwkvConfig

ARCH_ID = "rwkv6-7b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        source="arXiv:2404.05892; hf",
        num_layers=32,
        d_model=4096,
        num_heads=64,  # d_model / head_size
        num_kv_heads=64,
        d_ff=14336,
        vocab_size=65536,
        attention="none",
        norm="layernorm",  # rwkv reference uses LN
        rwkv=RwkvConfig(head_size=64, decay_lora=64, mix_lora=32),
        sharding_rules="fsdp",
    )


def smoke() -> ModelConfig:
    return full().copy(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=0,
        d_ff=224,
        vocab_size=256,
        rwkv=RwkvConfig(head_size=16, decay_lora=8, mix_lora=8),
        sharding_rules="tp",
    )
