"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152 — GQA, RoPE [arXiv:2402.19173; hf]."""

from .base import ModelConfig

ARCH_ID = "starcoder2-15b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        source="arXiv:2402.19173; hf",
        num_layers=40,
        d_model=6144,
        num_heads=48,
        num_kv_heads=4,
        d_ff=24576,
        vocab_size=49152,
        attention="gqa",
        qkv_bias=True,
        rope_theta=100000.0,
        activation="gelu",  # plain 4x MLP (d_ff = 4 d_model)
        norm="layernorm",
        sharding_rules="fsdp",
    )


def smoke() -> ModelConfig:
    return full().copy(
        num_layers=2,
        d_model=96,
        num_heads=6,
        num_kv_heads=2,
        head_dim=0,
        d_ff=384,
        vocab_size=257,
        sharding_rules="tp",
    )
