"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=128256 — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

The vision frontend is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings (B, 1601, 1280); every 5th layer is
a tanh-gated cross-attention layer over them (8 of 40).
"""

from .base import ModelConfig

ARCH_ID = "llama-3.2-vision-11b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=128256,
        attention="gqa",
        rope_theta=500000.0,
        activation="swiglu",
        norm="rmsnorm",
        cross_attn_every=5,
        vision_embed_dim=1280,
        num_patches=1601,
        sharding_rules="fsdp",
    )


def smoke() -> ModelConfig:
    return full().copy(
        num_layers=5,  # one cross-attn group
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=0,
        d_ff=224,
        vocab_size=256,
        vision_embed_dim=32,
        num_patches=17,
        sharding_rules="tp",
    )
