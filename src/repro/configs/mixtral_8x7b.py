"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8e top-2 — 8 experts top-2, SWA [arXiv:2401.04088; hf].

Sliding-window attention (4096) makes decode O(window): the KV cache is a
ring buffer, so the long_500k cell runs with constant memory.
8 experts < the 16-wide model axis, so EP shards each expert's d_ff
instead of the expert dim (see rules_overrides).
"""

from .base import ModelConfig, MoEConfig

ARCH_ID = "mixtral-8x7b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        source="arXiv:2401.04088; hf",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        attention="gqa",
        rope_theta=1000000.0,
        sliding_window=4096,
        activation="swiglu",
        norm="rmsnorm",
        moe=MoEConfig(
            num_experts=8,
            top_k=2,
            expert_d_ff=14336,
            moe_every=1,
            capacity_factor=1.25,
            group_size=2048,
        ),
        sharding_rules="fsdp",
        rules_overrides={"experts": None, "expert_ffn": "model", "expert_embed": "data"},
    )


def smoke() -> ModelConfig:
    return full().copy(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=0,
        d_ff=192,
        vocab_size=256,
        sliding_window=8,
        moe=MoEConfig(
            num_experts=4, top_k=2, expert_d_ff=192, moe_every=1,
            capacity_factor=2.0, group_size=64,
        ),
        sharding_rules="tp",
        rules_overrides={},
    )
