"""Architecture registry: ``--arch <id>`` resolves here."""

from __future__ import annotations

from . import (
    deepseek_v3_671b,
    granite_3_8b,
    jamba_1_5_large_398b,
    llama_3_2_vision_11b,
    mixtral_8x7b,
    qwen2_5_14b,
    rwkv6_7b,
    seamless_m4t_large_v2,
    stablelm_3b,
    starcoder2_15b,
)
from .base import SHAPES, ModelConfig, ShapeConfig, TrainConfig

_MODULES = [
    starcoder2_15b,
    qwen2_5_14b,
    stablelm_3b,
    granite_3_8b,
    jamba_1_5_large_398b,
    rwkv6_7b,
    llama_3_2_vision_11b,
    mixtral_8x7b,
    deepseek_v3_671b,
    seamless_m4t_large_v2,
]

ARCHS: dict[str, object] = {m.ARCH_ID: m for m in _MODULES}
ARCH_IDS: list[str] = list(ARCHS.keys())


def get_config(arch: str, variant: str = "full") -> ModelConfig:
    """variant: 'full' (assignment config) or 'smoke' (reduced, CPU-runnable)."""
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = ARCHS[arch]
    if variant == "full":
        return mod.full()
    if variant == "smoke":
        return mod.smoke()
    raise KeyError(f"unknown variant {variant!r} (full|smoke)")


__all__ = [
    "ARCHS",
    "ARCH_IDS",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "TrainConfig",
    "get_config",
]
