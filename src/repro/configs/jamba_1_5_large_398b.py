"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887; hf].

Layout: period-8 groups (attention at index 4, Mamba elsewhere), MoE
replaces the MLP on every other layer — 9 scanned groups of 8 layers.
Jamba uses no explicit positional encoding (the Mamba layers carry it).
"""

from .base import MambaConfig, ModelConfig, MoEConfig

ARCH_ID = "jamba-1.5-large-398b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        source="arXiv:2403.19887; hf",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=24576,
        vocab_size=65536,
        attention="gqa",
        use_rope=False,
        activation="swiglu",
        norm="rmsnorm",
        hybrid_period=8,
        hybrid_attn_index=4,
        mamba=MambaConfig(state_dim=16, conv_width=4, expand=2),
        moe=MoEConfig(
            num_experts=16,
            top_k=2,
            expert_d_ff=24576,
            moe_every=2,
            capacity_factor=1.25,
            group_size=2048,
        ),
        sharding_rules="fsdp",
        # 16 experts == 16-wide model axis: clean expert parallelism; the
        # 24576-wide expert hidden additionally shards over "data" so MoE
        # weights are 3.1 GB/chip with no FSDP re-gather per microbatch.
        rules_overrides={"expert_ffn": "data"},
    )


def smoke() -> ModelConfig:
    return full().copy(
        num_layers=8,  # one period group
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=0,
        d_ff=192,
        vocab_size=256,
        mamba=MambaConfig(state_dim=4, conv_width=4, expand=2),
        moe=MoEConfig(
            num_experts=4, top_k=2, expert_d_ff=192, moe_every=2,
            capacity_factor=2.0, group_size=64,
        ),
        sharding_rules="tp",
    )
