"""Mamba-1 selective-scan Pallas TPU kernel.

TPU adaptation of the hardware-aware CUDA scan: the recurrent state
h (d_block x N) is VMEM scratch carried across the sequential chunk grid
dimension; the discretized (C x d_block x N) tensors exist only in VMEM,
one chunk at a time — HBM traffic is dt/x (C x d_block), B/C (C x N) in
and y (C x d_block) out, never the O(T x d x N) expansion.

Grid: (batch, d_inner/d_block, T/C). d_inner is tiled so arbitrarily wide
models (jamba: 16384) keep the VMEM working set fixed; lane dim is the
SSM state N (16) padded into the (8,128)-tile by the compiler.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(
    dt_ref, x_ref, b_ref, c_ref, a_ref, h0_ref, y_ref, hout_ref, h_scr,
    *, chunk: int, nchunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    dt = dt_ref[0].astype(jnp.float32)  # (C, Db)
    x = x_ref[0].astype(jnp.float32)  # (C, Db)
    bmat = b_ref[0].astype(jnp.float32)  # (C, N)
    cmat = c_ref[0].astype(jnp.float32)  # (C, N)
    a = a_ref[...].astype(jnp.float32)  # (Db, N)

    da = jnp.exp(dt[:, :, None] * a[None, :, :])  # (C, Db, N)
    dbx = (dt * x)[:, :, None] * bmat[:, None, :]  # (C, Db, N)

    # intra-chunk associative scan over time (log-depth on the VPU)
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    acc_a, acc_b = jax.lax.associative_scan(combine, (da, dbx), axis=0)
    h_all = acc_a * h_scr[...][None] + acc_b  # (C, Db, N)
    y = jnp.sum(h_all * cmat[:, None, :], axis=2)  # (C, Db)
    h_scr[...] = h_all[-1]
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ic == nchunks - 1)
    def _final():
        hout_ref[0] = h_scr[...].astype(hout_ref.dtype)


def mamba_chunk_scan_b(
    dt: jnp.ndarray,  # (B, T, DI) fp32
    bmat: jnp.ndarray,  # (B, T, N)
    cmat: jnp.ndarray,  # (B, T, N)
    a: jnp.ndarray,  # (DI, N)
    x: jnp.ndarray,  # (B, T, DI)
    h0: jnp.ndarray,  # (B, DI, N)
    *,
    chunk: int = 64,
    d_block: int = 512,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    bsz, t, di = dt.shape
    n = a.shape[-1]
    chunk = min(chunk, t)
    d_block = min(d_block, di)
    assert t % chunk == 0 and di % d_block == 0, (t, chunk, di, d_block)
    nchunks = t // chunk
    nd = di // d_block
    kernel = functools.partial(_mamba_kernel, chunk=chunk, nchunks=nchunks)
    y, hout = pl.pallas_call(
        kernel,
        grid=(bsz, nd, nchunks),
        in_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, d_block), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, n), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((d_block, n), lambda b, d, c: (d, 0)),
            pl.BlockSpec((1, d_block, n), lambda b, d, c: (b, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, d_block, n), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, t, di), jnp.float32),
            jax.ShapeDtypeStruct((bsz, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d_block, n), jnp.float32)],
        interpret=interpret,
    )(dt, x, bmat, cmat, a, h0)
    return y, hout
