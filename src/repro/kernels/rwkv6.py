"""RWKV-6 chunked WKV Pallas TPU kernel.

TPU adaptation of the CUDA wkv6 kernel: grid is (B*H, T/C); the (K x V)
state matrix is VMEM scratch carried across the sequential chunk
dimension. Within a chunk, decay ratios are computed pairwise in log
space — exp(cum_{t-1} - cum_s) <= 1 for s < t — so the kernel never
overflows regardless of decay magnitude (the CUDA kernel's rescaling
tricks become unnecessary). All chunk-local tensors (C x K scores,
C x C attention) live in VMEM; HBM traffic is r/k/v/w in, out + final
state out.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(
    r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, s_final_ref, s_scr,
    *, chunk: int, nchunks: int,
):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)  # (C, K)
    k = k_ref[0].astype(jnp.float32)  # (C, K)
    v = v_ref[0].astype(jnp.float32)  # (C, V)
    lw = w_ref[0].astype(jnp.float32)  # (C, K) = log decay, <= 0
    u = u_ref[0].astype(jnp.float32)  # (1, K) bonus

    cum = jnp.cumsum(lw, axis=0)  # (C, K)
    cum_prev = cum - lw

    # Intra-chunk pairwise scores: A[t, s] = sum_k r[t]k[s]exp(cum_prev[t]-cum[s])
    diff = cum_prev[:, None, :] - cum[None, :, :]  # (C, C, K), <= 0 for s < t
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    ratio = jnp.where(tri[:, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("tk,sk,tsk->ts", r, k, ratio)  # (C, C)
    diag = jnp.sum(r * u * k, axis=1)  # (C,) bonus term
    out = jnp.dot(scores, v, preferred_element_type=jnp.float32)
    out = out + diag[:, None] * v

    # Cross-chunk: r decayed against incoming state
    s0 = s_scr[...]  # (K, V)
    rw = r * jnp.exp(cum_prev)  # (C, K)
    out = out + jnp.dot(rw, s0, preferred_element_type=jnp.float32)

    # State update: S' = diag(exp(cum_C)) S + sum_s exp(cum_C - cum_s) k_s v_s
    tail = jnp.exp(cum[-1][None, :] - cum)  # (C, K)
    s_scr[...] = jnp.exp(cum[-1])[:, None] * s0 + jnp.dot(
        (k * tail).T, v, preferred_element_type=jnp.float32
    )

    o_ref[0] = out.astype(o_ref.dtype)

    @pl.when(ic == nchunks - 1)
    def _final():
        s_final_ref[0] = s_scr[...].astype(s_final_ref.dtype)


def rwkv6_chunked_bh(
    r: jnp.ndarray,  # (BH, T, K) fp32
    k: jnp.ndarray,
    v: jnp.ndarray,  # (BH, T, V)
    logw: jnp.ndarray,  # (BH, T, K)
    u: jnp.ndarray,  # (BH, 1, K) per-head bonus (pre-broadcast)
    s0: jnp.ndarray,  # (BH, K, V) incoming state
    *,
    chunk: int = 32,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    bh, t, dk = r.shape
    dv = v.shape[-1]
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    nchunks = t // chunk
    kernel = functools.partial(_wkv_kernel, chunk=chunk, nchunks=nchunks)
    out, s_final = pl.pallas_call(
        kernel,
        grid=(bh, nchunks),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, dk), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, dv), r.dtype),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, s0)
    return out, s_final
