"""Jit'd wrappers: model-facing shapes -> kernel layouts (+ auto interpret).

``interpret`` defaults to True off-TPU so the same call sites run the
kernel bodies in Python on CPU (correctness) and compile natively on TPU.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd
from .mamba_scan import mamba_chunk_scan_b
from .rwkv6 import rwkv6_chunked_bh


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(
    q: jnp.ndarray,  # (B, S, H, D)
    k: jnp.ndarray,  # (B, S, KV, D)
    v: jnp.ndarray,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
) -> jnp.ndarray:
    b, s, h, d = q.shape
    kv = k.shape[2]
    group = h // kv
    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, s, d)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * kv, s, d)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * kv, s, d)
    out = flash_attention_bhsd(
        qf, kf, vf, group=group, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret(),
    )
    return jnp.moveaxis(out.reshape(b, h, s, d), 1, 2)


@partial(jax.jit, static_argnames=("chunk",))
def rwkv6_chunked(
    r: jnp.ndarray,  # (B, T, H, K) fp32
    k: jnp.ndarray,
    v: jnp.ndarray,
    logw: jnp.ndarray,
    u: jnp.ndarray,  # (H, K)
    s0: jnp.ndarray,  # (B, H, K, V)
    chunk: int = 32,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    b, t, h, dk = r.shape
    dv = v.shape[-1]

    def flat(x):
        return jnp.moveaxis(x, 2, 1).reshape(b * h, t, x.shape[-1])

    uf = jnp.broadcast_to(u[None], (b, h, dk)).reshape(b * h, 1, dk)
    out, s_final = rwkv6_chunked_bh(
        flat(r), flat(k), flat(v), flat(logw), uf,
        s0.reshape(b * h, dk, dv).astype(jnp.float32), chunk=chunk,
        interpret=_interpret(),
    )
    out = jnp.moveaxis(out.reshape(b, h, t, dv), 1, 2)
    return out, s_final.reshape(b, h, dk, dv)


@partial(jax.jit, static_argnames=("chunk", "d_block"))
def mamba_chunk_scan(
    dt: jnp.ndarray,  # (B, T, DI) fp32
    bmat: jnp.ndarray,  # (B, T, N)
    cmat: jnp.ndarray,
    a: jnp.ndarray,  # (DI, N)
    x: jnp.ndarray,  # (B, T, DI)
    h0: jnp.ndarray,  # (B, DI, N)
    chunk: int = 64,
    d_block: int = 512,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    di = dt.shape[-1]
    d_block = min(d_block, di)
    while di % d_block:
        d_block -= 1
    return mamba_chunk_scan_b(
        dt, bmat, cmat, a, x.astype(jnp.float32), h0,
        chunk=chunk, d_block=d_block, interpret=_interpret(),
    )
