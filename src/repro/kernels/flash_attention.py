"""Flash attention Pallas TPU kernel (GQA + causal + sliding window).

TPU adaptation of the standard flash algorithm: the (q_block, kv_block)
score tile lives only in VMEM; online-softmax running max/denominator are
VMEM scratch carried across the kv grid dimension (TPU grid iterations
execute sequentially, minor-most last). HBM traffic is exactly Q, K, V,
O — the score matrix never round-trips, which is what moves the
attention-heavy cells from memory-bound toward compute-bound (§Perf).

Layout decisions for the MXU/VPU:
  * block_q x head_dim and block_k x head_dim tiles are (128x128)-aligned
    by default (MXU native).
  * running m/l are (block_q, 128) f32 — lane-replicated, VPU-friendly.
  * GQA maps query head h to kv head h // group via the K/V index_map, so
    grouped heads re-read the same KV tile from HBM only once per group
    when the pipeline caches the block.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: int,
    block_q: int, block_k: int, nk: int,
):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (Bq, D)
    k = k_ref[0].astype(jnp.float32)  # (Bk, D)
    v = v_ref[0].astype(jnp.float32)  # (Bk, Dv)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (Bq, Bk)

    iq = pl.program_id(1)
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]  # (Bq, 128) lane-replicated
    l_prev = l_scr[...]
    m_blk = jnp.max(s, axis=1, keepdims=True)  # (Bq, 1)
    m_cur = jnp.maximum(m_prev, jnp.broadcast_to(m_blk, m_prev.shape))
    correction = jnp.exp(m_prev - m_cur)  # (Bq, 128)
    p = jnp.exp(s - m_cur[:, :1])  # (Bq, Bk)
    p = jnp.where(mask, p, 0.0)
    l_cur = l_prev * correction + jnp.broadcast_to(
        jnp.sum(p, axis=1, keepdims=True), l_prev.shape
    )
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Bq, Dv)
    acc_scr[...] = acc_scr[...] * correction[:, : acc_scr.shape[-1]][:, :1] + pv
    m_scr[...] = m_cur
    l_scr[...] = l_cur

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...][:, :1], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jnp.ndarray,  # (BH, S, D)
    k: jnp.ndarray,  # (BKV, S, D)
    v: jnp.ndarray,  # (BKV, S, D)
    *,
    group: int,  # q heads per kv head
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    bh, s, d = q.shape
    scale = d ** -0.5
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nq, nk = s // block_q, s // block_k

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),  # running max m
            pltpu.VMEM((block_q, 128), jnp.float32),  # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
