"""Pure-jnp oracles for every kernel — deliberately naive/sequential so
correctness is obvious; tests assert_allclose kernels against these."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jnp.ndarray,  # (B, S, H, D)
    k: jnp.ndarray,  # (B, S, KV, D)
    v: jnp.ndarray,
    causal: bool = True,
    window: int = 0,
) -> jnp.ndarray:
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32)) * d**-0.5
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)


def rwkv6_ref(
    r: jnp.ndarray,  # (B, T, H, K) fp32
    k: jnp.ndarray,
    v: jnp.ndarray,  # (B, T, H, V)
    logw: jnp.ndarray,  # (B, T, H, K)
    u: jnp.ndarray,  # (H, K)
    s0: jnp.ndarray,  # (B, H, K, V)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Token-by-token recurrence:
    out_t = r_t (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T.
    """

    def step(s, inp):
        rt, kt, vt, lwt = inp  # (B, H, K/V)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s_new = jnp.exp(lwt)[..., None] * s + kv
        return s_new, out

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (r, k, v, logw))
    s_final, out = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(out, 0, 1), s_final


def mamba_scan_ref(
    dt: jnp.ndarray,  # (B, T, DI) fp32
    bmat: jnp.ndarray,  # (B, T, N)
    cmat: jnp.ndarray,  # (B, T, N)
    a: jnp.ndarray,  # (DI, N)
    x: jnp.ndarray,  # (B, T, DI)
    h0: jnp.ndarray,  # (B, DI, N)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t;  y_t = C_t · h_t."""

    def step(h, inp):
        dtt, xt, bt, ct = inp
        da = jnp.exp(dtt[:, :, None] * a[None])  # (B, DI, N)
        h = da * h + (dtt * xt)[:, :, None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (dt, x, bmat, cmat))
    h_final, y = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(y, 0, 1), h_final
