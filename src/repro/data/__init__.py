"""repro.data subpackage."""
