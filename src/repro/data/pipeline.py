"""Deterministic synthetic data pipeline with background prefetch.

Stands in for a tokenized corpus: batches are generated from a counter-
keyed PRNG, so every (step, shard) is reproducible across restarts —
which the failsafe/restart integration tests rely on. A background
thread keeps a small prefetch queue full, overlapping host-side batch
synthesis with device compute (the same structure a real corpus loader
would have).
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig


class SyntheticTokens:
    """Markov-ish synthetic token stream (not uniform noise: CE can drop)."""

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int, seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(self.seed * 1_000_003 + step)
        v = self.cfg.vocab_size
        b, s = self.batch, self.seq_len
        # structured stream: tok_{t+1} = (a*tok_t + c + noise) % V — learnable
        a = 31
        toks = np.empty((b, s), np.int32)
        toks[:, 0] = rng.integers(0, v, b)
        noise = (rng.random((b, s)) < 0.1) * rng.integers(1, v, (b, s))
        for t in range(1, s):
            toks[:, t] = (a * toks[:, t - 1] + 7 + noise[:, t]) % v
        out = {"tokens": toks}
        if self.cfg.cross_attn_every > 0:
            out["image_embeds"] = rng.standard_normal(
                (b, self.cfg.num_patches, self.cfg.vision_embed_dim), np.float32
            ).astype(np.dtype(self.cfg.compute_dtype))
        if self.cfg.is_encdec:
            src = min(self.cfg.max_src_len, s)
            out["src_frames"] = rng.standard_normal(
                (b, src, self.cfg.audio_embed_dim), np.float32
            ).astype(np.dtype(self.cfg.compute_dtype))
        return out


class Prefetcher:
    """Background-thread prefetch queue over a step-indexed source."""

    def __init__(self, source: SyntheticTokens, start_step: int = 0, depth: int = 2):
        self.source = source
        self.queue: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.queue.put((step, batch), timeout=0.2)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        while not self._stop.is_set():
            yield self.queue.get()

    def next(self) -> tuple[int, dict]:
        return self.queue.get()

    def stop(self) -> None:
        self._stop.set()
        try:
            while True:
                self.queue.get_nowait()
        except queue.Empty:
            pass


def device_put_batch(batch: dict, mesh=None, rules=None) -> dict:
    """Host batch -> device arrays, sharded batch-dim over (pod, data)."""
    if mesh is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    out = {}
    for k, v in batch.items():
        spec = P(axes if axes else None)
        out[k] = jax.device_put(jnp.asarray(v), NamedSharding(mesh, spec))
    return out
