"""repro.runtime subpackage."""
