"""JAX executors — the meta-description → executable translation layer.

The paper's executors translate function specifications into Kubernetes
deployments or Slurm scripts; ours translate them into jitted JAX
programs. A ``train`` spec becomes a checkpointed training loop; an
``evaluate`` spec becomes an eval sweep from the latest CFS checkpoint;
``generate_batch`` (fired by the dynamic-batching generator) becomes one
batched inference call.

Fault tolerance is the broker's: each handler resumes from the latest
CFS checkpoint, so a ``maxexectime`` re-assignment after an executor
crash loses at most ``checkpoint_every`` steps.
"""

from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import TrainConfig, get_config
from ..core.client import Colonies
from ..core.errors import ValidationError
from ..core.executor import ExecutorBase, ProcessContext
from ..core.fs import CFSClient, Storage
from ..core.retry import RetryPolicy
from ..data.pipeline import SyntheticTokens
from ..train.checkpoint import CheckpointManager
from ..train.train_step import init_state, make_eval_step, make_train_step
from ..models import init_params, model_spec
from .chaos import SimulatedCrash

# Default blob-plane retry: generous enough to ride out one storage
# shard dying mid-operation (ShardedStorage already tolerates R−1 shard
# failures per call; this covers the window where ALL of a key's
# replicas are briefly unreachable).
BLOB_RETRY = RetryPolicy(base_s=0.01, cap_s=0.25, deadline_s=10.0, budget=6)


class JaxExecutorBase(ExecutorBase):
    """ExecutorBase + CFS access + crash simulation support.

    Implements the paper's fs sync directives (§3.4.5, Listing 2): before
    a handler runs, every ``fs.snapshots`` entry is materialized and
    every ``fs.dirs`` entry synced down into the process workdir; after
    it succeeds, ``fs.dirs`` entries with ``upload`` sync back up. All
    blob traffic is retry-backed (see BLOB_RETRY / CFSClient).
    """

    def __init__(self, client: Colonies, colonyname: str, executorname: str,
                 executortype: str, storage: Storage, colony_prvkey: str | None = None,
                 blob_retry: RetryPolicy | None = BLOB_RETRY, **kw: Any) -> None:
        super().__init__(client, colonyname, executorname, executortype,
                         colony_prvkey=colony_prvkey, **kw)
        self.storage = storage
        self.cfs = CFSClient(client, storage, self.prvkey, retry=blob_retry)

    def _execute(self, process) -> None:  # crash passthrough for chaos tests
        try:
            super()._execute(process)
        except SimulatedCrash:
            self.failed += 1  # vanish without closing — failsafe must recover

    # ------------------------------------------------- fs sync directives
    def _mount_dir(self, ctx: ProcessContext, directive_dir: str) -> str:
        """Resolve a directive's ``dir`` inside the process workdir.

        ``dir`` is relative to ``fs.mount`` (absolute paths are
        re-anchored by stripping the mount prefix); the result must stay
        inside the workdir — directives are part of the untrusted spec.
        """
        fs = ctx.process.spec.fs
        d = directive_dir or ""
        if fs.mount and d.startswith(fs.mount):
            d = d[len(fs.mount):]
        d = d.lstrip("/")
        base = ctx.workdir or "."
        for comp in d.split("/"):
            if comp in (".", "..") or "\\" in comp:
                raise ValidationError(f"unsafe fs directive dir {directive_dir!r}")
        dest = os.path.join(base, d) if d else base
        os.makedirs(dest, exist_ok=True)
        return dest

    def _sync_before(self, ctx: ProcessContext) -> None:
        fs = ctx.process.spec.fs
        for snap in fs.snapshots:
            self.cfs.materialize_snapshot(
                self.colonyname, snap.snapshotid, self._mount_dir(ctx, snap.dir)
            )
        for d in fs.dirs:
            self.cfs.sync_down(self.colonyname, d.label, self._mount_dir(ctx, d.dir))

    def _sync_after(self, ctx: ProcessContext) -> None:
        for d in ctx.process.spec.fs.dirs:
            if d.upload:
                self.cfs.sync_up(
                    self.colonyname, d.label, self._mount_dir(ctx, d.dir)
                )


def _smoke_cfg(kwargs: dict):
    cfg = get_config(kwargs["arch"], kwargs.get("variant", "smoke"))
    # CPU smoke numerics
    return cfg.copy(param_dtype="float32", compute_dtype="float32",
                    use_pallas=bool(kwargs.get("use_pallas", False)))


class TrainerExecutor(JaxExecutorBase):
    """Handles ``train`` and ``evaluate`` function specs."""

    def __init__(self, *args: Any, die_at_step: int | None = None, **kw: Any) -> None:
        super().__init__(*args, **kw)
        self.die_at_step = die_at_step
        self.register_function("train", self.train)
        self.register_function("evaluate", self.evaluate)

    # ------------------------------------------------------------------ train
    def train(self, ctx: ProcessContext, **kw: Any) -> list[Any]:
        cfg = _smoke_cfg(kw)
        steps = int(kw.get("steps", 10))
        batch_size = int(kw.get("batch", 4))
        seq_len = int(kw.get("seq_len", 64))
        run = kw.get("run", "run0")
        tcfg = TrainConfig(
            optimizer=kw.get("optimizer", "adamw"),
            learning_rate=float(kw.get("learning_rate", 3e-4)),
            warmup_steps=int(kw.get("warmup_steps", 10)),
            total_steps=steps,
            microbatches=int(kw.get("microbatches", 1)),
            checkpoint_every=int(kw.get("checkpoint_every", 5)),
            seed=int(kw.get("seed", 0)),
        )
        ckpt = CheckpointManager(self.cfs, self.colonyname, run=run)
        data = SyntheticTokens(cfg, batch_size, seq_len, seed=tcfg.seed)

        params = init_params(jax.random.key(tcfg.seed), model_spec(cfg), jnp.float32)
        state = init_state(params, tcfg)
        start = 0
        restored = ckpt.restore_latest(state)
        if restored is not None:
            state, start = restored
            start += 1  # resume after the checkpointed step
        step_fn = jax.jit(make_train_step(cfg, tcfg))

        last_metrics: dict = {}
        for step in range(start, steps):
            if self.die_at_step is not None and step == self.die_at_step:
                self.die_at_step = None  # a respawned clone must survive
                raise SimulatedCrash(f"chaos at step {step}")
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
            state, metrics = step_fn(state, batch)
            last_metrics = {k: float(v) for k, v in metrics.items()}
            if (step + 1) % tcfg.checkpoint_every == 0 or step == steps - 1:
                ckpt.save(state, step, async_=False)
        return [{"final_step": steps - 1, "metrics": last_metrics, "run": run}]

    # --------------------------------------------------------------- evaluate
    def evaluate(self, ctx: ProcessContext, **kw: Any) -> list[Any]:
        cfg = _smoke_cfg(kw)
        run = kw.get("run", "run0")
        batch_size = int(kw.get("batch", 4))
        seq_len = int(kw.get("seq_len", 64))
        batches = int(kw.get("eval_batches", 2))
        tcfg = TrainConfig(seed=int(kw.get("seed", 0)))
        ckpt = CheckpointManager(self.cfs, self.colonyname, run=run)
        params = init_params(jax.random.key(tcfg.seed), model_spec(cfg), jnp.float32)
        state = init_state(params, tcfg)
        restored = ckpt.restore_latest(state)
        if restored is None:
            raise RuntimeError(f"no checkpoint for run {run}")
        state, step = restored
        eval_fn = jax.jit(make_eval_step(cfg, tcfg))
        data = SyntheticTokens(cfg, batch_size, seq_len, seed=9999)
        ces = []
        for i in range(batches):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            ces.append(float(eval_fn(state["params"], batch)["ce"]))
        return [{"step": step, "eval_ce": float(np.mean(ces)), "run": run}]


class ServeExecutor(JaxExecutorBase):
    """Hosts a ServeEngine; handles generator-fired ``generate_batch``."""

    def __init__(self, *args: Any, arch: str = "stablelm-3b", max_len: int = 128,
                 run: str | None = None, **kw: Any) -> None:
        super().__init__(*args, **kw)
        from ..serve.batcher import make_batch_handler
        from ..serve.engine import ServeEngine

        cfg = _smoke_cfg({"arch": arch})
        params = init_params(jax.random.key(0), model_spec(cfg), jnp.float32)
        if run is not None:  # serve a trained checkpoint (continuum hand-off)
            from ..train.train_step import init_state as _init

            ckpt = CheckpointManager(self.cfs, self.colonyname, run=run)
            tcfg = TrainConfig()
            restored = ckpt.restore_latest(_init(params, tcfg))
            if restored is not None:
                params = restored[0]["params"]
        self.engine = ServeEngine(cfg, params, max_len=max_len)
        self.register_function(
            "generate_batch", make_batch_handler(self.engine, self.cfs, self.colonyname)
        )


class DataExecutor(JaxExecutorBase):
    """'Edge' executor: ingests (synthesizes) raw data into CFS."""

    def __init__(self, *args: Any, **kw: Any) -> None:
        super().__init__(*args, **kw)
        self.register_function("prepare_data", self.prepare_data)

    def prepare_data(self, ctx: ProcessContext, **kw: Any) -> list[Any]:
        import json

        shards = int(kw.get("shards", 2))
        tokens_per_shard = int(kw.get("tokens_per_shard", 1024))
        label = kw.get("label", "/datasets/synth")
        rng = np.random.default_rng(int(kw.get("seed", 0)))
        uploaded = []
        for i in range(shards):
            toks = rng.integers(0, int(kw.get("vocab", 256)), tokens_per_shard, dtype=np.int32)
            meta = self.cfs.upload_bytes(
                self.colonyname, label, f"shard-{i:04d}.bin", toks.tobytes()
            )
            uploaded.append(meta["fileid"])
        snap = self.cfs.client.create_snapshot(
            self.colonyname, label, kw.get("snapshot_name", "dataset-v1"), self.prvkey
        )
        return [{"snapshotid": snap["snapshotid"], "files": len(uploaded)}]
