"""Chaos engineering utilities (paper §3.4: "a Chaos monkey can be used
to deliberately terminate executors... the constant flux of executor
replacements ensures the system gracefully tolerates failures")."""

from __future__ import annotations

import random
import threading
import time
from typing import Callable


class SimulatedCrash(Exception):
    """Raised inside a handler to emulate sudden executor death:
    the process is NOT closed/failed — the broker's maxexectime failsafe
    must detect the lost lease and re-queue the process."""

    simulate_crash = True  # ExecutorBase re-raises instead of closing


class ChaosMonkey:
    """Randomly kills (stops) executors from a pool and spawns replacements."""

    def __init__(
        self,
        kill: Callable[[], None],
        spawn: Callable[[], None],
        interval: tuple[float, float] = (0.5, 2.0),
        seed: int = 0,
    ) -> None:
        self.kill = kill
        self.spawn = spawn
        self.interval = interval
        self.rng = random.Random(seed)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.kills = 0

    def start(self) -> None:
        def loop() -> None:
            while not self._stop.wait(self.rng.uniform(*self.interval)):
                try:
                    self.kill()
                    self.kills += 1
                    self.spawn()
                except Exception:  # noqa: BLE001 — chaos must not crash itself
                    pass

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)
