"""Deterministic fault injection for the RPC path (ROBUSTNESS.md).

The paper's failsafe story (§3.4) assumes executors die at any moment;
this module lets tests make the *rest* of the path — transports, the
server's dispatch/commit/reply window, database commits, raft ticks —
just as unreliable, deterministically.

Named fault points are compiled into the code (see the catalog below);
each is a single ``faults.hit(site, **ctx)`` call that reads one module
global and returns immediately when no plan is installed — zero cost in
production. A test activates a :class:`FaultPlan` (a list of
:class:`FaultRule` schedules plus a seeded RNG for probabilistic soak
rules) via the ``active()`` context manager; no environment variables
are involved.

Fault-point catalog (site → where it fires):

* ``transport.send``    — client transport, before the request is
  delivered (a fault here means the server never saw the request).
* ``transport.recv``    — client transport, after the reply was produced
  (a fault here means the server committed but the client never heard).
* ``server.pre_dispatch``  — ``ColoniesServer.handle``, after envelope
  verification but before the handler (and before the idempotency-replay
  check): the request dies server-side with no effect.
* ``server.post_commit`` — ``ColoniesServer.handle``, after the handler
  committed *and* the dedup record was written, before the reply is
  returned: the classic crash-after-commit-before-reply window.
* ``db.commit``         — entry of ``add_process`` / ``update_process``
  (both backends): the write itself fails.
* ``raft.tick``         — the HA event loop, once per tick: a raised
  fault skips the tick, a delay stalls it (forcing election churn).
* ``blob.put`` / ``blob.get`` — the CFS blob plane, once per
  child-shard operation inside ``ShardedStorage`` (ctx carries
  ``shard`` and ``key``): a raised fault models a dead storage shard,
  which puts tolerate (R−1 replicas may fail) and gets rotate past
  (read-repair rewrites the copies observed broken; see STORAGE.md).

Actions:

* ``drop`` / ``reset`` / ``crash`` — raise :class:`FaultInjected`
  (a ``ConnectionError``) at the site. The three names describe intent
  at different sites (request lost / connection reset before reply /
  process died) but behave identically; transports translate the raise
  into a retryable 503.
* ``delay`` — sleep ``delay_s`` seconds at the site, then continue.
* ``duplicate`` — returned to the caller as the string ``"duplicate"``;
  transports respond by delivering the request twice (at-least-once
  delivery made flesh).
"""

from __future__ import annotations

import random
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field

from ..analysis.locktrack import make_lock

RAISING_ACTIONS = frozenset({"drop", "reset", "crash"})
ACTIONS = RAISING_ACTIONS | {"delay", "duplicate"}

SITES = frozenset(
    {
        "transport.send",
        "transport.recv",
        "server.pre_dispatch",
        "server.post_commit",
        "db.commit",
        "raft.tick",
        "blob.put",
        "blob.get",
    }
)


class FaultInjected(ConnectionError):
    """Raised at a fault point for drop/reset/crash actions.

    Deliberately NOT a ColoniesError: it models infrastructure failure,
    so server dispatch never converts it into a clean RPC error reply —
    transports see a dead connection, exactly like the real thing.
    """


@dataclass
class FaultRule:
    """One scheduled fault: fire ``action`` at ``site``.

    Deterministic scheduling: the rule matches its ``site`` (and
    ``payloadtype``/``match`` if set), skips the first ``after``
    matches, then fires on the next ``times`` matches (``None`` =
    forever). ``prob`` < 1 makes firing probabilistic via the plan's
    seeded RNG — same seed, same schedule.
    """

    site: str
    action: str
    payloadtype: str | None = None  # match ctx["payloadtype"] when set
    match: dict = field(default_factory=dict)  # extra ctx equality matches
    after: int = 0  # skip the first N matching hits
    times: int | None = 1  # fire on at most N hits (None = unlimited)
    delay_s: float = 0.01  # for action == "delay"
    prob: float = 1.0  # firing probability (plan RNG)
    # counters (managed by the plan, under its lock)
    matched: int = 0
    fired: int = 0

    def __post_init__(self) -> None:
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r} (see {sorted(SITES)})")
        if self.action not in ACTIONS:
            raise ValueError(f"unknown fault action {self.action!r}")

    def _matches(self, site: str, ctx: dict) -> bool:
        if site != self.site:
            return False
        if self.payloadtype is not None and ctx.get("payloadtype") != self.payloadtype:
            return False
        for k, v in self.match.items():
            if ctx.get(k) != v:
                return False
        return True


class FaultPlan:
    """A seeded, schedule-driven set of fault rules.

    Install with :func:`install`/:func:`uninstall` or the
    :func:`active` context manager. ``plan.log`` records every fired
    fault as ``(site, action, ctx)`` for test assertions.
    """

    def __init__(self, rules: list[FaultRule] | None = None, seed: int = 0) -> None:
        self.rules = list(rules or [])
        self.rng = random.Random(seed)
        self.log: list[tuple[str, str, dict]] = []
        # Leaf lock: only dict/list ops are performed under it, and the
        # delay sleep happens after release (see CONCURRENCY.md).
        self._lock = make_lock("faults")

    def add(self, rule: FaultRule) -> "FaultPlan":
        self.rules.append(rule)
        return self

    def fired(self, site: str | None = None) -> int:
        with self._lock:
            return sum(
                1 for s, _a, _c in self.log if site is None or s == site
            )

    def fire(self, site: str, ctx: dict) -> str | None:
        """Evaluate rules for one fault-point hit (first match wins)."""
        with self._lock:
            action = None
            delay_s = 0.0
            for rule in self.rules:
                if not rule._matches(site, ctx):
                    continue
                rule.matched += 1
                if rule.matched <= rule.after:
                    continue
                if rule.times is not None and rule.fired >= rule.times:
                    continue
                if rule.prob < 1.0 and self.rng.random() >= rule.prob:
                    continue
                rule.fired += 1
                self.log.append((site, rule.action, dict(ctx)))
                action = rule.action
                delay_s = rule.delay_s
                break
        if action is None:
            return None
        if action == "delay":
            time.sleep(delay_s)
            return None
        if action in RAISING_ACTIONS:
            raise FaultInjected(f"injected {action} at {site} ({ctx})")
        return action  # "duplicate": interpreted by the transport


# ---------------------------------------------------------------------------
# Module-level activation (per-test, no env vars)
# ---------------------------------------------------------------------------

_plan: FaultPlan | None = None
_install_guard = threading.Lock()


def install(plan: FaultPlan) -> None:
    global _plan
    with _install_guard:
        if _plan is not None:
            raise RuntimeError("a FaultPlan is already installed")
        _plan = plan


def uninstall() -> None:
    global _plan
    with _install_guard:
        _plan = None


def current() -> FaultPlan | None:
    return _plan


@contextmanager
def active(plan: FaultPlan):
    """``with faults.active(plan): ...`` — install for the block only."""
    install(plan)
    try:
        yield plan
    finally:
        uninstall()


def hit(site: str, **ctx) -> str | None:
    """The fault point. Zero-cost when no plan is installed."""
    plan = _plan
    if plan is None:
        return None
    return plan.fire(site, ctx)
