"""Queue-as-database (paper §3.2–3.3) — indexed, event-friendly broker core.

The paper's key departure from broker-based workflow systems: the queue
IS a standard database table, so assignment can match *any* column
(fine-grained per-executor targeting, capability matching, introspection)
and ordering is a plain ``ORDER BY priority_time``.

Two backends behind one interface:

* :class:`SqliteDatabase` — faithful to the paper (Postgres in the Go
  implementation): the candidate query is literally an ``ORDER BY
  priority_time ASC`` SQL select over covering indexes; file-backed
  (survives restarts) or ``:memory:``.
* :class:`MemoryDatabase` — per-colony sharded in-process tables for
  broker micro-benchmarks; identical semantics.

Both backends maintain the same auxiliary indexes so the server's hot
paths do bounded work regardless of how many processes have ever been
stored:

* **per-colony state counters** — ``colony_stats`` is an O(states) dict
  read (memdb) or a 4-row indexed select (sqlite), never a table scan;
* **deadline indexes** — ``running_past_deadline`` /
  ``waiting_past_deadline`` pop lazily-invalidated min-heaps (memdb) or
  range-scan ``(state, deadline)`` B-tree indexes (sqlite), so the 250 ms
  failsafe tick touches only expired + stale entries;
* **ready-queue side-listing** — ``wait_for_parents`` processes are kept
  out of the ready queues entirely (they re-enter via ``requeue`` when
  released) and executor-targeted processes live in per-target side
  queues, so neither class can pin the queue head for everyone else;
* **per-colony locks** — ``colony_lock(colony)`` hands out one lock per
  colony, shared by every server replica using the same database object,
  so assignment/close/failsafe serialize per colony instead of across
  the whole deployment.

Stale ready-queue entries (processes assigned, closed, or expired since
they were enqueued) are dropped lazily: each candidate scan compacts the
prefix it walked in a single pass, and a whole queue is rebuilt once its
stale count dominates — never one ``list.remove`` per entry.
"""

from __future__ import annotations

import bisect
import heapq
import json
import marshal
import sqlite3
import threading
from collections import deque
from typing import Any, Iterable

from ..analysis.authtrack import guard_database_subclass
from ..analysis.contracts import requires_lock
from ..analysis.locktrack import make_lock
from ..runtime import faults
from .errors import ConflictError, NotFoundError
from .process import (
    FAILED,
    RUNNING,
    SUCCESSFUL,
    WAITING,
    Colony,
    Executor,
    Process,
    now_ns,
)


# RPC dedup table bounds (ROBUSTNESS.md): a record only needs to outlive
# its client's retry window, so entries expire after DEDUP_TTL_NS and each
# colony keeps at most DEDUP_MAX_PER_COLONY records (oldest evicted first).
DEDUP_TTL_NS = 600 * 10**9
DEDUP_MAX_PER_COLONY = 4096


class Database:
    """Abstract storage interface shared by all Colonies server replicas."""

    def __init_subclass__(cls, **kwargs) -> None:
        super().__init_subclass__(**kwargs)
        # Under REPRO_AUTH_CHECK=1, colony-scoped entry points refuse to
        # run inside a request that never recorded an auth fact for the
        # colony they touch (see repro/analysis/authtrack.py, SECURITY.md).
        guard_database_subclass(cls)

    # -- colonies ---------------------------------------------------------
    def add_colony(self, colony: Colony) -> None:
        raise NotImplementedError

    def get_colony(self, name: str) -> Colony:
        raise NotImplementedError

    def list_colonies(self) -> list[Colony]:
        raise NotImplementedError

    # -- executors --------------------------------------------------------
    def add_executor(self, ex: Executor) -> None:
        raise NotImplementedError

    def get_executor(self, executorid: str) -> Executor:
        raise NotImplementedError

    def get_executor_by_name(self, colony: str, name: str) -> Executor:
        raise NotImplementedError

    def list_executors(self, colony: str) -> list[Executor]:
        raise NotImplementedError

    def set_executor_state(self, executorid: str, state: str) -> None:
        raise NotImplementedError

    def remove_executor(self, executorid: str) -> None:
        raise NotImplementedError

    def touch_executor(self, executorid: str, ts: int) -> None:
        raise NotImplementedError

    # -- function registry --------------------------------------------------
    def add_function(self, executorid: str, colony: str, funcname: str) -> None:
        raise NotImplementedError

    def list_functions(self, colony: str, executorid: str | None = None) -> list[dict]:
        raise NotImplementedError

    # -- processes ----------------------------------------------------------
    def add_process(self, p: Process) -> None:
        raise NotImplementedError

    def get_process(self, processid: str) -> Process:
        raise NotImplementedError

    def update_process(self, p: Process) -> None:
        raise NotImplementedError

    def candidates(
        self, colony: str, executortype: str, executorname: str, limit: int = 8
    ) -> list[Process]:
        """Waiting, parent-free processes for this executor, oldest priority first."""
        raise NotImplementedError

    def list_processes(
        self, colony: str, state: str | None = None, count: int = 100
    ) -> list[Process]:
        raise NotImplementedError

    def running_past_deadline(self, ts: int) -> list[Process]:
        raise NotImplementedError

    def waiting_past_deadline(self, ts: int) -> list[Process]:
        raise NotImplementedError

    def delete_process(self, processid: str) -> None:
        raise NotImplementedError

    def colony_stats(self, colony: str) -> dict[str, int]:
        """Per-state process counts for one colony; O(states), not O(processes)."""
        raise NotImplementedError

    def colony_lock(self, colony: str) -> threading.RLock:
        """Per-colony critical-section lock, shared by all replicas on this db."""
        raise NotImplementedError

    # -- RPC dedup table (exactly-once mutating RPCs; ROBUSTNESS.md) --------
    # Keyed on "identity:msgid". Bounded: TTL-evicted plus a per-colony
    # record cap, so a retry storm cannot grow the table without limit.
    # Lives in the shared db so every HA replica dedups identically.
    def dedup_get(self, key: str) -> dict | None:
        """Recorded reply for a keyed RPC, or None if never completed."""
        raise NotImplementedError

    def dedup_put(self, key: str, colony: str, ts: int, reply) -> None:
        """Record the reply of a completed keyed RPC (successes only)."""
        raise NotImplementedError

    def replica_state(self, colony: str) -> list[tuple]:
        """Replication-visible rows of one colony, for digest cross-checks.

        One tuple per process, matching
        :func:`repro.analysis.statehash.process_state_tuple`: (processid,
        state, assignedexecutorid, retries, wait_for_parents, queue_ready,
        starttime_ns, endtime_ns). Order is unspecified — the digest fold
        is order-independent.
        """
        raise NotImplementedError

    # -- CFS metadata plane (fs.py; paper §3.4.5) ---------------------------
    # Indexed per colony so no operation ever scans the whole file table:
    # label trees answer subtree listings, (label, name) revision heads
    # answer lookups/next-revision, and pin refcounts answer removal checks.
    def cfs_add_file(self, entry: dict) -> dict:
        """Store a new revision; assigns ``entry['revision']`` = head + 1."""
        raise NotImplementedError

    def cfs_get_file(self, colony: str, fileid: str) -> dict | None:
        raise NotImplementedError

    def cfs_get_files_by_ids(self, colony: str, fileids: list[str]) -> list[dict | None]:
        """Batched lookup, one entry per id in order (None where absent)."""
        raise NotImplementedError

    def cfs_head(self, colony: str, label: str, name: str) -> dict | None:
        """Latest revision of (label, name), or None."""
        raise NotImplementedError

    def cfs_list(self, colony: str, label: str) -> list[dict]:
        """Latest revisions at ``label`` and below, sorted by (label, name)."""
        raise NotImplementedError

    def cfs_remove_file(self, colony: str, fileid: str) -> dict | None:
        """Remove one revision; ConflictError if pinned, None if absent."""
        raise NotImplementedError

    def cfs_pin_count(self, colony: str, fileid: str) -> int:
        """How many live snapshots pin this revision (O(1)/indexed)."""
        raise NotImplementedError

    def cfs_create_snapshot(self, snap: dict) -> dict:
        """Atomically pin the heads under ``snap['label']``; fills 'fileids'."""
        raise NotImplementedError

    def cfs_get_snapshot(self, colony: str, snapshotid: str) -> dict | None:
        raise NotImplementedError

    def cfs_list_snapshots(self, colony: str) -> list[dict]:
        """All snapshots of one colony, oldest first (indexed per colony)."""
        raise NotImplementedError

    def cfs_remove_snapshot(self, colony: str, snapshotid: str) -> dict | None:
        """Remove a snapshot and release its pins; None if absent."""
        raise NotImplementedError

    # -- cron / generator tables (cron.py, generator.py) --------------------
    # First-class per-colony indexed tables: listings never scan other
    # colonies' entries, and the cron leader tick reads a deadline index
    # instead of the whole table (the kv buckets the seed used survive
    # only as a sqlite migration source).
    def cron_put(self, entry: dict) -> None:
        """Insert or update a cron entry (keyed by ``entry['cronid']``)."""
        raise NotImplementedError

    def cron_get(self, cronid: str) -> dict | None:
        raise NotImplementedError

    def cron_del(self, cronid: str) -> None:
        raise NotImplementedError

    def cron_list(self, colony: str) -> list[dict]:
        raise NotImplementedError

    def cron_due(self, ts: int) -> list[dict]:
        """Entries with ``deadline < ts`` via the deadline index, O(due)."""
        raise NotImplementedError

    def generator_put(self, entry: dict) -> None:
        raise NotImplementedError

    def generator_get(self, generatorid: str) -> dict | None:
        raise NotImplementedError

    def generator_del(self, generatorid: str) -> None:
        raise NotImplementedError

    def generator_list(self, colony: str) -> list[dict]:
        raise NotImplementedError

    def generator_all(self) -> list[dict]:
        """Every generator (leader tick); first-class table iteration."""
        raise NotImplementedError

    # -- colony users (server.py `_require_member`; paper Table 5) ----------
    # First-class table keyed by userid with a per-colony listing index,
    # so membership checks stay O(1) and `listusers` never scans other
    # colonies (the kv bucket the seed used survives as a migration source).
    def user_put(self, entry: dict) -> None:
        """Insert or update a user (keyed by ``entry['userid']``)."""
        raise NotImplementedError

    def user_get(self, userid: str) -> dict | None:
        raise NotImplementedError

    def user_del(self, userid: str) -> None:
        raise NotImplementedError

    def user_list(self, colony: str) -> list[dict]:
        """All users of one colony, sorted by name (indexed per colony)."""
        raise NotImplementedError

    # -- key/value side tables (cron, generators, CFS metadata) -------------
    def kv_put(self, table: str, key: str, value: dict) -> None:
        raise NotImplementedError

    def kv_get(self, table: str, key: str) -> dict | None:
        raise NotImplementedError

    def kv_del(self, table: str, key: str) -> None:
        raise NotImplementedError

    def kv_list(self, table: str) -> list[dict]:
        raise NotImplementedError

    def kv_append(self, table: str, key: str, value: dict) -> int:
        """Append to a list bucket; returns new length (generator pack queues)."""
        raise NotImplementedError

    def kv_take_all(self, table: str, key: str) -> list[dict]:
        """Atomically drain a list bucket."""
        raise NotImplementedError

    def kv_len(self, table: str, key: str) -> int:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# In-memory backend
# ---------------------------------------------------------------------------

# Compact a ready queue outright once this many stale entries accumulated
# AND they outnumber the live ones (amortized O(1) per transition).
_COMPACT_MIN_STALE = 64


class _ColonyShard:
    """All mutable broker state for one colony, guarded by one lock."""

    __slots__ = (
        "lock",
        "procs",
        "queues",
        "targeted",
        "stale",
        "counters",
        "acct",
        "exec_heap",
        "wait_heap",
        "exec_pushed",
        "wait_pushed",
    )

    def __init__(self, colony: str = "") -> None:
        self.lock = make_lock(f"shard:{colony}")
        self.procs: dict[str, Process] = {}
        # executortype -> sorted [(priority_time, pid)] of ready untargeted procs
        self.queues: dict[str, list[tuple[int, str]]] = {}
        # executortype -> executorname -> sorted [(priority_time, pid)]
        self.targeted: dict[str, dict[str, list[tuple[int, str]]]] = {}
        self.stale: dict[str, int] = {}  # executortype -> stale-entry estimate
        self.counters: dict[str, int] = {}  # state -> live count
        self.acct: dict[str, str] = {}  # pid -> last counted state
        self.exec_heap: list[tuple[int, str]] = []  # (deadline, pid), RUNNING
        self.wait_heap: list[tuple[int, str]] = []  # (waitdeadline, pid), WAITING
        self.exec_pushed: dict[str, int] = {}  # pid -> deadline currently in heap
        self.wait_pushed: dict[str, int] = {}


class _CfsShard:
    """One colony's CFS metadata, guarded by one lock.

    ``by_label`` is the revision index: label -> name -> ascending
    ``(revision, fileid)`` list whose tail is the head revision.
    ``children`` is the label tree: label -> immediate child labels, so a
    subtree listing walks exactly the labels under the query prefix.
    ``pins`` maps fileid -> the set of snapshot ids pinning it (refcount =
    set size), making the removal check O(1) instead of a snapshot scan.
    """

    __slots__ = ("lock", "files", "by_label", "children", "snapshots", "pins")

    def __init__(self, colony: str = "") -> None:
        self.lock = make_lock(f"cfs:{colony}")
        self.files: dict[str, dict] = {}
        self.by_label: dict[str, dict[str, list[tuple[int, str]]]] = {}
        self.children: dict[str, set[str]] = {}
        self.snapshots: dict[str, dict] = {}
        self.pins: dict[str, set[str]] = {}


def _cfs_parent(label: str) -> str:
    return label.rsplit("/", 1)[0] or "/"


class MemoryDatabase(Database):
    def __init__(self) -> None:
        # Registries + shard map only; a LEAF lock (see CONCURRENCY.md):
        # nothing may be acquired and nothing may block while holding it.
        self._glock = make_lock("glock")
        self._colonies: dict[str, Colony] = {}
        self._executors: dict[str, Executor] = {}
        self._functions: list[dict] = []
        self._shards: dict[str, _ColonyShard] = {}
        self._cfs_shards: dict[str, _CfsShard] = {}
        self._pid_colony: dict[str, str] = {}
        self._kv: dict[str, dict[str, dict]] = {}
        self._kvlists: dict[str, dict[str, list[dict]]] = {}
        # Cron/generator tables: colony -> id -> entry, with reverse maps
        # for id-keyed lookups and a lazily-invalidated cron deadline heap.
        self._crons: dict[str, dict[str, dict]] = {}
        self._cron_colony: dict[str, str] = {}
        self._cron_heap: list[tuple[int, str]] = []
        self._generators: dict[str, dict[str, dict]] = {}
        self._generator_colony: dict[str, str] = {}
        # Colony users: colony -> userid -> entry, with a reverse map for
        # the id-keyed membership check (`_require_member`).
        self._users: dict[str, dict[str, dict]] = {}
        self._user_colony: dict[str, str] = {}
        # RPC dedup records: key -> (ts, colony, marshal-blob), plus a
        # per-colony FIFO of keys driving cap/TTL eviction. Both live
        # under _glock (straight-line dict/deque ops only — leaf lock).
        self._dedup: dict[str, tuple[int, str, bytes]] = {}
        self._dedup_fifo: dict[str, deque[str]] = {}
        # Observability for bounded-work regression tests/benchmarks.
        self.metrics: dict[str, int] = {
            "deadline_pops": 0,
            "queue_scan_steps": 0,
            "stale_evicted": 0,
            "compactions": 0,
            "cfs_nodes_visited": 0,
        }

    def _shard(self, colony: str) -> _ColonyShard:
        with self._glock:
            s = self._shards.get(colony)
            if s is None:
                s = self._shards[colony] = _ColonyShard(colony)
            return s

    def _cfs(self, colony: str) -> _CfsShard:
        with self._glock:
            s = self._cfs_shards.get(colony)
            if s is None:
                s = self._cfs_shards[colony] = _CfsShard(colony)
            return s

    def colony_lock(self, colony: str) -> threading.RLock:
        return self._shard(colony).lock

    # colonies
    def add_colony(self, colony: Colony) -> None:
        with self._glock:
            if colony.colonyname in self._colonies:
                raise ConflictError(f"colony {colony.colonyname} exists")
            self._colonies[colony.colonyname] = colony

    def get_colony(self, name: str) -> Colony:
        with self._glock:
            c = self._colonies.get(name)
            if c is None:
                raise NotFoundError(f"colony {name} not found")
            return c

    def list_colonies(self) -> list[Colony]:
        with self._glock:
            return list(self._colonies.values())

    # executors
    def add_executor(self, ex: Executor) -> None:
        with self._glock:
            if ex.executorid in self._executors:
                raise ConflictError("executor exists")
            for other in self._executors.values():
                if (
                    other.colonyname == ex.colonyname
                    and other.executorname == ex.executorname
                ):
                    raise ConflictError(f"executor name {ex.executorname} taken")
            self._executors[ex.executorid] = ex

    def get_executor(self, executorid: str) -> Executor:
        with self._glock:
            ex = self._executors.get(executorid)
            if ex is None:
                raise NotFoundError("executor not found")
            return ex

    def get_executor_by_name(self, colony: str, name: str) -> Executor:
        with self._glock:
            for ex in self._executors.values():
                if ex.colonyname == colony and ex.executorname == name:
                    return ex
            raise NotFoundError(f"executor {name} not found")

    def list_executors(self, colony: str) -> list[Executor]:
        with self._glock:
            return [e for e in self._executors.values() if e.colonyname == colony]

    def set_executor_state(self, executorid: str, state: str) -> None:
        with self._glock:
            self.get_executor(executorid).state = state

    def remove_executor(self, executorid: str) -> None:
        with self._glock:
            if executorid not in self._executors:
                raise NotFoundError("executor not found")
            del self._executors[executorid]

    def touch_executor(self, executorid: str, ts: int) -> None:
        with self._glock:
            ex = self._executors.get(executorid)
            if ex is not None:
                ex.lastheardfrom_ns = ts

    # functions
    def add_function(self, executorid: str, colony: str, funcname: str) -> None:
        with self._glock:
            self._functions.append(
                {"executorid": executorid, "colonyname": colony, "funcname": funcname}
            )

    def list_functions(self, colony: str, executorid: str | None = None) -> list[dict]:
        with self._glock:
            return [
                dict(f)
                for f in self._functions
                if f["colonyname"] == colony
                and (executorid is None or f["executorid"] == executorid)
            ]

    # -- process bookkeeping (contract: shard lock held, checked under
    # REPRO_LOCK_CHECK — see repro.analysis.contracts) ------------------------
    @requires_lock("shard")
    def _account(self, s: _ColonyShard, p: Process) -> None:
        old = s.acct.get(p.processid)
        if old == p.state:
            return
        if old is not None:
            s.counters[old] = s.counters.get(old, 0) - 1
            if old == WAITING:
                self._note_stale(s, p)
        s.counters[p.state] = s.counters.get(p.state, 0) + 1
        s.acct[p.processid] = p.state

    @requires_lock("shard")
    def _note_stale(self, s: _ColonyShard, p: Process) -> None:
        etype = p.spec.conditions.executortype
        # One unit per queue entry the process held: a multi-target process
        # left one entry in each target's side queue.
        entries = len(p.spec.conditions.executornames) or 1
        s.stale[etype] = s.stale.get(etype, 0) + entries
        self._maybe_compact(s, etype)

    @requires_lock("shard")
    def _maybe_compact(self, s: _ColonyShard, etype: str) -> None:
        n_stale = s.stale.get(etype, 0)
        q = s.queues.get(etype, [])
        tmap = s.targeted.get(etype, {})
        total = len(q) + sum(len(v) for v in tmap.values())
        if n_stale < _COMPACT_MIN_STALE or n_stale * 2 <= total:
            return

        def live(entry: tuple[int, str]) -> bool:
            lp = s.procs.get(entry[1])
            return lp is not None and lp.queue_ready

        before = total
        q[:] = [e for e in q if live(e)]
        for name in list(tmap):
            tq = tmap[name]
            tq[:] = [e for e in tq if live(e)]
            if not tq:
                del tmap[name]
        after = len(q) + sum(len(v) for v in tmap.values())
        s.stale[etype] = 0
        self.metrics["compactions"] += 1
        self.metrics["stale_evicted"] += before - after

    @requires_lock("shard")
    def _push_deadlines(self, s: _ColonyShard, p: Process) -> None:
        pid = p.processid
        if p.state == RUNNING and p.deadline_ns:
            if s.exec_pushed.get(pid) != p.deadline_ns:
                heapq.heappush(s.exec_heap, (p.deadline_ns, pid))
                s.exec_pushed[pid] = p.deadline_ns
        if p.state == WAITING and p.waitdeadline_ns:
            if s.wait_pushed.get(pid) != p.waitdeadline_ns:
                heapq.heappush(s.wait_heap, (p.waitdeadline_ns, pid))
                s.wait_pushed[pid] = p.waitdeadline_ns

    @requires_lock("shard")
    def _enqueue(self, s: _ColonyShard, p: Process) -> None:
        # Blocked processes are side-lined entirely; they re-enter the ready
        # queues through requeue() when their last parent succeeds.
        if not p.queue_ready:
            return
        etype = p.spec.conditions.executortype
        entry = (p.priority_time, p.processid)
        targets = p.spec.conditions.executornames
        if targets:
            tmap = s.targeted.setdefault(etype, {})
            for name in targets:
                self._insort_unique(tmap.setdefault(name, []), entry)
        else:
            self._insort_unique(s.queues.setdefault(etype, []), entry)

    @staticmethod
    def _insort_unique(q: list[tuple[int, str]], entry: tuple[int, str]) -> None:
        idx = bisect.bisect_left(q, entry)
        if idx < len(q) and q[idx] == entry:
            return  # already queued (e.g. failsafe requeue racing a release)
        q.insert(idx, entry)

    # processes
    def add_process(self, p: Process) -> None:
        # Fault point BEFORE any lock (CONCURRENCY.md: nothing may raise
        # or sleep under a shard lock that isn't the write itself).
        faults.hit("db.commit", method="add_process")
        s = self._shard(p.colonyname)
        with s.lock:
            s.procs[p.processid] = p
            with self._glock:
                self._pid_colony[p.processid] = p.colonyname
            self._account(s, p)
            self._push_deadlines(s, p)
            self._enqueue(s, p)

    def get_process(self, processid: str) -> Process:
        with self._glock:
            colony = self._pid_colony.get(processid)
        if colony is None:
            raise NotFoundError(f"process {processid} not found")
        s = self._shard(colony)
        with s.lock:
            p = s.procs.get(processid)
            if p is None:
                raise NotFoundError(f"process {processid} not found")
            return p

    def update_process(self, p: Process) -> None:
        faults.hit("db.commit", method="update_process")
        s = self._shard(p.colonyname)
        with s.lock:
            if p.processid not in s.procs:
                raise NotFoundError("process not found")
            s.procs[p.processid] = p
            self._account(s, p)
            self._push_deadlines(s, p)

    def requeue(self, p: Process) -> None:
        """Re-insert a reset or released process into the ready queues."""
        s = self._shard(p.colonyname)
        with s.lock:
            self._push_deadlines(s, p)
            self._enqueue(s, p)

    # RPC dedup (exactly-once keyed RPCs; ROBUSTNESS.md). The reply is
    # snapshotted with ``marshal`` — a flat bytes blob, so (a) a caller
    # mutating the live result object can never corrupt the record, and
    # (b) the table is invisible to the cyclic GC. Both alternatives
    # measured worse on the hot path: storing the object graph by
    # reference kept thousands of long-lived containers on the gen-2
    # scan list (a per-cycle GC tax bigger than the marshal dump), and
    # JSON costs ~3x marshal to encode. Replies are plain JSON-shaped
    # data (dict/list/str/num/bool/None), exactly marshal's domain.
    def dedup_get(self, key: str) -> dict | None:
        with self._glock:
            rec = self._dedup.get(key)
            if rec is None:
                return None
            ts, _colony, blob = rec
            if now_ns() - ts > DEDUP_TTL_NS:
                del self._dedup[key]
                return None
        return marshal.loads(blob)

    def dedup_put(self, key: str, colony: str, ts: int, reply) -> None:
        blob = marshal.dumps(reply)
        with self._glock:
            if key not in self._dedup:
                fifo = self._dedup_fifo.get(colony)
                if fifo is None:
                    fifo = self._dedup_fifo[colony] = deque()
                fifo.append(key)
                # Amortized eviction: cap overflow plus any expired prefix.
                while len(fifo) > DEDUP_MAX_PER_COLONY:
                    self._dedup.pop(fifo.popleft(), None)
                while fifo:
                    head = self._dedup.get(fifo[0])
                    if head is None:
                        fifo.popleft()
                    elif ts - head[0] > DEDUP_TTL_NS:
                        del self._dedup[fifo.popleft()]
                    else:
                        break
            self._dedup[key] = (ts, colony, blob)

    @requires_lock("shard")
    def _scan_queue(
        self,
        s: _ColonyShard,
        q: list[tuple[int, str]] | None,
        etype: str,
        executorname: str,
        limit: int,
        targeted: bool,
    ) -> list[Process]:
        """Collect up to ``limit`` ready processes from one sorted queue.

        Stale entries discovered in the scanned prefix are evicted in a
        single rebuild of that prefix — never one ``list.remove`` each.
        """
        if not q:
            return []
        out: list[Process] = []
        scanned = 0
        found_stale = False
        for _, pid in q:
            scanned += 1
            self.metrics["queue_scan_steps"] += 1
            p = s.procs.get(pid)
            ok = p is not None and p.queue_ready
            if ok and targeted:
                ok = executorname in p.spec.conditions.executornames
            elif ok and p.spec.conditions.executornames:
                ok = False  # targeted proc must never ride the shared queue
            if not ok:
                found_stale = True
                continue
            out.append(p)
            if len(out) >= limit:
                break
        if found_stale:

            def live(entry: tuple[int, str]) -> bool:
                lp = s.procs.get(entry[1])
                if lp is None or not lp.queue_ready:
                    return False
                if targeted:
                    return executorname in lp.spec.conditions.executornames
                return not lp.spec.conditions.executornames

            prefix = [e for e in q[:scanned] if live(e)]
            evicted = scanned - len(prefix)
            self.metrics["stale_evicted"] += evicted
            if evicted:
                s.stale[etype] = max(0, s.stale.get(etype, 0) - evicted)
            q[:scanned] = prefix
        return out

    def candidates(
        self, colony: str, executortype: str, executorname: str, limit: int = 8
    ) -> list[Process]:
        s = self._shard(colony)
        with s.lock:
            main = self._scan_queue(
                s,
                s.queues.get(executortype),
                executortype,
                executorname,
                limit,
                targeted=False,
            )
            side = self._scan_queue(
                s,
                s.targeted.get(executortype, {}).get(executorname),
                executortype,
                executorname,
                limit,
                targeted=True,
            )
            if not side:
                return main
            merged = sorted(main + side, key=lambda p: (p.priority_time, p.processid))
            return merged[:limit]

    def list_processes(
        self, colony: str, state: str | None = None, count: int = 100
    ) -> list[Process]:
        s = self._shard(colony)
        with s.lock:
            out = [
                p
                for p in s.procs.values()
                if state is None or p.state == state
            ]
            out.sort(key=lambda p: p.priority_time)
            return out[:count]

    @requires_lock("shard")
    def _pop_expired(
        self,
        s: _ColonyShard,
        heap: list[tuple[int, str]],
        pushed: dict[str, int],
        want_state: str,
        attr: str,
        ts: int,
    ) -> list[Process]:
        expired: list[Process] = []
        keep: list[tuple[int, str]] = []
        while heap and heap[0][0] < ts:
            deadline, pid = heapq.heappop(heap)
            self.metrics["deadline_pops"] += 1
            p = s.procs.get(pid)
            if p is not None and p.state == want_state and getattr(p, attr) == deadline:
                expired.append(p)
                keep.append((deadline, pid))  # caller mutates; entry goes stale then
            elif pushed.get(pid) == deadline:
                pushed.pop(pid, None)
        for entry in keep:
            heapq.heappush(heap, entry)
        return expired

    def running_past_deadline(self, ts: int) -> list[Process]:
        with self._glock:
            shards = list(self._shards.values())
        out: list[Process] = []
        for s in shards:
            with s.lock:
                out.extend(
                    self._pop_expired(
                        s, s.exec_heap, s.exec_pushed, RUNNING, "deadline_ns", ts
                    )
                )
        return out

    def waiting_past_deadline(self, ts: int) -> list[Process]:
        with self._glock:
            shards = list(self._shards.values())
        out: list[Process] = []
        for s in shards:
            with s.lock:
                out.extend(
                    self._pop_expired(
                        s, s.wait_heap, s.wait_pushed, WAITING, "waitdeadline_ns", ts
                    )
                )
        return out

    def delete_process(self, processid: str) -> None:
        with self._glock:
            colony = self._pid_colony.pop(processid, None)
        if colony is None:
            return
        s = self._shard(colony)
        with s.lock:
            p = s.procs.pop(processid, None)
            if p is None:
                return
            old = s.acct.pop(processid, None)
            if old is not None:
                s.counters[old] = s.counters.get(old, 0) - 1
            s.exec_pushed.pop(processid, None)
            s.wait_pushed.pop(processid, None)
            if old == WAITING:
                self._note_stale(s, p)

    def colony_stats(self, colony: str) -> dict[str, int]:
        s = self._shard(colony)
        with s.lock:
            return {state: n for state, n in s.counters.items() if n}

    def replica_state(self, colony: str) -> list[tuple]:
        from ..analysis.statehash import process_state_tuple

        s = self._shard(colony)
        with s.lock:
            return [process_state_tuple(p) for p in s.procs.values()]

    # -- CFS metadata -------------------------------------------------------
    @staticmethod
    @requires_lock("cfs")
    def _cfs_link(s: _CfsShard, label: str) -> None:
        """Wire a new label into the tree, up to the first existing edge."""
        while label != "/":
            parent = _cfs_parent(label)
            kids = s.children.setdefault(parent, set())
            if label in kids:
                return
            kids.add(label)
            label = parent

    @staticmethod
    @requires_lock("cfs")
    def _cfs_prune(s: _CfsShard, label: str) -> None:
        """Drop now-empty labels so the tree only holds live paths."""
        while label != "/" and not s.by_label.get(label) and not s.children.get(label):
            s.by_label.pop(label, None)
            s.children.pop(label, None)
            parent = _cfs_parent(label)
            kids = s.children.get(parent)
            if kids is not None:
                kids.discard(label)
            label = parent

    def cfs_add_file(self, entry: dict) -> dict:
        s = self._cfs(entry["colonyname"])
        label, name = entry["label"], entry["name"]
        with s.lock:
            new_label = label not in s.by_label and label not in s.children
            revs = s.by_label.setdefault(label, {}).setdefault(name, [])
            entry = dict(entry)
            entry["revision"] = (revs[-1][0] + 1) if revs else 1
            s.files[entry["fileid"]] = entry
            revs.append((entry["revision"], entry["fileid"]))
            if new_label:
                self._cfs_link(s, label)
            return dict(entry)

    def cfs_get_file(self, colony: str, fileid: str) -> dict | None:
        s = self._cfs(colony)
        with s.lock:
            e = s.files.get(fileid)
            return dict(e) if e is not None else None

    def cfs_get_files_by_ids(self, colony: str, fileids: list[str]) -> list[dict | None]:
        s = self._cfs(colony)
        with s.lock:  # one lock pass for the whole batch
            return [
                dict(e) if (e := s.files.get(fid)) is not None else None
                for fid in fileids
            ]

    def cfs_head(self, colony: str, label: str, name: str) -> dict | None:
        s = self._cfs(colony)
        with s.lock:
            revs = s.by_label.get(label, {}).get(name)
            return dict(s.files[revs[-1][1]]) if revs else None

    def cfs_list(self, colony: str, label: str) -> list[dict]:
        s = self._cfs(colony)
        with s.lock:
            return self._cfs_list_locked(s, label)

    @requires_lock("cfs")
    def _cfs_list_locked(self, s: _CfsShard, label: str) -> list[dict]:
        if label not in s.by_label and label not in s.children:
            return []
        out: list[dict] = []
        stack = [label]
        while stack:
            lbl = stack.pop()
            self.metrics["cfs_nodes_visited"] += 1
            for revs in s.by_label.get(lbl, {}).values():
                out.append(dict(s.files[revs[-1][1]]))
            stack.extend(s.children.get(lbl, ()))
        out.sort(key=lambda e: (e["label"], e["name"]))
        return out

    def cfs_remove_file(self, colony: str, fileid: str) -> dict | None:
        s = self._cfs(colony)
        with s.lock:
            e = s.files.get(fileid)
            if e is None:
                return None
            holders = s.pins.get(fileid)
            if holders:
                raise ConflictError(
                    "file revision pinned by snapshot " + next(iter(holders))
                )
            del s.files[fileid]
            names = s.by_label.get(e["label"], {})
            revs = names.get(e["name"], [])
            if (e["revision"], fileid) in revs:
                revs.remove((e["revision"], fileid))
            if not revs:
                names.pop(e["name"], None)
                if not names:
                    s.by_label.pop(e["label"], None)
                    self._cfs_prune(s, e["label"])
            return e

    def cfs_pin_count(self, colony: str, fileid: str) -> int:
        s = self._cfs(colony)
        with s.lock:
            return len(s.pins.get(fileid, ()))

    def cfs_create_snapshot(self, snap: dict) -> dict:
        s = self._cfs(snap["colonyname"])
        with s.lock:
            # Listing + pinning under one lock: a file removed concurrently
            # can never leave the snapshot holding a tombstone.
            snap = dict(snap)
            snap["fileids"] = [
                e["fileid"] for e in self._cfs_list_locked(s, snap["label"])
            ]
            s.snapshots[snap["snapshotid"]] = dict(snap)
            for fid in snap["fileids"]:
                s.pins.setdefault(fid, set()).add(snap["snapshotid"])
            return snap

    def cfs_get_snapshot(self, colony: str, snapshotid: str) -> dict | None:
        s = self._cfs(colony)
        with s.lock:
            snap = s.snapshots.get(snapshotid)
            return dict(snap) if snap is not None else None

    def cfs_list_snapshots(self, colony: str) -> list[dict]:
        s = self._cfs(colony)
        with s.lock:
            snaps = [dict(v) for v in s.snapshots.values()]
        snaps.sort(key=lambda e: (e.get("added", 0), e["snapshotid"]))
        return snaps

    def cfs_remove_snapshot(self, colony: str, snapshotid: str) -> dict | None:
        s = self._cfs(colony)
        with s.lock:
            snap = s.snapshots.pop(snapshotid, None)
            if snap is None:
                return None
            for fid in snap["fileids"]:
                holders = s.pins.get(fid)
                if holders is not None:
                    holders.discard(snapshotid)
                    if not holders:
                        del s.pins[fid]
            return snap

    # kv
    def kv_put(self, table: str, key: str, value: dict) -> None:
        with self._glock:
            self._kv.setdefault(table, {})[key] = dict(value)

    def kv_get(self, table: str, key: str) -> dict | None:
        with self._glock:
            v = self._kv.get(table, {}).get(key)
            return dict(v) if v is not None else None

    def kv_del(self, table: str, key: str) -> None:
        with self._glock:
            self._kv.get(table, {}).pop(key, None)

    def kv_list(self, table: str) -> list[dict]:
        with self._glock:
            return [dict(v) for v in self._kv.get(table, {}).values()]

    def kv_append(self, table: str, key: str, value: dict) -> int:
        with self._glock:
            lst = self._kvlists.setdefault(table, {}).setdefault(key, [])
            lst.append(dict(value))
            return len(lst)

    def kv_take_all(self, table: str, key: str) -> list[dict]:
        with self._glock:
            lst = self._kvlists.get(table, {}).pop(key, [])
            return lst

    def kv_len(self, table: str, key: str) -> int:
        with self._glock:
            return len(self._kvlists.get(table, {}).get(key, []))

    # cron / generator tables
    def cron_put(self, entry: dict) -> None:
        with self._glock:
            colony = entry["colonyname"]
            self._crons.setdefault(colony, {})[entry["cronid"]] = dict(entry)
            self._cron_colony[entry["cronid"]] = colony
            heapq.heappush(
                self._cron_heap, (entry.get("deadline", 0), entry["cronid"])
            )

    def cron_get(self, cronid: str) -> dict | None:
        with self._glock:
            colony = self._cron_colony.get(cronid)
            if colony is None:
                return None
            e = self._crons.get(colony, {}).get(cronid)
            return dict(e) if e is not None else None

    def cron_del(self, cronid: str) -> None:
        with self._glock:
            colony = self._cron_colony.pop(cronid, None)
            if colony is not None:
                self._crons.get(colony, {}).pop(cronid, None)
                # Heap entries go stale and are dropped lazily by cron_due.

    def cron_list(self, colony: str) -> list[dict]:
        with self._glock:
            entries = [dict(e) for e in self._crons.get(colony, {}).values()]
        entries.sort(key=lambda e: (e.get("added", 0), e["cronid"]))
        return entries

    def cron_due(self, ts: int) -> list[dict]:
        """Due entries via the deadline heap, dropping stale ones lazily.

        Still-live due entries are pushed back with their unchanged
        deadline: the caller fires and reschedules via cron_put (a new
        heap entry supersedes the pushed-back one), so a leader crash
        between due() and fire loses nothing — the next scan sees the
        entry again, exactly like sqlite's read-only range scan.
        """
        due: list[dict] = []
        keep: list[tuple[int, str]] = []
        with self._glock:
            while self._cron_heap and self._cron_heap[0][0] < ts:
                deadline, cronid = heapq.heappop(self._cron_heap)
                colony = self._cron_colony.get(cronid)
                e = self._crons.get(colony, {}).get(cronid) if colony else None
                if e is None or e.get("deadline", 0) != deadline:
                    continue  # removed or rescheduled: stale heap entry
                due.append(dict(e))
                keep.append((deadline, cronid))
            for item in keep:
                heapq.heappush(self._cron_heap, item)
        return due

    def generator_put(self, entry: dict) -> None:
        with self._glock:
            colony = entry["colonyname"]
            self._generators.setdefault(colony, {})[entry["generatorid"]] = dict(entry)
            self._generator_colony[entry["generatorid"]] = colony

    def generator_get(self, generatorid: str) -> dict | None:
        with self._glock:
            colony = self._generator_colony.get(generatorid)
            if colony is None:
                return None
            e = self._generators.get(colony, {}).get(generatorid)
            return dict(e) if e is not None else None

    def generator_del(self, generatorid: str) -> None:
        with self._glock:
            colony = self._generator_colony.pop(generatorid, None)
            if colony is not None:
                self._generators.get(colony, {}).pop(generatorid, None)

    def generator_list(self, colony: str) -> list[dict]:
        with self._glock:
            entries = [dict(e) for e in self._generators.get(colony, {}).values()]
        entries.sort(key=lambda e: (e.get("added", 0), e["generatorid"]))
        return entries

    def generator_all(self) -> list[dict]:
        with self._glock:
            return [
                dict(e)
                for per_colony in self._generators.values()
                for e in per_colony.values()
            ]

    # colony users
    def user_put(self, entry: dict) -> None:
        with self._glock:
            colony = entry["colonyname"]
            old = self._user_colony.get(entry["userid"])
            if old is not None and old != colony:
                self._users.get(old, {}).pop(entry["userid"], None)
            self._users.setdefault(colony, {})[entry["userid"]] = dict(entry)
            self._user_colony[entry["userid"]] = colony

    def user_get(self, userid: str) -> dict | None:
        with self._glock:
            colony = self._user_colony.get(userid)
            if colony is None:
                return None
            e = self._users.get(colony, {}).get(userid)
            return dict(e) if e is not None else None

    def user_del(self, userid: str) -> None:
        with self._glock:
            colony = self._user_colony.pop(userid, None)
            if colony is not None:
                self._users.get(colony, {}).pop(userid, None)

    def user_list(self, colony: str) -> list[dict]:
        with self._glock:
            entries = [dict(e) for e in self._users.get(colony, {}).values()]
        entries.sort(key=lambda e: (e.get("name", ""), e["userid"]))
        return entries


# ---------------------------------------------------------------------------
# Sqlite backend — the paper's SQL queue, verbatim semantics
# ---------------------------------------------------------------------------

_SCHEMA = """
CREATE TABLE IF NOT EXISTS colonies (
    colonyname TEXT PRIMARY KEY, colonyid TEXT NOT NULL
);
CREATE TABLE IF NOT EXISTS executors (
    executorid TEXT PRIMARY KEY, executorname TEXT, executortype TEXT,
    colonyname TEXT, state TEXT, commissiontime INTEGER, lastheardfrom INTEGER,
    capabilities TEXT,
    UNIQUE(colonyname, executorname)
);
CREATE TABLE IF NOT EXISTS functions (
    executorid TEXT, colonyname TEXT, funcname TEXT
);
CREATE TABLE IF NOT EXISTS processes (
    processid TEXT PRIMARY KEY,
    colonyname TEXT NOT NULL,
    executortype TEXT NOT NULL,
    state TEXT NOT NULL,
    waitforparents INTEGER NOT NULL DEFAULT 0,
    prioritytime INTEGER NOT NULL,
    deadline INTEGER NOT NULL DEFAULT 0,
    waitdeadline INTEGER NOT NULL DEFAULT 0,
    targets TEXT NOT NULL DEFAULT '',
    body TEXT NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_proc_queue
    ON processes (colonyname, executortype, state, waitforparents, prioritytime);
CREATE INDEX IF NOT EXISTS idx_proc_deadline ON processes (state, deadline);
CREATE INDEX IF NOT EXISTS idx_proc_waitdeadline ON processes (state, waitdeadline);
CREATE TABLE IF NOT EXISTS proc_counts (
    colonyname TEXT NOT NULL, state TEXT NOT NULL, n INTEGER NOT NULL,
    PRIMARY KEY (colonyname, state)
);
CREATE TABLE IF NOT EXISTS cfs_files (
    fileid TEXT PRIMARY KEY,
    colonyname TEXT NOT NULL,
    label TEXT NOT NULL,
    name TEXT NOT NULL,
    revision INTEGER NOT NULL,
    body TEXT NOT NULL
);
CREATE UNIQUE INDEX IF NOT EXISTS idx_cfs_head
    ON cfs_files (colonyname, label, name, revision);
CREATE TABLE IF NOT EXISTS cfs_snapshots (
    snapshotid TEXT PRIMARY KEY, colonyname TEXT NOT NULL, body TEXT NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_cfs_snap_colony ON cfs_snapshots (colonyname);
CREATE TABLE IF NOT EXISTS crons (
    cronid TEXT PRIMARY KEY, colonyname TEXT NOT NULL,
    deadline INTEGER NOT NULL DEFAULT 0, body TEXT NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_crons_colony ON crons (colonyname);
CREATE INDEX IF NOT EXISTS idx_crons_deadline ON crons (deadline);
CREATE TABLE IF NOT EXISTS generators (
    generatorid TEXT PRIMARY KEY, colonyname TEXT NOT NULL, body TEXT NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_generators_colony ON generators (colonyname);
CREATE TABLE IF NOT EXISTS users (
    userid TEXT PRIMARY KEY, colonyname TEXT NOT NULL, body TEXT NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_users_colony ON users (colonyname);
CREATE TABLE IF NOT EXISTS cfs_pins (
    colonyname TEXT NOT NULL, fileid TEXT NOT NULL, snapshotid TEXT NOT NULL,
    PRIMARY KEY (colonyname, fileid, snapshotid)
);
CREATE INDEX IF NOT EXISTS idx_cfs_pins_snap ON cfs_pins (colonyname, snapshotid);
CREATE TABLE IF NOT EXISTS kv (
    tbl TEXT NOT NULL, key TEXT NOT NULL, value TEXT NOT NULL,
    PRIMARY KEY (tbl, key)
);
CREATE TABLE IF NOT EXISTS kvlist (
    tbl TEXT NOT NULL, key TEXT NOT NULL, seq INTEGER NOT NULL, value TEXT NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_kvlist ON kvlist (tbl, key, seq);
CREATE TABLE IF NOT EXISTS rpc_dedup (
    key TEXT PRIMARY KEY, colonyname TEXT NOT NULL, ts INTEGER NOT NULL,
    reply TEXT NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_rpc_dedup_colony ON rpc_dedup (colonyname, ts);
"""


def _targets_column(p: Process) -> str:
    names = p.spec.conditions.executornames
    return "|" + "|".join(names) + "|" if names else ""


class SqliteDatabase(Database):
    """File-backed (or ``:memory:``) SQL queue.

    The candidate query is the paper's: ``ORDER BY prioritytime ASC`` over
    indexed (colony, executortype, state, waitforparents) columns, with
    executor targeting pushed into SQL so pinned processes never shadow
    the queue head for other executors. ``proc_counts`` is maintained
    transactionally with every process write, making ``colony_stats``
    independent of table size (and restart-safe).
    """

    def __init__(self, path: str = ":memory:") -> None:
        self._lock = make_lock("sqlite")
        self._colony_locks: dict[str, threading.RLock] = {}
        self._dedup_puts = 0  # amortized rpc_dedup eviction counter
        self._conn = sqlite3.connect(path, check_same_thread=False)
        with self._lock:
            self._conn.execute("PRAGMA journal_mode=WAL")
            self._migrate()
            self._conn.executescript(_SCHEMA)
            self._rebuild_counts_if_missing()
            self._migrate_cfs()
            self._migrate_cron_gen()
            self._migrate_users()
            self._conn.commit()

    def _migrate(self) -> None:
        """Add columns introduced after a db file was created."""
        cols = {
            r[1]
            for r in self._conn.execute("PRAGMA table_info(processes)").fetchall()
        }
        if cols and "targets" not in cols:
            self._conn.execute(
                "ALTER TABLE processes ADD COLUMN targets TEXT NOT NULL DEFAULT ''"
            )
            # Backfill from the body JSON: pre-migration rows kept executor
            # targeting only there, and a blank targets column would make a
            # pinned process assignable by anyone.
            rows = self._conn.execute(
                "SELECT processid, body FROM processes"
            ).fetchall()
            for pid, body in rows:
                t = _targets_column(Process.from_json(body))
                if t:
                    self._conn.execute(
                        "UPDATE processes SET targets=? WHERE processid=?", (t, pid)
                    )

    def _migrate_cfs(self) -> None:
        """Backfill first-class CFS tables from the seed's kv rows.

        Pre-index databases kept every file and snapshot as opaque JSON
        under kv(tbl='cfs_files'/'cfs_snapshots'); move them into the
        indexed tables (rebuilding pin rows from each snapshot's fileids)
        and drop the kv copies so there is a single source of truth. The
        kv bucket names below are frozen — they must match what old
        database files contain, regardless of future table renames.
        """
        rows = self._conn.execute(
            "SELECT value FROM kv WHERE tbl='cfs_files'"
        ).fetchall()
        for (val,) in rows:
            e = json.loads(val)
            exists = self._conn.execute(
                "SELECT 1 FROM cfs_files WHERE fileid=?", (e["fileid"],)
            ).fetchone()
            if exists:
                continue
            e["revision"] = int(e.get("revision", 1))
            try:
                self._conn.execute(
                    "INSERT INTO cfs_files VALUES (?,?,?,?,?,?)",
                    (
                        e["fileid"],
                        e["colonyname"],
                        e["label"],
                        e["name"],
                        e["revision"],
                        json.dumps(e),
                    ),
                )
            except sqlite3.IntegrityError:
                # The seed computed revisions without a lock, so two adds of
                # the same (label, name) could both claim revision N.
                # Re-sequence the loser past the current head instead of
                # silently dropping its metadata.
                head = self._conn.execute(
                    "SELECT MAX(revision) FROM cfs_files"
                    " WHERE colonyname=? AND label=? AND name=?",
                    (e["colonyname"], e["label"], e["name"]),
                ).fetchone()[0]
                e["revision"] = (head or 0) + 1
                self._conn.execute(
                    "INSERT INTO cfs_files VALUES (?,?,?,?,?,?)",
                    (
                        e["fileid"],
                        e["colonyname"],
                        e["label"],
                        e["name"],
                        e["revision"],
                        json.dumps(e),
                    ),
                )
        rows = self._conn.execute(
            "SELECT value FROM kv WHERE tbl='cfs_snapshots'"
        ).fetchall()
        for (val,) in rows:
            snap = json.loads(val)
            self._conn.execute(
                "INSERT OR IGNORE INTO cfs_snapshots VALUES (?,?,?)",
                (snap["snapshotid"], snap["colonyname"], json.dumps(snap)),
            )
            for fid in snap.get("fileids", []):
                self._conn.execute(
                    "INSERT OR IGNORE INTO cfs_pins VALUES (?,?,?)",
                    (snap["colonyname"], fid, snap["snapshotid"]),
                )
        self._conn.execute("DELETE FROM kv WHERE tbl IN ('cfs_files','cfs_snapshots')")

    def _migrate_cron_gen(self) -> None:
        """Backfill first-class cron/generator tables from the seed's kv rows.

        Same pattern as :meth:`_migrate_cfs`: pre-index databases stored
        cron and generator entries as opaque JSON under kv(tbl='crons') /
        kv(tbl='generators'); lift them into the indexed tables and drop
        the kv copies.
        """
        for (val,) in self._conn.execute(
            "SELECT value FROM kv WHERE tbl='crons'"
        ).fetchall():
            e = json.loads(val)
            self._conn.execute(
                "INSERT OR IGNORE INTO crons VALUES (?,?,?,?)",
                (
                    e["cronid"],
                    e["colonyname"],
                    int(e.get("deadline", 0)),
                    json.dumps(e),
                ),
            )
        for (val,) in self._conn.execute(
            "SELECT value FROM kv WHERE tbl='generators'"
        ).fetchall():
            e = json.loads(val)
            self._conn.execute(
                "INSERT OR IGNORE INTO generators VALUES (?,?,?)",
                (e["generatorid"], e["colonyname"], json.dumps(e)),
            )
        self._conn.execute("DELETE FROM kv WHERE tbl IN ('crons','generators')")

    def _migrate_users(self) -> None:
        """Backfill the first-class users table from the seed's kv rows.

        Same pattern as :meth:`_migrate_cron_gen`: pre-index databases
        stored colony users as opaque JSON under kv(tbl='users'), keyed
        by the user's identity; lift them into the indexed table and drop
        the kv copies.
        """
        for key, val in self._conn.execute(
            "SELECT key, value FROM kv WHERE tbl='users'"
        ).fetchall():
            e = json.loads(val)
            self._conn.execute(
                "INSERT OR IGNORE INTO users VALUES (?,?,?)",
                (e.get("userid", key), e.get("colonyname", ""), json.dumps(e)),
            )
        self._conn.execute("DELETE FROM kv WHERE tbl='users'")

    def _rebuild_counts_if_missing(self) -> None:
        have = self._conn.execute("SELECT COUNT(*) FROM proc_counts").fetchone()[0]
        procs = self._conn.execute("SELECT COUNT(*) FROM processes").fetchone()[0]
        if have == 0 and procs > 0:
            self._conn.execute(
                "INSERT INTO proc_counts"
                " SELECT colonyname, state, COUNT(*) FROM processes"
                " GROUP BY colonyname, state"
            )

    @requires_lock("sqlite")
    def _exec(self, sql: str, args: Iterable[Any] = ()) -> sqlite3.Cursor:
        return self._conn.execute(sql, tuple(args))

    def colony_lock(self, colony: str) -> threading.RLock:
        with self._lock:
            lk = self._colony_locks.get(colony)
            if lk is None:
                lk = self._colony_locks[colony] = make_lock(f"dbcolony:{colony}")
            return lk

    # colonies
    def add_colony(self, colony: Colony) -> None:
        with self._lock:
            try:
                self._exec(
                    "INSERT INTO colonies VALUES (?, ?)",
                    (colony.colonyname, colony.colonyid),
                )
                self._conn.commit()
            except sqlite3.IntegrityError as e:
                raise ConflictError(f"colony {colony.colonyname} exists") from e

    def get_colony(self, name: str) -> Colony:
        with self._lock:
            row = self._exec(
                "SELECT colonyname, colonyid FROM colonies WHERE colonyname=?", (name,)
            ).fetchone()
            if row is None:
                raise NotFoundError(f"colony {name} not found")
            return Colony(colonyname=row[0], colonyid=row[1])

    def list_colonies(self) -> list[Colony]:
        with self._lock:
            rows = self._exec("SELECT colonyname, colonyid FROM colonies").fetchall()
            return [Colony(colonyname=r[0], colonyid=r[1]) for r in rows]

    # executors
    def add_executor(self, ex: Executor) -> None:
        with self._lock:
            try:
                self._exec(
                    "INSERT INTO executors VALUES (?,?,?,?,?,?,?,?)",
                    (
                        ex.executorid,
                        ex.executorname,
                        ex.executortype,
                        ex.colonyname,
                        ex.state,
                        ex.commissiontime_ns,
                        ex.lastheardfrom_ns,
                        json.dumps(ex.capabilities),
                    ),
                )
                self._conn.commit()
            except sqlite3.IntegrityError as e:
                raise ConflictError("executor exists or name taken") from e

    @staticmethod
    def _row_to_executor(row: tuple) -> Executor:
        return Executor(
            executorid=row[0],
            executorname=row[1],
            executortype=row[2],
            colonyname=row[3],
            state=row[4],
            commissiontime_ns=row[5],
            lastheardfrom_ns=row[6],
            capabilities=json.loads(row[7] or "{}"),
        )

    def get_executor(self, executorid: str) -> Executor:
        with self._lock:
            row = self._exec(
                "SELECT * FROM executors WHERE executorid=?", (executorid,)
            ).fetchone()
            if row is None:
                raise NotFoundError("executor not found")
            return self._row_to_executor(row)

    def get_executor_by_name(self, colony: str, name: str) -> Executor:
        with self._lock:
            row = self._exec(
                "SELECT * FROM executors WHERE colonyname=? AND executorname=?",
                (colony, name),
            ).fetchone()
            if row is None:
                raise NotFoundError(f"executor {name} not found")
            return self._row_to_executor(row)

    def list_executors(self, colony: str) -> list[Executor]:
        with self._lock:
            rows = self._exec(
                "SELECT * FROM executors WHERE colonyname=?", (colony,)
            ).fetchall()
            return [self._row_to_executor(r) for r in rows]

    def set_executor_state(self, executorid: str, state: str) -> None:
        with self._lock:
            cur = self._exec(
                "UPDATE executors SET state=? WHERE executorid=?", (state, executorid)
            )
            if cur.rowcount == 0:
                raise NotFoundError("executor not found")
            self._conn.commit()

    def remove_executor(self, executorid: str) -> None:
        with self._lock:
            cur = self._exec("DELETE FROM executors WHERE executorid=?", (executorid,))
            if cur.rowcount == 0:
                raise NotFoundError("executor not found")
            self._conn.commit()

    def touch_executor(self, executorid: str, ts: int) -> None:
        with self._lock:
            self._exec(
                "UPDATE executors SET lastheardfrom=? WHERE executorid=?",
                (ts, executorid),
            )
            self._conn.commit()

    # functions
    def add_function(self, executorid: str, colony: str, funcname: str) -> None:
        with self._lock:
            self._exec(
                "INSERT INTO functions VALUES (?,?,?)", (executorid, colony, funcname)
            )
            self._conn.commit()

    def list_functions(self, colony: str, executorid: str | None = None) -> list[dict]:
        with self._lock:
            if executorid is None:
                rows = self._exec(
                    "SELECT executorid, colonyname, funcname FROM functions WHERE colonyname=?",
                    (colony,),
                ).fetchall()
            else:
                rows = self._exec(
                    "SELECT executorid, colonyname, funcname FROM functions"
                    " WHERE colonyname=? AND executorid=?",
                    (colony, executorid),
                ).fetchall()
            return [
                {"executorid": r[0], "colonyname": r[1], "funcname": r[2]} for r in rows
            ]

    # processes
    @requires_lock("sqlite")
    def _bump_count(self, colony: str, state: str, delta: int) -> None:
        self._exec(
            "INSERT INTO proc_counts VALUES (?,?,?)"
            " ON CONFLICT(colonyname,state) DO UPDATE SET n=n+excluded.n",
            (colony, state, delta),
        )

    @requires_lock("sqlite")
    def _write_process(self, p: Process, insert: bool) -> None:
        body = p.to_json()
        if insert:
            self._exec(
                "INSERT INTO processes VALUES (?,?,?,?,?,?,?,?,?,?)",
                (
                    p.processid,
                    p.colonyname,
                    p.spec.conditions.executortype,
                    p.state,
                    int(p.wait_for_parents),
                    p.priority_time,
                    p.deadline_ns,
                    p.waitdeadline_ns,
                    _targets_column(p),
                    body,
                ),
            )
            self._bump_count(p.colonyname, p.state, +1)
        else:
            old = self._exec(
                "SELECT state FROM processes WHERE processid=?", (p.processid,)
            ).fetchone()
            if old is None:
                raise NotFoundError("process not found")
            self._exec(
                "UPDATE processes SET state=?, waitforparents=?, prioritytime=?,"
                " deadline=?, waitdeadline=?, targets=?, body=? WHERE processid=?",
                (
                    p.state,
                    int(p.wait_for_parents),
                    p.priority_time,
                    p.deadline_ns,
                    p.waitdeadline_ns,
                    _targets_column(p),
                    body,
                    p.processid,
                ),
            )
            if old[0] != p.state:
                self._bump_count(p.colonyname, old[0], -1)
                self._bump_count(p.colonyname, p.state, +1)
        self._conn.commit()

    def add_process(self, p: Process) -> None:
        # Fault point BEFORE the lock: an injected commit failure must not
        # abandon a held sqlite lock or a half-written transaction.
        faults.hit("db.commit", method="add_process")
        with self._lock:
            self._write_process(p, insert=True)

    def get_process(self, processid: str) -> Process:
        with self._lock:
            row = self._exec(
                "SELECT body FROM processes WHERE processid=?", (processid,)
            ).fetchone()
            if row is None:
                raise NotFoundError(f"process {processid} not found")
            return Process.from_json(row[0])

    def update_process(self, p: Process) -> None:
        faults.hit("db.commit", method="update_process")
        with self._lock:
            self._write_process(p, insert=False)

    def candidates(
        self, colony: str, executortype: str, executorname: str, limit: int = 8
    ) -> list[Process]:
        with self._lock:
            # The paper's queue query (§3.3): the table *is* the queue.
            # Targeting is part of the WHERE clause, so a process pinned to
            # another executor can never occupy this executor's queue head.
            rows = self._exec(
                "SELECT body FROM processes"
                " WHERE colonyname=? AND executortype=? AND state=? AND waitforparents=0"
                " AND (targets='' OR instr(targets, ?) > 0)"
                " ORDER BY prioritytime ASC LIMIT ?",
                (colony, executortype, WAITING, f"|{executorname}|", limit),
            ).fetchall()
            return [Process.from_json(body) for (body,) in rows]

    def list_processes(
        self, colony: str, state: str | None = None, count: int = 100
    ) -> list[Process]:
        with self._lock:
            if state is None:
                rows = self._exec(
                    "SELECT body FROM processes WHERE colonyname=?"
                    " ORDER BY prioritytime ASC LIMIT ?",
                    (colony, count),
                ).fetchall()
            else:
                rows = self._exec(
                    "SELECT body FROM processes WHERE colonyname=? AND state=?"
                    " ORDER BY prioritytime ASC LIMIT ?",
                    (colony, state, count),
                ).fetchall()
            return [Process.from_json(r[0]) for r in rows]

    def running_past_deadline(self, ts: int) -> list[Process]:
        with self._lock:
            # Range scan on idx_proc_deadline (state, deadline): O(expired).
            rows = self._exec(
                "SELECT body FROM processes WHERE state=? AND deadline>0 AND deadline<?",
                (RUNNING, ts),
            ).fetchall()
            return [Process.from_json(r[0]) for r in rows]

    def waiting_past_deadline(self, ts: int) -> list[Process]:
        with self._lock:
            # Range scan on idx_proc_waitdeadline (state, waitdeadline).
            rows = self._exec(
                "SELECT body FROM processes WHERE state=? AND waitdeadline>0 AND waitdeadline<?",
                (WAITING, ts),
            ).fetchall()
            return [Process.from_json(r[0]) for r in rows]

    def delete_process(self, processid: str) -> None:
        with self._lock:
            row = self._exec(
                "SELECT colonyname, state FROM processes WHERE processid=?",
                (processid,),
            ).fetchone()
            if row is None:
                return
            self._exec("DELETE FROM processes WHERE processid=?", (processid,))
            self._bump_count(row[0], row[1], -1)
            self._conn.commit()

    def colony_stats(self, colony: str) -> dict[str, int]:
        with self._lock:
            rows = self._exec(
                "SELECT state, n FROM proc_counts WHERE colonyname=? AND n>0",
                (colony,),
            ).fetchall()
            return {r[0]: r[1] for r in rows}

    def replica_state(self, colony: str) -> list[tuple]:
        from ..analysis.statehash import process_state_tuple

        with self._lock:
            rows = self._exec(
                "SELECT body FROM processes WHERE colonyname=?", (colony,)
            ).fetchall()
            return [process_state_tuple(Process.from_json(r[0])) for r in rows]

    def requeue(self, p: Process) -> None:  # row update already re-queues in SQL
        pass

    # -- RPC dedup (exactly-once keyed RPCs; ROBUSTNESS.md) -----------------

    def dedup_get(self, key: str) -> dict | None:
        with self._lock:
            row = self._exec(
                "SELECT ts, reply FROM rpc_dedup WHERE key=?", (key,)
            ).fetchone()
            if row is None or now_ns() - row[0] > DEDUP_TTL_NS:
                return None
            return json.loads(row[1])

    def dedup_put(self, key: str, colony: str, ts: int, reply) -> None:
        with self._lock:
            self._exec(
                "INSERT OR REPLACE INTO rpc_dedup VALUES (?,?,?,?)",
                (key, colony, ts, json.dumps(reply)),
            )
            # Amortized eviction (~1/128 puts): expired rows everywhere,
            # plus cap overflow in this colony via idx_rpc_dedup_colony.
            self._dedup_puts += 1
            if self._dedup_puts % 128 == 0:
                self._exec("DELETE FROM rpc_dedup WHERE ts<?", (ts - DEDUP_TTL_NS,))
                self._exec(
                    "DELETE FROM rpc_dedup WHERE key IN ("
                    " SELECT key FROM rpc_dedup WHERE colonyname=?"
                    " ORDER BY ts DESC LIMIT -1 OFFSET ?)",
                    (colony, DEDUP_MAX_PER_COLONY),
                )
            # The commit must happen per put: the handler's effect already
            # committed before this call, so without it this INSERT would
            # open a fresh write transaction and hold the file's RESERVED
            # lock indefinitely — any other connection to the same
            # database (broker restart, a second broker in the paper's
            # shared-DB model) hits "database is locked". A crash between
            # the effect commit and this one loses only the dedup record,
            # which re-executes the op on retry — the same outcome an
            # unkeyed retry produces (ROBUSTNESS.md).
            self._conn.commit()

    # -- CFS metadata -------------------------------------------------------

    def cfs_add_file(self, entry: dict) -> dict:
        with self._lock:
            row = self._exec(
                "SELECT revision FROM cfs_files"
                " WHERE colonyname=? AND label=? AND name=?"
                " ORDER BY revision DESC LIMIT 1",
                (entry["colonyname"], entry["label"], entry["name"]),
            ).fetchone()
            entry = dict(entry)
            entry["revision"] = (row[0] + 1) if row else 1
            self._exec(
                "INSERT INTO cfs_files VALUES (?,?,?,?,?,?)",
                (
                    entry["fileid"],
                    entry["colonyname"],
                    entry["label"],
                    entry["name"],
                    entry["revision"],
                    json.dumps(entry),
                ),
            )
            self._conn.commit()
            return entry

    def cfs_get_file(self, colony: str, fileid: str) -> dict | None:
        with self._lock:
            row = self._exec(
                "SELECT body FROM cfs_files WHERE fileid=? AND colonyname=?",
                (fileid, colony),
            ).fetchone()
            return json.loads(row[0]) if row else None

    def cfs_get_files_by_ids(self, colony: str, fileids: list[str]) -> list[dict | None]:
        found: dict[str, dict] = {}
        with self._lock:
            # chunked to stay under sqlite's bound-parameter limit
            for i in range(0, len(fileids), 500):
                chunk = fileids[i : i + 500]
                ph = ",".join("?" * len(chunk))
                rows = self._exec(
                    f"SELECT fileid, body FROM cfs_files"
                    f" WHERE colonyname=? AND fileid IN ({ph})",
                    (colony, *chunk),
                ).fetchall()
                for fid, body in rows:
                    found[fid] = json.loads(body)
        return [found.get(fid) for fid in fileids]

    def cfs_head(self, colony: str, label: str, name: str) -> dict | None:
        with self._lock:
            row = self._exec(
                "SELECT body FROM cfs_files"
                " WHERE colonyname=? AND label=? AND name=?"
                " ORDER BY revision DESC LIMIT 1",
                (colony, label, name),
            ).fetchone()
            return json.loads(row[0]) if row else None

    @requires_lock("sqlite")
    def _cfs_list_locked(self, colony: str, label: str) -> list[dict]:
        # Two range probes of idx_cfs_head (an OR'd predicate makes sqlite
        # fall back to scanning the whole colony prefix): the label itself,
        # then its descendants — exactly [label+'/', label+'0'), '0' being
        # the code point after '/'. The exact-label rows sort first, so
        # concatenation preserves (label, name) order. sqlite's
        # bare-column-with-MAX rule makes body the head revision's body.
        out = [
            json.loads(r[0])
            for r in self._exec(
                "SELECT body, MAX(revision) FROM cfs_files"
                " WHERE colonyname=? AND label=? GROUP BY name ORDER BY name",
                (colony, label),
            ).fetchall()
        ]
        # Strict lower bound: normalized labels never end in '/', so this
        # drops nothing for non-root prefixes and keeps the root itself
        # out of its own descendant range.
        lo, hi = (("/", "0") if label == "/" else (label + "/", label + "0"))
        out += [
            json.loads(r[0])
            for r in self._exec(
                "SELECT body, MAX(revision) FROM cfs_files"
                " WHERE colonyname=? AND label>? AND label<?"
                " GROUP BY label, name ORDER BY label, name",
                (colony, lo, hi),
            ).fetchall()
        ]
        return out

    def cfs_list(self, colony: str, label: str) -> list[dict]:
        with self._lock:
            return self._cfs_list_locked(colony, label)

    def cfs_remove_file(self, colony: str, fileid: str) -> dict | None:
        with self._lock:
            row = self._exec(
                "SELECT body FROM cfs_files WHERE fileid=? AND colonyname=?",
                (fileid, colony),
            ).fetchone()
            if row is None:
                return None
            pin = self._exec(
                "SELECT snapshotid FROM cfs_pins WHERE colonyname=? AND fileid=? LIMIT 1",
                (colony, fileid),
            ).fetchone()
            if pin is not None:
                raise ConflictError("file revision pinned by snapshot " + pin[0])
            self._exec("DELETE FROM cfs_files WHERE fileid=?", (fileid,))
            self._conn.commit()
            return json.loads(row[0])

    def cfs_pin_count(self, colony: str, fileid: str) -> int:
        with self._lock:
            return self._exec(
                "SELECT COUNT(*) FROM cfs_pins WHERE colonyname=? AND fileid=?",
                (colony, fileid),
            ).fetchone()[0]

    def cfs_create_snapshot(self, snap: dict) -> dict:
        with self._lock:
            snap = dict(snap)
            snap["fileids"] = [
                e["fileid"] for e in self._cfs_list_locked(snap["colonyname"], snap["label"])
            ]
            self._exec(
                "INSERT INTO cfs_snapshots VALUES (?,?,?)",
                (snap["snapshotid"], snap["colonyname"], json.dumps(snap)),
            )
            self._conn.executemany(
                "INSERT OR IGNORE INTO cfs_pins VALUES (?,?,?)",
                [(snap["colonyname"], fid, snap["snapshotid"]) for fid in snap["fileids"]],
            )
            self._conn.commit()
            return snap

    def cfs_get_snapshot(self, colony: str, snapshotid: str) -> dict | None:
        with self._lock:
            row = self._exec(
                "SELECT body FROM cfs_snapshots WHERE snapshotid=? AND colonyname=?",
                (snapshotid, colony),
            ).fetchone()
            return json.loads(row[0]) if row else None

    def cfs_list_snapshots(self, colony: str) -> list[dict]:
        with self._lock:
            rows = self._exec(
                "SELECT body FROM cfs_snapshots WHERE colonyname=?", (colony,)
            ).fetchall()
        snaps = [json.loads(r[0]) for r in rows]
        snaps.sort(key=lambda e: (e.get("added", 0), e["snapshotid"]))
        return snaps

    def cfs_remove_snapshot(self, colony: str, snapshotid: str) -> dict | None:
        with self._lock:
            row = self._exec(
                "SELECT body FROM cfs_snapshots WHERE snapshotid=? AND colonyname=?",
                (snapshotid, colony),
            ).fetchone()
            if row is None:
                return None
            self._exec("DELETE FROM cfs_snapshots WHERE snapshotid=?", (snapshotid,))
            self._exec(
                "DELETE FROM cfs_pins WHERE colonyname=? AND snapshotid=?",
                (colony, snapshotid),
            )
            self._conn.commit()
            return json.loads(row[0])

    # kv
    def kv_put(self, table: str, key: str, value: dict) -> None:
        with self._lock:
            self._exec(
                "INSERT INTO kv VALUES (?,?,?) ON CONFLICT(tbl,key) DO UPDATE SET value=excluded.value",
                (table, key, json.dumps(value)),
            )
            self._conn.commit()

    def kv_get(self, table: str, key: str) -> dict | None:
        with self._lock:
            row = self._exec(
                "SELECT value FROM kv WHERE tbl=? AND key=?", (table, key)
            ).fetchone()
            return json.loads(row[0]) if row else None

    def kv_del(self, table: str, key: str) -> None:
        with self._lock:
            self._exec("DELETE FROM kv WHERE tbl=? AND key=?", (table, key))
            self._conn.commit()

    def kv_list(self, table: str) -> list[dict]:
        with self._lock:
            rows = self._exec("SELECT value FROM kv WHERE tbl=?", (table,)).fetchall()
            return [json.loads(r[0]) for r in rows]

    def kv_append(self, table: str, key: str, value: dict) -> int:
        with self._lock:
            row = self._exec(
                "SELECT COALESCE(MAX(seq), -1) FROM kvlist WHERE tbl=? AND key=?",
                (table, key),
            ).fetchone()
            seq = row[0] + 1
            self._exec(
                "INSERT INTO kvlist VALUES (?,?,?,?)",
                (table, key, seq, json.dumps(value)),
            )
            self._conn.commit()
            cnt = self._exec(
                "SELECT COUNT(*) FROM kvlist WHERE tbl=? AND key=?", (table, key)
            ).fetchone()[0]
            return cnt

    def kv_take_all(self, table: str, key: str) -> list[dict]:
        with self._lock:
            rows = self._exec(
                "SELECT value FROM kvlist WHERE tbl=? AND key=? ORDER BY seq",
                (table, key),
            ).fetchall()
            self._exec("DELETE FROM kvlist WHERE tbl=? AND key=?", (table, key))
            self._conn.commit()
            return [json.loads(r[0]) for r in rows]

    def kv_len(self, table: str, key: str) -> int:
        with self._lock:
            return self._exec(
                "SELECT COUNT(*) FROM kvlist WHERE tbl=? AND key=?", (table, key)
            ).fetchone()[0]

    # cron / generator tables
    def cron_put(self, entry: dict) -> None:
        with self._lock:
            self._exec(
                "INSERT INTO crons VALUES (?,?,?,?) ON CONFLICT(cronid)"
                " DO UPDATE SET deadline=excluded.deadline, body=excluded.body",
                (
                    entry["cronid"],
                    entry["colonyname"],
                    int(entry.get("deadline", 0)),
                    json.dumps(entry),
                ),
            )
            self._conn.commit()

    def cron_get(self, cronid: str) -> dict | None:
        with self._lock:
            row = self._exec(
                "SELECT body FROM crons WHERE cronid=?", (cronid,)
            ).fetchone()
            return json.loads(row[0]) if row else None

    def cron_del(self, cronid: str) -> None:
        with self._lock:
            self._exec("DELETE FROM crons WHERE cronid=?", (cronid,))
            self._conn.commit()

    def cron_list(self, colony: str) -> list[dict]:
        with self._lock:
            rows = self._exec(
                "SELECT body FROM crons WHERE colonyname=?", (colony,)
            ).fetchall()
        entries = [json.loads(r[0]) for r in rows]
        entries.sort(key=lambda e: (e.get("added", 0), e["cronid"]))
        return entries

    def cron_due(self, ts: int) -> list[dict]:
        with self._lock:
            # Range scan on idx_crons_deadline: O(due), not O(crons).
            rows = self._exec(
                "SELECT body FROM crons WHERE deadline<? ORDER BY deadline", (ts,)
            ).fetchall()
        return [json.loads(r[0]) for r in rows]

    def generator_put(self, entry: dict) -> None:
        with self._lock:
            self._exec(
                "INSERT INTO generators VALUES (?,?,?) ON CONFLICT(generatorid)"
                " DO UPDATE SET body=excluded.body",
                (entry["generatorid"], entry["colonyname"], json.dumps(entry)),
            )
            self._conn.commit()

    def generator_get(self, generatorid: str) -> dict | None:
        with self._lock:
            row = self._exec(
                "SELECT body FROM generators WHERE generatorid=?", (generatorid,)
            ).fetchone()
            return json.loads(row[0]) if row else None

    def generator_del(self, generatorid: str) -> None:
        with self._lock:
            self._exec("DELETE FROM generators WHERE generatorid=?", (generatorid,))
            self._conn.commit()

    def generator_list(self, colony: str) -> list[dict]:
        with self._lock:
            rows = self._exec(
                "SELECT body FROM generators WHERE colonyname=?", (colony,)
            ).fetchall()
        entries = [json.loads(r[0]) for r in rows]
        entries.sort(key=lambda e: (e.get("added", 0), e["generatorid"]))
        return entries

    def generator_all(self) -> list[dict]:
        with self._lock:
            rows = self._exec("SELECT body FROM generators").fetchall()
            return [json.loads(r[0]) for r in rows]

    # colony users
    def user_put(self, entry: dict) -> None:
        with self._lock:
            self._exec(
                "INSERT INTO users VALUES (?,?,?) ON CONFLICT(userid)"
                " DO UPDATE SET colonyname=excluded.colonyname, body=excluded.body",
                (entry["userid"], entry["colonyname"], json.dumps(entry)),
            )
            self._conn.commit()

    def user_get(self, userid: str) -> dict | None:
        with self._lock:
            row = self._exec(
                "SELECT body FROM users WHERE userid=?", (userid,)
            ).fetchone()
            return json.loads(row[0]) if row else None

    def user_del(self, userid: str) -> None:
        with self._lock:
            self._exec("DELETE FROM users WHERE userid=?", (userid,))
            self._conn.commit()

    def user_list(self, colony: str) -> list[dict]:
        with self._lock:
            rows = self._exec(
                "SELECT body FROM users WHERE colonyname=?", (colony,)
            ).fetchall()
        entries = [json.loads(r[0]) for r in rows]
        entries.sort(key=lambda e: (e.get("name", ""), e["userid"]))
        return entries
