"""Queue-as-database (paper §3.2–3.3).

The paper's key departure from broker-based workflow systems: the queue
IS a standard database table, so assignment can match *any* column
(fine-grained per-executor targeting, capability matching, introspection)
and ordering is a plain ``ORDER BY priority_time``.

Two backends behind one interface:

* :class:`SqliteDatabase` — faithful to the paper (Postgres in the Go
  implementation): the candidate query is literally an ``ORDER BY
  priority_time ASC`` SQL select; file-backed (survives restarts) or
  ``:memory:``.
* :class:`MemoryDatabase` — per-(colony, executortype) bisect-sorted
  queues for broker micro-benchmarks; identical semantics.

Only ``assign`` mutates shared queue state non-monotonically, so it is
the only operation guarded by the assignment lock (paper §3.4.1:
"synchronization is not necessary for other requests").
"""

from __future__ import annotations

import bisect
import json
import sqlite3
import threading
from typing import Any, Iterable

from .errors import ConflictError, NotFoundError
from .process import (
    FAILED,
    RUNNING,
    SUCCESSFUL,
    WAITING,
    Colony,
    Executor,
    Process,
    now_ns,
)


class Database:
    """Abstract storage interface shared by all Colonies server replicas."""

    # -- colonies ---------------------------------------------------------
    def add_colony(self, colony: Colony) -> None:
        raise NotImplementedError

    def get_colony(self, name: str) -> Colony:
        raise NotImplementedError

    def list_colonies(self) -> list[Colony]:
        raise NotImplementedError

    # -- executors --------------------------------------------------------
    def add_executor(self, ex: Executor) -> None:
        raise NotImplementedError

    def get_executor(self, executorid: str) -> Executor:
        raise NotImplementedError

    def get_executor_by_name(self, colony: str, name: str) -> Executor:
        raise NotImplementedError

    def list_executors(self, colony: str) -> list[Executor]:
        raise NotImplementedError

    def set_executor_state(self, executorid: str, state: str) -> None:
        raise NotImplementedError

    def remove_executor(self, executorid: str) -> None:
        raise NotImplementedError

    def touch_executor(self, executorid: str, ts: int) -> None:
        raise NotImplementedError

    # -- function registry --------------------------------------------------
    def add_function(self, executorid: str, colony: str, funcname: str) -> None:
        raise NotImplementedError

    def list_functions(self, colony: str, executorid: str | None = None) -> list[dict]:
        raise NotImplementedError

    # -- processes ----------------------------------------------------------
    def add_process(self, p: Process) -> None:
        raise NotImplementedError

    def get_process(self, processid: str) -> Process:
        raise NotImplementedError

    def update_process(self, p: Process) -> None:
        raise NotImplementedError

    def candidates(
        self, colony: str, executortype: str, executorname: str, limit: int = 8
    ) -> list[Process]:
        """Waiting, parent-free processes for this executor, oldest priority first."""
        raise NotImplementedError

    def list_processes(
        self, colony: str, state: str | None = None, count: int = 100
    ) -> list[Process]:
        raise NotImplementedError

    def running_past_deadline(self, ts: int) -> list[Process]:
        raise NotImplementedError

    def waiting_past_deadline(self, ts: int) -> list[Process]:
        raise NotImplementedError

    def delete_process(self, processid: str) -> None:
        raise NotImplementedError

    # -- key/value side tables (cron, generators, CFS metadata) -------------
    def kv_put(self, table: str, key: str, value: dict) -> None:
        raise NotImplementedError

    def kv_get(self, table: str, key: str) -> dict | None:
        raise NotImplementedError

    def kv_del(self, table: str, key: str) -> None:
        raise NotImplementedError

    def kv_list(self, table: str) -> list[dict]:
        raise NotImplementedError

    def kv_append(self, table: str, key: str, value: dict) -> int:
        """Append to a list bucket; returns new length (generator pack queues)."""
        raise NotImplementedError

    def kv_take_all(self, table: str, key: str) -> list[dict]:
        """Atomically drain a list bucket."""
        raise NotImplementedError

    def kv_len(self, table: str, key: str) -> int:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# In-memory backend
# ---------------------------------------------------------------------------


class MemoryDatabase(Database):
    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._colonies: dict[str, Colony] = {}
        self._executors: dict[str, Executor] = {}
        self._functions: list[dict] = []
        self._processes: dict[str, Process] = {}
        # (colony, executortype) -> sorted list of (priority_time, processid)
        self._queues: dict[tuple[str, str], list[tuple[int, str]]] = {}
        self._kv: dict[str, dict[str, dict]] = {}
        self._kvlists: dict[str, dict[str, list[dict]]] = {}

    # colonies
    def add_colony(self, colony: Colony) -> None:
        with self._lock:
            if colony.colonyname in self._colonies:
                raise ConflictError(f"colony {colony.colonyname} exists")
            self._colonies[colony.colonyname] = colony

    def get_colony(self, name: str) -> Colony:
        with self._lock:
            c = self._colonies.get(name)
            if c is None:
                raise NotFoundError(f"colony {name} not found")
            return c

    def list_colonies(self) -> list[Colony]:
        with self._lock:
            return list(self._colonies.values())

    # executors
    def add_executor(self, ex: Executor) -> None:
        with self._lock:
            if ex.executorid in self._executors:
                raise ConflictError("executor exists")
            for other in self._executors.values():
                if (
                    other.colonyname == ex.colonyname
                    and other.executorname == ex.executorname
                ):
                    raise ConflictError(f"executor name {ex.executorname} taken")
            self._executors[ex.executorid] = ex

    def get_executor(self, executorid: str) -> Executor:
        with self._lock:
            ex = self._executors.get(executorid)
            if ex is None:
                raise NotFoundError("executor not found")
            return ex

    def get_executor_by_name(self, colony: str, name: str) -> Executor:
        with self._lock:
            for ex in self._executors.values():
                if ex.colonyname == colony and ex.executorname == name:
                    return ex
            raise NotFoundError(f"executor {name} not found")

    def list_executors(self, colony: str) -> list[Executor]:
        with self._lock:
            return [e for e in self._executors.values() if e.colonyname == colony]

    def set_executor_state(self, executorid: str, state: str) -> None:
        with self._lock:
            self.get_executor(executorid).state = state

    def remove_executor(self, executorid: str) -> None:
        with self._lock:
            if executorid not in self._executors:
                raise NotFoundError("executor not found")
            del self._executors[executorid]

    def touch_executor(self, executorid: str, ts: int) -> None:
        with self._lock:
            ex = self._executors.get(executorid)
            if ex is not None:
                ex.lastheardfrom_ns = ts

    # functions
    def add_function(self, executorid: str, colony: str, funcname: str) -> None:
        with self._lock:
            self._functions.append(
                {"executorid": executorid, "colonyname": colony, "funcname": funcname}
            )

    def list_functions(self, colony: str, executorid: str | None = None) -> list[dict]:
        with self._lock:
            return [
                dict(f)
                for f in self._functions
                if f["colonyname"] == colony
                and (executorid is None or f["executorid"] == executorid)
            ]

    # processes
    def _queue_key(self, p: Process) -> tuple[str, str]:
        return (p.colonyname, p.spec.conditions.executortype)

    def add_process(self, p: Process) -> None:
        with self._lock:
            self._processes[p.processid] = p
            self._enqueue(p)

    def _enqueue(self, p: Process) -> None:
        q = self._queues.setdefault(self._queue_key(p), [])
        bisect.insort(q, (p.priority_time, p.processid))

    def get_process(self, processid: str) -> Process:
        with self._lock:
            p = self._processes.get(processid)
            if p is None:
                raise NotFoundError(f"process {processid} not found")
            return p

    def update_process(self, p: Process) -> None:
        with self._lock:
            if p.processid not in self._processes:
                raise NotFoundError("process not found")
            self._processes[p.processid] = p

    def requeue(self, p: Process) -> None:
        """Re-insert a reset process (failsafe path)."""
        with self._lock:
            self._enqueue(p)

    def candidates(
        self, colony: str, executortype: str, executorname: str, limit: int = 8
    ) -> list[Process]:
        with self._lock:
            q = self._queues.get((colony, executortype), [])
            out: list[Process] = []
            stale: list[tuple[int, str]] = []
            for item in q:
                _, pid = item
                p = self._processes.get(pid)
                if p is None or p.state != WAITING:
                    stale.append(item)  # lazily drop assigned/closed entries
                    continue
                if p.wait_for_parents:
                    continue
                targets = p.spec.conditions.executornames
                if targets and executorname not in targets:
                    continue
                out.append(p)
                if len(out) >= limit:
                    break
            for item in stale:
                q.remove(item)
            return out

    def list_processes(
        self, colony: str, state: str | None = None, count: int = 100
    ) -> list[Process]:
        with self._lock:
            out = [
                p
                for p in self._processes.values()
                if p.colonyname == colony and (state is None or p.state == state)
            ]
            out.sort(key=lambda p: p.priority_time)
            return out[:count]

    def running_past_deadline(self, ts: int) -> list[Process]:
        with self._lock:
            return [
                p
                for p in self._processes.values()
                if p.state == RUNNING and p.deadline_ns and p.deadline_ns < ts
            ]

    def waiting_past_deadline(self, ts: int) -> list[Process]:
        with self._lock:
            return [
                p
                for p in self._processes.values()
                if p.state == WAITING and p.waitdeadline_ns and p.waitdeadline_ns < ts
            ]

    def delete_process(self, processid: str) -> None:
        with self._lock:
            self._processes.pop(processid, None)

    # kv
    def kv_put(self, table: str, key: str, value: dict) -> None:
        with self._lock:
            self._kv.setdefault(table, {})[key] = dict(value)

    def kv_get(self, table: str, key: str) -> dict | None:
        with self._lock:
            v = self._kv.get(table, {}).get(key)
            return dict(v) if v is not None else None

    def kv_del(self, table: str, key: str) -> None:
        with self._lock:
            self._kv.get(table, {}).pop(key, None)

    def kv_list(self, table: str) -> list[dict]:
        with self._lock:
            return [dict(v) for v in self._kv.get(table, {}).values()]

    def kv_append(self, table: str, key: str, value: dict) -> int:
        with self._lock:
            lst = self._kvlists.setdefault(table, {}).setdefault(key, [])
            lst.append(dict(value))
            return len(lst)

    def kv_take_all(self, table: str, key: str) -> list[dict]:
        with self._lock:
            lst = self._kvlists.get(table, {}).pop(key, [])
            return lst

    def kv_len(self, table: str, key: str) -> int:
        with self._lock:
            return len(self._kvlists.get(table, {}).get(key, []))


# ---------------------------------------------------------------------------
# Sqlite backend — the paper's SQL queue, verbatim semantics
# ---------------------------------------------------------------------------

_SCHEMA = """
CREATE TABLE IF NOT EXISTS colonies (
    colonyname TEXT PRIMARY KEY, colonyid TEXT NOT NULL
);
CREATE TABLE IF NOT EXISTS executors (
    executorid TEXT PRIMARY KEY, executorname TEXT, executortype TEXT,
    colonyname TEXT, state TEXT, commissiontime INTEGER, lastheardfrom INTEGER,
    capabilities TEXT,
    UNIQUE(colonyname, executorname)
);
CREATE TABLE IF NOT EXISTS functions (
    executorid TEXT, colonyname TEXT, funcname TEXT
);
CREATE TABLE IF NOT EXISTS processes (
    processid TEXT PRIMARY KEY,
    colonyname TEXT NOT NULL,
    executortype TEXT NOT NULL,
    state TEXT NOT NULL,
    waitforparents INTEGER NOT NULL DEFAULT 0,
    prioritytime INTEGER NOT NULL,
    deadline INTEGER NOT NULL DEFAULT 0,
    waitdeadline INTEGER NOT NULL DEFAULT 0,
    body TEXT NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_proc_queue
    ON processes (colonyname, executortype, state, waitforparents, prioritytime);
CREATE INDEX IF NOT EXISTS idx_proc_deadline ON processes (state, deadline);
CREATE TABLE IF NOT EXISTS kv (
    tbl TEXT NOT NULL, key TEXT NOT NULL, value TEXT NOT NULL,
    PRIMARY KEY (tbl, key)
);
CREATE TABLE IF NOT EXISTS kvlist (
    tbl TEXT NOT NULL, key TEXT NOT NULL, seq INTEGER NOT NULL, value TEXT NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_kvlist ON kvlist (tbl, key, seq);
"""


class SqliteDatabase(Database):
    """File-backed (or ``:memory:``) SQL queue.

    The candidate query is the paper's: ``ORDER BY prioritytime ASC`` over
    indexed (colony, executortype, state, waitforparents) columns.
    """

    def __init__(self, path: str = ":memory:") -> None:
        self._lock = threading.RLock()
        self._conn = sqlite3.connect(path, check_same_thread=False)
        self._conn.execute("PRAGMA journal_mode=WAL")
        self._conn.executescript(_SCHEMA)
        self._conn.commit()

    def _exec(self, sql: str, args: Iterable[Any] = ()) -> sqlite3.Cursor:
        return self._conn.execute(sql, tuple(args))

    # colonies
    def add_colony(self, colony: Colony) -> None:
        with self._lock:
            try:
                self._exec(
                    "INSERT INTO colonies VALUES (?, ?)",
                    (colony.colonyname, colony.colonyid),
                )
                self._conn.commit()
            except sqlite3.IntegrityError as e:
                raise ConflictError(f"colony {colony.colonyname} exists") from e

    def get_colony(self, name: str) -> Colony:
        with self._lock:
            row = self._exec(
                "SELECT colonyname, colonyid FROM colonies WHERE colonyname=?", (name,)
            ).fetchone()
            if row is None:
                raise NotFoundError(f"colony {name} not found")
            return Colony(colonyname=row[0], colonyid=row[1])

    def list_colonies(self) -> list[Colony]:
        with self._lock:
            rows = self._exec("SELECT colonyname, colonyid FROM colonies").fetchall()
            return [Colony(colonyname=r[0], colonyid=r[1]) for r in rows]

    # executors
    def add_executor(self, ex: Executor) -> None:
        with self._lock:
            try:
                self._exec(
                    "INSERT INTO executors VALUES (?,?,?,?,?,?,?,?)",
                    (
                        ex.executorid,
                        ex.executorname,
                        ex.executortype,
                        ex.colonyname,
                        ex.state,
                        ex.commissiontime_ns,
                        ex.lastheardfrom_ns,
                        json.dumps(ex.capabilities),
                    ),
                )
                self._conn.commit()
            except sqlite3.IntegrityError as e:
                raise ConflictError("executor exists or name taken") from e

    @staticmethod
    def _row_to_executor(row: tuple) -> Executor:
        return Executor(
            executorid=row[0],
            executorname=row[1],
            executortype=row[2],
            colonyname=row[3],
            state=row[4],
            commissiontime_ns=row[5],
            lastheardfrom_ns=row[6],
            capabilities=json.loads(row[7] or "{}"),
        )

    def get_executor(self, executorid: str) -> Executor:
        with self._lock:
            row = self._exec(
                "SELECT * FROM executors WHERE executorid=?", (executorid,)
            ).fetchone()
            if row is None:
                raise NotFoundError("executor not found")
            return self._row_to_executor(row)

    def get_executor_by_name(self, colony: str, name: str) -> Executor:
        with self._lock:
            row = self._exec(
                "SELECT * FROM executors WHERE colonyname=? AND executorname=?",
                (colony, name),
            ).fetchone()
            if row is None:
                raise NotFoundError(f"executor {name} not found")
            return self._row_to_executor(row)

    def list_executors(self, colony: str) -> list[Executor]:
        with self._lock:
            rows = self._exec(
                "SELECT * FROM executors WHERE colonyname=?", (colony,)
            ).fetchall()
            return [self._row_to_executor(r) for r in rows]

    def set_executor_state(self, executorid: str, state: str) -> None:
        with self._lock:
            cur = self._exec(
                "UPDATE executors SET state=? WHERE executorid=?", (state, executorid)
            )
            if cur.rowcount == 0:
                raise NotFoundError("executor not found")
            self._conn.commit()

    def remove_executor(self, executorid: str) -> None:
        with self._lock:
            cur = self._exec("DELETE FROM executors WHERE executorid=?", (executorid,))
            if cur.rowcount == 0:
                raise NotFoundError("executor not found")
            self._conn.commit()

    def touch_executor(self, executorid: str, ts: int) -> None:
        with self._lock:
            self._exec(
                "UPDATE executors SET lastheardfrom=? WHERE executorid=?",
                (ts, executorid),
            )
            self._conn.commit()

    # functions
    def add_function(self, executorid: str, colony: str, funcname: str) -> None:
        with self._lock:
            self._exec(
                "INSERT INTO functions VALUES (?,?,?)", (executorid, colony, funcname)
            )
            self._conn.commit()

    def list_functions(self, colony: str, executorid: str | None = None) -> list[dict]:
        with self._lock:
            if executorid is None:
                rows = self._exec(
                    "SELECT executorid, colonyname, funcname FROM functions WHERE colonyname=?",
                    (colony,),
                ).fetchall()
            else:
                rows = self._exec(
                    "SELECT executorid, colonyname, funcname FROM functions"
                    " WHERE colonyname=? AND executorid=?",
                    (colony, executorid),
                ).fetchall()
            return [
                {"executorid": r[0], "colonyname": r[1], "funcname": r[2]} for r in rows
            ]

    # processes
    def _write_process(self, p: Process, insert: bool) -> None:
        body = p.to_json()
        if insert:
            self._exec(
                "INSERT INTO processes VALUES (?,?,?,?,?,?,?,?,?)",
                (
                    p.processid,
                    p.colonyname,
                    p.spec.conditions.executortype,
                    p.state,
                    int(p.wait_for_parents),
                    p.priority_time,
                    p.deadline_ns,
                    p.waitdeadline_ns,
                    body,
                ),
            )
        else:
            cur = self._exec(
                "UPDATE processes SET state=?, waitforparents=?, prioritytime=?,"
                " deadline=?, waitdeadline=?, body=? WHERE processid=?",
                (
                    p.state,
                    int(p.wait_for_parents),
                    p.priority_time,
                    p.deadline_ns,
                    p.waitdeadline_ns,
                    body,
                    p.processid,
                ),
            )
            if cur.rowcount == 0:
                raise NotFoundError("process not found")
        self._conn.commit()

    def add_process(self, p: Process) -> None:
        with self._lock:
            self._write_process(p, insert=True)

    def get_process(self, processid: str) -> Process:
        with self._lock:
            row = self._exec(
                "SELECT body FROM processes WHERE processid=?", (processid,)
            ).fetchone()
            if row is None:
                raise NotFoundError(f"process {processid} not found")
            return Process.from_json(row[0])

    def update_process(self, p: Process) -> None:
        with self._lock:
            self._write_process(p, insert=False)

    def candidates(
        self, colony: str, executortype: str, executorname: str, limit: int = 8
    ) -> list[Process]:
        with self._lock:
            # The paper's queue query (§3.3): the table *is* the queue.
            rows = self._exec(
                "SELECT body FROM processes"
                " WHERE colonyname=? AND executortype=? AND state=? AND waitforparents=0"
                " ORDER BY prioritytime ASC LIMIT ?",
                (colony, executortype, WAITING, limit * 4),
            ).fetchall()
            out = []
            for (body,) in rows:
                p = Process.from_json(body)
                targets = p.spec.conditions.executornames
                if targets and executorname not in targets:
                    continue
                out.append(p)
                if len(out) >= limit:
                    break
            return out

    def list_processes(
        self, colony: str, state: str | None = None, count: int = 100
    ) -> list[Process]:
        with self._lock:
            if state is None:
                rows = self._exec(
                    "SELECT body FROM processes WHERE colonyname=?"
                    " ORDER BY prioritytime ASC LIMIT ?",
                    (colony, count),
                ).fetchall()
            else:
                rows = self._exec(
                    "SELECT body FROM processes WHERE colonyname=? AND state=?"
                    " ORDER BY prioritytime ASC LIMIT ?",
                    (colony, state, count),
                ).fetchall()
            return [Process.from_json(r[0]) for r in rows]

    def running_past_deadline(self, ts: int) -> list[Process]:
        with self._lock:
            rows = self._exec(
                "SELECT body FROM processes WHERE state=? AND deadline>0 AND deadline<?",
                (RUNNING, ts),
            ).fetchall()
            return [Process.from_json(r[0]) for r in rows]

    def waiting_past_deadline(self, ts: int) -> list[Process]:
        with self._lock:
            rows = self._exec(
                "SELECT body FROM processes WHERE state=? AND waitdeadline>0 AND waitdeadline<?",
                (WAITING, ts),
            ).fetchall()
            return [Process.from_json(r[0]) for r in rows]

    def delete_process(self, processid: str) -> None:
        with self._lock:
            self._exec("DELETE FROM processes WHERE processid=?", (processid,))
            self._conn.commit()

    def requeue(self, p: Process) -> None:  # row update already re-queues in SQL
        pass

    # kv
    def kv_put(self, table: str, key: str, value: dict) -> None:
        with self._lock:
            self._exec(
                "INSERT INTO kv VALUES (?,?,?) ON CONFLICT(tbl,key) DO UPDATE SET value=excluded.value",
                (table, key, json.dumps(value)),
            )
            self._conn.commit()

    def kv_get(self, table: str, key: str) -> dict | None:
        with self._lock:
            row = self._exec(
                "SELECT value FROM kv WHERE tbl=? AND key=?", (table, key)
            ).fetchone()
            return json.loads(row[0]) if row else None

    def kv_del(self, table: str, key: str) -> None:
        with self._lock:
            self._exec("DELETE FROM kv WHERE tbl=? AND key=?", (table, key))
            self._conn.commit()

    def kv_list(self, table: str) -> list[dict]:
        with self._lock:
            rows = self._exec("SELECT value FROM kv WHERE tbl=?", (table,)).fetchall()
            return [json.loads(r[0]) for r in rows]

    def kv_append(self, table: str, key: str, value: dict) -> int:
        with self._lock:
            row = self._exec(
                "SELECT COALESCE(MAX(seq), -1) FROM kvlist WHERE tbl=? AND key=?",
                (table, key),
            ).fetchone()
            seq = row[0] + 1
            self._exec(
                "INSERT INTO kvlist VALUES (?,?,?,?)",
                (table, key, seq, json.dumps(value)),
            )
            self._conn.commit()
            cnt = self._exec(
                "SELECT COUNT(*) FROM kvlist WHERE tbl=? AND key=?", (table, key)
            ).fetchone()[0]
            return cnt

    def kv_take_all(self, table: str, key: str) -> list[dict]:
        with self._lock:
            rows = self._exec(
                "SELECT value FROM kvlist WHERE tbl=? AND key=? ORDER BY seq",
                (table, key),
            ).fetchall()
            self._exec("DELETE FROM kvlist WHERE tbl=? AND key=?", (table, key))
            self._conn.commit()
            return [json.loads(r[0]) for r in rows]

    def kv_len(self, table: str, key: str) -> int:
        with self._lock:
            return self._exec(
                "SELECT COUNT(*) FROM kvlist WHERE tbl=? AND key=?", (table, key)
            ).fetchone()[0]
