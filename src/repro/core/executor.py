"""Executor microservice base (paper §3.1, §4.1 Listings 3–4).

An executor is a small, independently deployable service that:
  1. generates an identity and is registered+approved by the colony owner,
  2. announces the functions it can run,
  3. long-polls ``assign`` and dispatches to registered handlers,
  4. closes processes with output (or failure), optionally extending the
     DAG with dynamic children.

Function handlers receive ``(ctx, *args, **kwargs)`` where ``ctx`` exposes
the process, the SDK client and CFS sync helpers.
"""

from __future__ import annotations

import os
import random
import threading
import time
import traceback
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable

from .client import Colonies
from .crypto import Crypto
from .errors import (
    ColoniesError,
    ConflictError,
    NotLeaderError,
    TimeoutError_,
    TransportError,
)
from .process import Process, new_id

# Pending-close journal bounds: per-result delivery attempts before the
# result is declared lost, and the capped base backoff between attempts.
PENDING_MAX_ATTEMPTS = 8
PENDING_BACKOFF_BASE_S = 0.05
PENDING_BACKOFF_CAP_S = 2.0


@dataclass
class _PendingClose:
    """A computed result whose delivery to the broker failed retryably.

    The msgid is fixed at creation and reused on every re-delivery, so
    the server's dedup table collapses them into one close even when an
    earlier attempt committed but lost its reply (ROBUSTNESS.md)."""

    processid: str
    successful: bool
    out: list
    errors: list
    msgid: str
    counted: bool  # outcome already reflected in processed/failed
    attempts: int = 0
    next_try: float = 0.0


@dataclass
class ProcessContext:
    process: Process
    client: Colonies
    executor: "ExecutorBase"
    workdir: str = ""
    extras: dict[str, Any] = field(default_factory=dict)

    @property
    def inputs(self) -> list[Any]:
        return self.process.inputs

    def add_child(self, spec: dict, waitforparent: bool = False) -> dict:
        return self.client.add_child(
            self.process.processid, spec, self.executor.prvkey, waitforparent
        )


class ExecutorBase:
    """Long-poll worker; subclass or register function handlers directly."""

    def __init__(
        self,
        client: Colonies,
        colonyname: str,
        executorname: str,
        executortype: str,
        colony_prvkey: str | None = None,
        prvkey: str | None = None,
        capabilities: dict[str, Any] | None = None,
        workdir_root: str | None = None,
    ) -> None:
        self.client = client
        self.colonyname = colonyname
        self.executorname = executorname
        self.executortype = executortype
        # When set, every assigned process gets its own directory under
        # this root (ctx.workdir) — the sandbox the CFS sync directives
        # (fs.snapshots / fs.dirs) materialize into and upload from.
        self.workdir_root = workdir_root
        self.prvkey = prvkey or Crypto.prvkey()
        self.executorid = Crypto.id(self.prvkey)
        self.capabilities = capabilities or {}
        self._handlers: dict[str, Callable[..., Any]] = {}
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.processed = 0
        self.failed = 0
        # Pending-close journal: computed results whose delivery failed
        # retryably wait here for flush_pending_closes instead of being
        # dropped. The lock guards only list swaps — never held across RPC.
        self._pending: list[_PendingClose] = []
        self._pending_lock = threading.Lock()
        # Deterministic per-executor jitter (tests may override _rng).
        self._rng = random.Random(zlib.crc32(self.executorid.encode()))
        if colony_prvkey is not None:
            self.register(colony_prvkey)

    # ------------------------------------------------------------ lifecycle
    def register(self, colony_prvkey: str) -> None:
        self.client.add_executor(
            {
                "executorname": self.executorname,
                "executorid": self.executorid,
                "colonyname": self.colonyname,
                "executortype": self.executortype,
                "capabilities": self.capabilities,
            },
            colony_prvkey,
        )
        self.client.approve_executor(self.executorid, colony_prvkey)

    def register_function(self, funcname: str, fn: Callable[..., Any]) -> None:
        self._handlers[funcname] = fn
        self.client.add_function(self.executorid, self.colonyname, funcname, self.prvkey)

    # ------------------------------------------------------------ main loop
    def step(self, timeout: float = 1.0) -> bool:
        """One assign+execute+close cycle; returns True if a process ran."""
        try:
            pd = self.client.assign(self.colonyname, timeout, self.prvkey)
        except (TimeoutError_, NotLeaderError):
            return False
        process = Process.from_dict(pd)
        self._execute(process)
        return True

    def _execute(self, process: Process) -> None:
        funcname = process.spec.funcname
        fn = self._handlers.get(funcname)
        ctx = ProcessContext(process=process, client=self.client, executor=self)
        if self.workdir_root:
            ctx.workdir = os.path.join(self.workdir_root, process.processid)
            os.makedirs(ctx.workdir, exist_ok=True)
        # Run the handler and deliver the result in separate phases, so a
        # transport failure during delivery is never misread as a handler
        # failure (and vice versa).
        try:
            if fn is None:
                raise ColoniesError(f"no handler for function {funcname!r}")
            self._sync_before(ctx)
            out = fn(ctx, *process.spec.args, **process.spec.kwargs)
            self._sync_after(ctx)
            if out is None:
                out = []
            elif not isinstance(out, list):
                out = [out]
        except Exception as e:  # noqa: BLE001 — report any failure to the broker
            if getattr(e, "simulate_crash", False):
                # Chaos: vanish WITHOUT closing — the broker's maxexectime
                # failsafe must detect the lost lease and re-queue.
                raise
            self.failed += 1
            self._deliver_close(
                process.processid,
                successful=False,
                out=[],
                errors=[f"{type(e).__name__}: {e}", traceback.format_exc(limit=5)],
                counted=True,
            )
            return
        self._deliver_close(
            process.processid, successful=True, out=out, errors=[], counted=False
        )

    # --------------------------------------------------- result delivery
    def _deliver_close(
        self, processid: str, *, successful: bool, out: list, errors: list,
        counted: bool,
    ) -> None:
        """Deliver a close now; journal it for retry if the transport fails."""
        pc = _PendingClose(
            processid=processid,
            successful=successful,
            out=out,
            errors=errors,
            # "" when the client opts out of idempotency keys: the close
            # goes out unkeyed and re-deliveries rely on ConflictError.
            msgid=new_id() if self.client.idempotency else "",
            counted=counted,
        )
        if not self._try_deliver(pc):
            with self._pending_lock:
                self._pending.append(pc)

    def _try_deliver(self, pc: _PendingClose) -> bool:
        """One delivery attempt. True = settled (delivered or dropped),
        False = journal for another try after ``pc.next_try``."""
        pc.attempts += 1
        try:
            if pc.successful:
                self.client.close(pc.processid, pc.out, self.prvkey, msgid=pc.msgid)
            else:
                self.client.fail(pc.processid, pc.errors, self.prvkey, msgid=pc.msgid)
        except ConflictError:
            # Lost the lease (failsafe reset while we were computing) —
            # the paper's expected behaviour; drop the result silently.
            if not pc.counted:
                self.failed += 1
            return True
        except (TransportError, TimeoutError_, NotLeaderError):
            if pc.attempts >= PENDING_MAX_ATTEMPTS:
                if not pc.counted:
                    self.failed += 1
                return True
            backoff = min(
                PENDING_BACKOFF_CAP_S,
                PENDING_BACKOFF_BASE_S * 2 ** (pc.attempts - 1),
            )
            pc.next_try = time.monotonic() + backoff * (0.5 + self._rng.random() / 2)
            return False
        except ColoniesError:
            # Application-level rejection (auth, validation): retrying the
            # same request can't succeed.
            if not pc.counted:
                self.failed += 1
            return True
        if not pc.counted:
            self.processed += 1
        return True

    def flush_pending_closes(self, force: bool = False) -> int:
        """Re-deliver journaled closes whose backoff elapsed (all of them
        when ``force``); returns how many remain pending."""
        with self._pending_lock:
            pending, self._pending = self._pending, []
        now = time.monotonic()
        keep = [
            pc
            for pc in pending
            if (not force and now < pc.next_try) or not self._try_deliver(pc)
        ]
        with self._pending_lock:
            self._pending = keep + self._pending
            return len(self._pending)

    # CFS hooks — overridden by executors that mount snapshots (runtime/).
    def _sync_before(self, ctx: ProcessContext) -> None:
        pass

    def _sync_after(self, ctx: ProcessContext) -> None:
        pass

    def run_forever(self, poll_timeout: float = 1.0) -> None:
        consecutive_errors = 0
        while not self._stop.is_set():
            self.flush_pending_closes()
            try:
                self.step(poll_timeout)
            except ColoniesError:
                # Broker unreachable or erroring: back off exponentially
                # (capped, jittered) instead of hammering it every 50 ms.
                consecutive_errors += 1
                self._stop.wait(self._error_backoff(consecutive_errors))
            else:
                consecutive_errors = 0

    def _error_backoff(self, consecutive_errors: int) -> float:
        base = min(
            PENDING_BACKOFF_CAP_S,
            PENDING_BACKOFF_BASE_S * 2 ** min(consecutive_errors - 1, 8),
        )
        return base * (0.5 + self._rng.random() / 2)

    def start(self, poll_timeout: float = 1.0) -> None:
        self._thread = threading.Thread(
            target=self.run_forever, args=(poll_timeout,), daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
        # Graceful drain: give journaled results a last bounded round of
        # delivery attempts instead of discarding computed work.
        for _ in range(3):
            if self.flush_pending_closes(force=True) == 0:
                break
