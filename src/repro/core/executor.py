"""Executor microservice base (paper §3.1, §4.1 Listings 3–4).

An executor is a small, independently deployable service that:
  1. generates an identity and is registered+approved by the colony owner,
  2. announces the functions it can run,
  3. long-polls ``assign`` and dispatches to registered handlers,
  4. closes processes with output (or failure), optionally extending the
     DAG with dynamic children.

Function handlers receive ``(ctx, *args, **kwargs)`` where ``ctx`` exposes
the process, the SDK client and CFS sync helpers.
"""

from __future__ import annotations

import threading
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable

from .client import Colonies
from .crypto import Crypto
from .errors import ColoniesError, ConflictError, NotLeaderError, TimeoutError_
from .process import Process


@dataclass
class ProcessContext:
    process: Process
    client: Colonies
    executor: "ExecutorBase"
    workdir: str = ""
    extras: dict[str, Any] = field(default_factory=dict)

    @property
    def inputs(self) -> list[Any]:
        return self.process.inputs

    def add_child(self, spec: dict, waitforparent: bool = False) -> dict:
        return self.client.add_child(
            self.process.processid, spec, self.executor.prvkey, waitforparent
        )


class ExecutorBase:
    """Long-poll worker; subclass or register function handlers directly."""

    def __init__(
        self,
        client: Colonies,
        colonyname: str,
        executorname: str,
        executortype: str,
        colony_prvkey: str | None = None,
        prvkey: str | None = None,
        capabilities: dict[str, Any] | None = None,
    ) -> None:
        self.client = client
        self.colonyname = colonyname
        self.executorname = executorname
        self.executortype = executortype
        self.prvkey = prvkey or Crypto.prvkey()
        self.executorid = Crypto.id(self.prvkey)
        self.capabilities = capabilities or {}
        self._handlers: dict[str, Callable[..., Any]] = {}
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.processed = 0
        self.failed = 0
        if colony_prvkey is not None:
            self.register(colony_prvkey)

    # ------------------------------------------------------------ lifecycle
    def register(self, colony_prvkey: str) -> None:
        self.client.add_executor(
            {
                "executorname": self.executorname,
                "executorid": self.executorid,
                "colonyname": self.colonyname,
                "executortype": self.executortype,
                "capabilities": self.capabilities,
            },
            colony_prvkey,
        )
        self.client.approve_executor(self.executorid, colony_prvkey)

    def register_function(self, funcname: str, fn: Callable[..., Any]) -> None:
        self._handlers[funcname] = fn
        self.client.add_function(self.executorid, self.colonyname, funcname, self.prvkey)

    # ------------------------------------------------------------ main loop
    def step(self, timeout: float = 1.0) -> bool:
        """One assign+execute+close cycle; returns True if a process ran."""
        try:
            pd = self.client.assign(self.colonyname, timeout, self.prvkey)
        except (TimeoutError_, NotLeaderError):
            return False
        process = Process.from_dict(pd)
        self._execute(process)
        return True

    def _execute(self, process: Process) -> None:
        funcname = process.spec.funcname
        fn = self._handlers.get(funcname)
        ctx = ProcessContext(process=process, client=self.client, executor=self)
        try:
            if fn is None:
                raise ColoniesError(f"no handler for function {funcname!r}")
            self._sync_before(ctx)
            out = fn(ctx, *process.spec.args, **process.spec.kwargs)
            self._sync_after(ctx)
            if out is None:
                out = []
            elif not isinstance(out, list):
                out = [out]
            self.client.close(process.processid, out, self.prvkey)
            self.processed += 1
        except ConflictError:
            # Lost the lease (failsafe reset while we were computing) —
            # the paper's expected behaviour; drop the result silently.
            self.failed += 1
        except Exception as e:  # noqa: BLE001 — report any failure to the broker
            if getattr(e, "simulate_crash", False):
                # Chaos: vanish WITHOUT closing — the broker's maxexectime
                # failsafe must detect the lost lease and re-queue.
                raise
            self.failed += 1
            try:
                self.client.fail(
                    process.processid,
                    [f"{type(e).__name__}: {e}", traceback.format_exc(limit=5)],
                    self.prvkey,
                )
            except ColoniesError:
                pass

    # CFS hooks — overridden by executors that mount snapshots (runtime/).
    def _sync_before(self, ctx: ProcessContext) -> None:
        pass

    def _sync_after(self, ctx: ProcessContext) -> None:
        pass

    def run_forever(self, poll_timeout: float = 1.0) -> None:
        while not self._stop.is_set():
            try:
                self.step(poll_timeout)
            except ColoniesError:
                self._stop.wait(0.05)

    def start(self, poll_timeout: float = 1.0) -> None:
        self._thread = threading.Thread(
            target=self.run_forever, args=(poll_timeout,), daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
