"""HTTP transport (paper §3.3): signed JSON envelopes over POST /api.

The server *hangs* assign requests (long-poll) until a process matches or
the timer expires — each request runs in its own thread
(ThreadingHTTPServer), so hanging one connection never blocks others.
Executors always dial the server, never the reverse, so they can live
behind firewalls/NATs exactly as the paper argues.

Stdlib only: http.server + urllib.
"""

from __future__ import annotations

import http.client
import json
import threading
import urllib.error
import urllib.request
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from ..runtime import faults
from .retry import RetryPolicy, send_with_retry
from .server import ColoniesServer


class _Handler(BaseHTTPRequestHandler):
    server_version = "ColoniesHTTP/1.0"
    colonies: ColoniesServer = None  # type: ignore[assignment]

    def log_message(self, fmt: str, *args) -> None:  # silence default logging
        pass

    def do_POST(self) -> None:  # noqa: N802 (stdlib naming)
        if self.path.rstrip("/") != "/api":
            self.send_error(404)
            return
        try:
            length = int(self.headers.get("Content-Length", "0"))
            envelope = json.loads(self.rfile.read(length) or b"{}")
        except (ValueError, json.JSONDecodeError):
            self._reply(400, {"error": "malformed request", "status": 400})
            return
        # external=True: envelopes that crossed the network are always
        # signature-verified, even on servers built with
        # verify_signatures=False (that path is in-process-only).
        try:
            resp = self.colonies.handle(envelope, external=True)  # may hang (long-poll)
        except faults.FaultInjected:
            # Injected server crash window: die without replying, so the
            # client sees a reset connection — not a clean error body.
            self.close_connection = True
            try:
                self.connection.close()
            except OSError:
                pass
            return
        status = int(resp.get("status", 200)) if "error" in resp else 200
        self._reply(status, resp)

    def do_GET(self) -> None:  # noqa: N802
        if self.path.rstrip("/") == "/health":
            self._reply(200, {"status": "ok", "server": self.colonies.name})
        else:
            self.send_error(404)

    def _reply(self, status: int, body: dict) -> None:
        data = json.dumps(body).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)


class ColoniesHttpServer:
    """Serve one ColoniesServer replica over HTTP."""

    def __init__(self, colonies: ColoniesServer, host: str = "127.0.0.1", port: int = 0):
        handler = type("BoundHandler", (_Handler,), {"colonies": colonies})
        self.httpd = ThreadingHTTPServer((host, port), handler)
        self.host, self.port = self.httpd.server_address[:2]
        self._thread: threading.Thread | None = None

    def start(self) -> None:
        self._thread = threading.Thread(target=self.httpd.serve_forever, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self.httpd.shutdown()
        self.httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=2)


class HttpTransport:
    """Client side; compatible with client.Colonies.

    One pass rotates over all endpoints — 421 means "follower, try the
    next host" (leader failover), connection errors rotate the same way.
    ``retry=RetryPolicy(...)`` re-runs the pass with capped jittered
    backoff when every endpoint failed retryably (mid-election cluster,
    restarting server) — see retry.py; safe for mutating RPCs because
    the envelope's msgid makes the retry exactly-once server-side."""

    def __init__(
        self,
        host: str,
        port: int,
        fallbacks: list[tuple[str, int]] | None = None,
        retry: RetryPolicy | None = None,
    ):
        self.endpoints = [(host, port)] + list(fallbacks or [])
        self.retry = retry
        self._preferred = 0

    def send(self, envelope: dict, timeout: float = 90.0) -> dict:
        return send_with_retry(lambda: self._send_once(envelope, timeout), self.retry)

    def _send_once(self, envelope: dict, timeout: float) -> dict:
        data = json.dumps(envelope).encode()
        ptype = envelope.get("payloadtype", "")
        last: dict = {"error": "no endpoints", "status": 500}
        order = list(range(len(self.endpoints)))
        order = order[self._preferred :] + order[: self._preferred]
        for idx in order:
            host, port = self.endpoints[idx]
            req = urllib.request.Request(
                f"http://{host}:{port}/api",
                data=data,
                headers={"Content-Type": "application/json"},
                method="POST",
            )
            try:
                action = faults.hit("transport.send", payloadtype=ptype)
                with urllib.request.urlopen(req, timeout=timeout) as resp:
                    body = json.loads(resp.read())
                if action == "duplicate":  # at-least-once delivery: send twice
                    with urllib.request.urlopen(req, timeout=timeout) as resp:
                        body = json.loads(resp.read())
                faults.hit("transport.recv", payloadtype=ptype)
            except urllib.error.HTTPError as e:
                try:
                    body = json.loads(e.read())
                except (ValueError, json.JSONDecodeError):
                    body = {"error": str(e), "status": e.code}
            except (
                urllib.error.URLError,
                TimeoutError,
                ConnectionError,
                http.client.HTTPException,
            ) as e:
                # Includes server-side injected crash windows: do_POST
                # closes the socket without a reply, which surfaces here
                # as RemoteDisconnected/ConnectionError.
                last = {"error": f"transport: {e}", "status": 503}
                continue
            if body.get("status") == 421:  # follower — try next replica
                last = body
                continue
            self._preferred = idx
            return body
        return last
