"""Colonies client SDK (paper §4.1, Listings 3–5).

Transport-agnostic: ``InProcTransport`` calls a server object directly
(deterministic tests), ``HttpTransport`` speaks the JSON envelope protocol
over HTTP with long-polling ``assign`` (see http_transport.py). The SDK
surface mirrors the paper's Python SDK (``pycolonies``).
"""

from __future__ import annotations

import time
from typing import Any, Callable

from ..runtime import faults
from . import idempotency
from .errors import (
    AuthError,
    ColoniesError,
    ConflictError,
    NotFoundError,
    NotLeaderError,
    TimeoutError_,
    TransportError,
    ValidationError,
)
from .process import new_id
from .retry import RetryPolicy, send_with_retry
from .security import sign_envelope
from .spec import FunctionSpec, WorkflowSpec

_ERROR_TYPES: dict[int, type[ColoniesError]] = {
    400: ValidationError,
    403: AuthError,
    404: NotFoundError,
    408: TimeoutError_,
    409: ConflictError,
    421: NotLeaderError,
    503: TransportError,
}


class InProcTransport:
    """Direct dispatch to one or more server replicas (follower redirect aware).

    ``retry=RetryPolicy(...)`` re-runs the replica pass on transport-level
    failures (503/421) with capped jittered backoff — see retry.py."""

    def __init__(self, servers: list, retry: RetryPolicy | None = None) -> None:
        if not isinstance(servers, list):
            servers = [servers]
        self.servers = servers
        self.retry = retry
        self._preferred = 0

    def send(self, envelope: dict, timeout: float | None = None) -> dict:
        # timeout is accepted for interface parity with HttpTransport;
        # in-proc dispatch blocks in the server's own long-poll budget.
        return send_with_retry(lambda: self._send_once(envelope), self.retry)

    def _send_once(self, envelope: dict) -> dict:
        ptype = envelope.get("payloadtype", "")
        last: dict = {"error": "no servers", "status": 500}
        order = list(range(len(self.servers)))
        order = order[self._preferred :] + order[: self._preferred]
        for idx in order:
            try:
                action = faults.hit("transport.send", payloadtype=ptype)
                resp = self.servers[idx].handle(envelope)
                if action == "duplicate":  # at-least-once delivery: send twice
                    resp = self.servers[idx].handle(envelope)
                faults.hit("transport.recv", payloadtype=ptype)
            except ConnectionError as e:
                # Injected transport faults and server-side crash windows
                # (FaultInjected is a ConnectionError) look identical to a
                # dead connection: retryable, reply lost.
                last = {"error": f"transport: {e}", "status": 503}
                continue
            if resp.get("status") == 421:  # not leader — try the next replica
                last = resp
                continue
            self._preferred = idx
            return resp
        return last


class Colonies:
    """The SDK client: ``Colonies(transport)`` or ``Colonies.connect(host, port)``.

    ``insecure=True`` skips request signing and sends a bare identity claim —
    only honoured by servers running with ``verify_signatures=False``
    (benchmarking the broker without the crypto term).

    ``idempotency=False`` stops stamping mutating envelopes with a msgid
    (benchmarking the dedup term; retried mutations may then duplicate)."""

    def __init__(
        self, transport, insecure: bool = False, idempotency: bool = True
    ) -> None:
        self.transport = transport
        self.insecure = insecure
        self.idempotency = idempotency

    @staticmethod
    def connect(host: str, port: int, retry: RetryPolicy | None = None) -> "Colonies":
        from .http_transport import HttpTransport

        return Colonies(HttpTransport(host, port, retry=retry))

    # ------------------------------------------------------------------ rpc
    def _rpc(
        self,
        payloadtype: str,
        payload: dict,
        prvkey: str,
        timeout: float | None = None,
        msgid: str | None = None,
    ) -> Any:
        if (
            msgid is None
            and self.idempotency
            and idempotency.classify(payloadtype) == idempotency.KEYED
        ):
            # One key per logical operation: transport retries of this
            # call all carry the same msgid, so the server dedups them.
            msgid = new_id()
        if self.insecure:
            from .crypto import Crypto
            from .security import canonical

            env = {
                "payloadtype": payloadtype,
                "payload": canonical(payload),
                "identity": Crypto.id(prvkey),
            }
            if msgid:
                env["msgid"] = msgid
        else:
            env = sign_envelope(payloadtype, payload, prvkey, msgid=msgid)
        if timeout is None:
            resp = self.transport.send(env)
        else:
            resp = self.transport.send(env, timeout=timeout)
        if "error" in resp:
            err_cls = _ERROR_TYPES.get(int(resp.get("status", 500)), ColoniesError)
            raise err_cls(resp["error"])
        return resp["result"]

    # ------------------------------------------------------------- colonies
    def add_colony(self, colonyname: str, colonyid: str, server_prvkey: str) -> dict:
        return self._rpc(
            "addcolony",
            {"colony": {"colonyname": colonyname, "colonyid": colonyid}},
            server_prvkey,
        )

    # ------------------------------------------------------------- executors
    def add_executor(self, executor: dict, colony_prvkey: str) -> dict:
        return self._rpc("addexecutor", {"executor": executor}, colony_prvkey)

    def approve_executor(self, executorid: str, colony_prvkey: str) -> dict:
        return self._rpc("approveexecutor", {"executorid": executorid}, colony_prvkey)

    def reject_executor(self, executorid: str, colony_prvkey: str) -> dict:
        return self._rpc("rejectexecutor", {"executorid": executorid}, colony_prvkey)

    def remove_executor(self, executorid: str, colony_prvkey: str) -> dict:
        return self._rpc("removeexecutor", {"executorid": executorid}, colony_prvkey)

    def list_executors(self, colonyname: str, prvkey: str) -> list[dict]:
        return self._rpc("listexecutors", {"colonyname": colonyname}, prvkey)

    def add_user(self, colonyname: str, userid: str, username: str, colony_prvkey: str) -> dict:
        return self._rpc(
            "adduser",
            {"colonyname": colonyname, "userid": userid, "username": username},
            colony_prvkey,
        )

    def list_users(self, colonyname: str, prvkey: str) -> list[dict]:
        return self._rpc("listusers", {"colonyname": colonyname}, prvkey)

    def add_function(
        self, executorid: str, colonyname: str, funcname: str, executor_prvkey: str
    ) -> dict:
        return self._rpc(
            "addfunction",
            {"executorid": executorid, "colonyname": colonyname, "funcname": funcname},
            executor_prvkey,
        )

    # ------------------------------------------------------------- processes
    def submit(self, spec: FunctionSpec | dict, prvkey: str) -> dict:
        spec_d = spec.to_dict() if isinstance(spec, FunctionSpec) else spec
        return self._rpc("submitfunctionspec", {"spec": spec_d}, prvkey)

    def submit_workflow(self, wf: WorkflowSpec | dict, prvkey: str) -> dict:
        wf_d = wf.to_dict() if isinstance(wf, WorkflowSpec) else wf
        return self._rpc("submitworkflow", {"workflow": wf_d}, prvkey)

    def assign(self, colonyname: str, timeout: float, executor_prvkey: str) -> dict:
        """Long-poll for a process assignment (raises TimeoutError_ on expiry)."""
        return self._rpc(
            "assign", {"colonyname": colonyname, "timeout": timeout}, executor_prvkey
        )

    def close(
        self,
        processid: str,
        output: list[Any],
        executor_prvkey: str,
        msgid: str | None = None,
    ) -> dict:
        # msgid lets a caller (the executor's pending-close journal) reuse
        # one idempotency key across its own re-deliveries of this close.
        return self._rpc(
            "close",
            {"processid": processid, "successful": True, "out": list(output)},
            executor_prvkey,
            msgid=msgid,
        )

    def fail(
        self,
        processid: str,
        errors: list[str],
        executor_prvkey: str,
        msgid: str | None = None,
    ) -> dict:
        return self._rpc(
            "close",
            {"processid": processid, "successful": False, "errors": list(errors)},
            executor_prvkey,
            msgid=msgid,
        )

    def add_child(
        self,
        processid: str,
        spec: FunctionSpec | dict,
        executor_prvkey: str,
        waitforparent: bool = False,
    ) -> dict:
        spec_d = spec.to_dict() if isinstance(spec, FunctionSpec) else spec
        return self._rpc(
            "addchild",
            {"processid": processid, "spec": spec_d, "waitforparent": waitforparent},
            executor_prvkey,
        )

    def get_process(self, processid: str, prvkey: str) -> dict:
        return self._rpc("getprocess", {"processid": processid}, prvkey)

    def get_processes(
        self, colonyname: str, prvkey: str, state: str | None = None, count: int = 100
    ) -> list[dict]:
        return self._rpc(
            "getprocesses",
            {"colonyname": colonyname, "state": state, "count": count},
            prvkey,
        )

    def stats(self, colonyname: str, prvkey: str) -> dict:
        return self._rpc("colonystats", {"colonyname": colonyname}, prvkey)

    def wait(
        self, processid: str, prvkey: str, timeout: float = 30.0, poll: float = 0.05
    ) -> dict:
        """Poll until a process reaches a terminal state.

        The overall deadline holds even against a hung transport: each
        poll gets a per-request timeout derived from the remaining
        budget, and the timeout error surfaces the last non-timeout
        failure instead of a generic message."""
        deadline = time.monotonic() + timeout
        last_err: ColoniesError | None = None
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            try:
                p = self._rpc(
                    "getprocess",
                    {"processid": processid},
                    prvkey,
                    timeout=max(0.05, remaining),
                )
                if p["state"] in ("successful", "failed"):
                    return p
            except TimeoutError_:
                pass  # transient poll expiry; the outer deadline governs
            except ColoniesError as e:
                last_err = e
            time.sleep(max(0.0, min(poll, deadline - time.monotonic())))
        detail = f" (last error: {last_err})" if last_err is not None else ""
        raise TimeoutError_(
            f"process {processid} still not terminal after {timeout}s{detail}"
        )

    # ------------------------------------------------------------------ cron
    def add_cron(self, cron: dict, prvkey: str) -> dict:
        return self._rpc("addcron", {"cron": cron}, prvkey)

    def get_crons(self, colonyname: str, prvkey: str) -> list[dict]:
        return self._rpc("getcrons", {"colonyname": colonyname}, prvkey)

    def remove_cron(self, cronid: str, prvkey: str) -> dict:
        return self._rpc("removecron", {"cronid": cronid}, prvkey)

    # -------------------------------------------------------------- generator
    def add_generator(self, generator: dict, prvkey: str) -> dict:
        return self._rpc("addgenerator", {"generator": generator}, prvkey)

    def pack(self, generatorid: str, arg: Any, prvkey: str) -> dict:
        return self._rpc("pack", {"generatorid": generatorid, "arg": arg}, prvkey)

    def get_generators(self, colonyname: str, prvkey: str) -> list[dict]:
        return self._rpc("getgenerators", {"colonyname": colonyname}, prvkey)

    # -------------------------------------------------------------------- cfs
    def add_file(self, file: dict, prvkey: str) -> dict:
        return self._rpc("addfile", {"file": file}, prvkey)

    def get_file(self, colonyname: str, label: str, name: str, prvkey: str) -> dict:
        return self._rpc(
            "getfile",
            {"colonyname": colonyname, "label": label, "name": name},
            prvkey,
        )

    def get_files(self, colonyname: str, label: str, prvkey: str) -> list[dict]:
        return self._rpc("getfiles", {"colonyname": colonyname, "label": label}, prvkey)

    def remove_file(self, colonyname: str, fileid: str, prvkey: str) -> dict:
        return self._rpc(
            "removefile", {"colonyname": colonyname, "fileid": fileid}, prvkey
        )

    def create_snapshot(self, colonyname: str, label: str, name: str, prvkey: str) -> dict:
        return self._rpc(
            "createsnapshot",
            {"colonyname": colonyname, "label": label, "name": name},
            prvkey,
        )

    def get_snapshot(self, colonyname: str, snapshotid: str, prvkey: str) -> dict:
        return self._rpc(
            "getsnapshot", {"colonyname": colonyname, "snapshotid": snapshotid}, prvkey
        )

    def get_snapshots(self, colonyname: str, prvkey: str) -> list[dict]:
        return self._rpc("getsnapshots", {"colonyname": colonyname}, prvkey)

    def remove_snapshot(self, colonyname: str, snapshotid: str, prvkey: str) -> dict:
        return self._rpc(
            "removesnapshot",
            {"colonyname": colonyname, "snapshotid": snapshotid},
            prvkey,
        )
