"""ColonyOS core — the paper's meta-operating system, in Python.

Public surface:
  Crypto, Colonies (SDK), ColoniesServer, ExecutorBase, FunctionSpec,
  WorkflowSpec, databases, CFS, cron, generators, Raft cluster.
"""

from .blobstore import ShardedStorage
from .client import Colonies, InProcTransport
from .crypto import Crypto
from .database import Database, MemoryDatabase, SqliteDatabase
from .errors import TransportError
from .executor import ExecutorBase, ProcessContext
from .process import FAILED, RUNNING, SUCCESSFUL, WAITING, Process
from .retry import RetryPolicy
from .server import ColoniesServer
from .spec import Conditions, FunctionSpec, WorkflowSpec

__all__ = [
    "ShardedStorage",
    "Colonies",
    "InProcTransport",
    "RetryPolicy",
    "TransportError",
    "Crypto",
    "Database",
    "MemoryDatabase",
    "SqliteDatabase",
    "ExecutorBase",
    "ProcessContext",
    "Process",
    "WAITING",
    "RUNNING",
    "SUCCESSFUL",
    "FAILED",
    "ColoniesServer",
    "Conditions",
    "FunctionSpec",
    "WorkflowSpec",
]
