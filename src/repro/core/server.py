"""The Colonies server — stateless broker at the heart of ColonyOS (paper §3).

Every request alters or reads database state; no session data lives in
memory between requests (§3.4.3), so any replica can serve any request —
except ``assign``, the single synchronized operation (§3.4.1), which in
HA deployments is serialized through the Raft leader (see cluster.py).

Responsibilities implemented here:
  * process submission / assignment / close (Tables 1–2, Fig. 2)
  * the Eq. (1) priority queue via the database backends
  * the ``maxexectime``/``maxwaittime`` stateless failsafe scanner (§3.4)
  * workflow DAGs with ``wait_for_parents`` + dynamic children (§3.4.2)
  * zero-trust authorization of every envelope (§3.4.6)

Cron, generators and CFS are separate modules wired in by this server.
"""

from __future__ import annotations

import threading
from typing import Any, Callable

from .database import Database, MemoryDatabase
from .errors import (
    AuthError,
    ColoniesError,
    ConflictError,
    NotFoundError,
    NotLeaderError,
    TimeoutError_,
    ValidationError,
)
from .process import (
    FAILED,
    RUNNING,
    SUCCESSFUL,
    WAITING,
    Colony,
    Executor,
    Process,
    now_ns,
)
from .security import open_envelope
from .spec import FunctionSpec, WorkflowSpec

USERS_TABLE = "users"


class ColoniesServer:
    """A single Colonies server replica.

    ``serverid`` is the identity of the server owner (SHA3 of their public
    key); only that identity may create colonies. In HA mode, ``is_leader``
    and ``propose_assign`` are overridden by the cluster layer.
    """

    def __init__(
        self,
        serverid: str,
        db: Database | None = None,
        verify_signatures: bool = True,
        name: str = "colonies-0",
    ) -> None:
        self.name = name
        self.serverid = serverid
        self.db = db if db is not None else MemoryDatabase()
        self.verify_signatures = verify_signatures
        # The one synchronized critical section (paper §3.4.1).
        self._assign_lock = threading.Lock()
        self._queue_cv = threading.Condition()
        self._handlers: dict[str, Callable[[str, dict], Any]] = {
            "addcolony": self._h_add_colony,
            "addexecutor": self._h_add_executor,
            "approveexecutor": self._h_approve_executor,
            "rejectexecutor": self._h_reject_executor,
            "removeexecutor": self._h_remove_executor,
            "listexecutors": self._h_list_executors,
            "adduser": self._h_add_user,
            "addfunction": self._h_add_function,
            "listfunctions": self._h_list_functions,
            "submitfunctionspec": self._h_submit,
            "submitworkflow": self._h_submit_workflow,
            "assign": self._h_assign,
            "close": self._h_close,
            "addchild": self._h_add_child,
            "getprocess": self._h_get_process,
            "getprocesses": self._h_get_processes,
            "colonystats": self._h_stats,
        }
        # Extension points (cron/generator/fs register their handlers here).
        self.extensions: list[Any] = []
        # HA hooks — standalone servers are always leader.
        self._is_leader: Callable[[], bool] = lambda: True
        self._propose_assign: Callable[[dict], None] | None = None
        self._stop = threading.Event()
        self._failsafe_thread: threading.Thread | None = None

    # ------------------------------------------------------------------ RPC
    def handle(self, envelope: dict) -> dict:
        """Entry point for all transports. Returns {"error":...} or {"result":...}."""
        try:
            identity, ptype, payload = open_envelope(
                envelope, verify=self.verify_signatures
            )
            handler = self._handlers.get(ptype)
            if handler is None:
                for ext in self.extensions:
                    handler = ext.handlers().get(ptype)
                    if handler is not None:
                        break
            if handler is None:
                raise ValidationError(f"unknown payloadtype {ptype!r}")
            result = handler(identity, payload)
            return {"result": result}
        except NotLeaderError as e:
            return {"error": str(e), "status": e.status, "leader": e.leader}
        except ColoniesError as e:
            return {"error": str(e), "status": e.status}

    # ------------------------------------------------------------ auth utils
    def _require_server_owner(self, identity: str) -> None:
        if identity != self.serverid:
            raise AuthError("requires server owner")

    def _require_colony_owner(self, identity: str, colonyname: str) -> Colony:
        colony = self.db.get_colony(colonyname)
        if identity != colony.colonyid:
            raise AuthError("requires colony owner")
        return colony

    def _require_member(self, identity: str, colonyname: str) -> Executor | None:
        """Approved executor OR registered user OR colony owner."""
        colony = self.db.get_colony(colonyname)
        if identity == colony.colonyid:
            return None
        try:
            ex = self.db.get_executor(identity)
            if ex.colonyname == colonyname and ex.state == "approved":
                self.db.touch_executor(identity, now_ns())
                return ex
        except NotFoundError:
            pass
        user = self.db.kv_get(USERS_TABLE, identity)
        if user is not None and user.get("colonyname") == colonyname:
            return None
        raise AuthError("identity is not a member of the colony")

    def _require_executor(self, identity: str, colonyname: str) -> Executor:
        try:
            ex = self.db.get_executor(identity)
        except NotFoundError as e:
            raise AuthError("unknown executor identity") from e
        if ex.colonyname != colonyname:
            raise AuthError("executor belongs to another colony")
        if ex.state != "approved":
            raise AuthError(f"executor not approved (state={ex.state})")
        self.db.touch_executor(identity, now_ns())
        return ex

    # -------------------------------------------------------------- handlers
    def _h_add_colony(self, identity: str, payload: dict) -> dict:
        self._require_server_owner(identity)
        colony = Colony.from_dict(payload.get("colony", payload))
        if not colony.colonyname or not colony.colonyid:
            raise ValidationError("colony needs colonyname and colonyid")
        self.db.add_colony(colony)
        return colony.to_dict()

    def _h_add_executor(self, identity: str, payload: dict) -> dict:
        ex = Executor.from_dict(payload.get("executor", payload))
        self._require_colony_owner(identity, ex.colonyname)
        if not ex.executorid or not ex.executortype:
            raise ValidationError("executor needs executorid and executortype")
        ex.state = "pending"
        ex.commissiontime_ns = now_ns()
        self.db.add_executor(ex)
        return ex.to_dict()

    def _h_approve_executor(self, identity: str, payload: dict) -> dict:
        ex = self.db.get_executor(payload["executorid"])
        self._require_colony_owner(identity, ex.colonyname)
        self.db.set_executor_state(ex.executorid, "approved")
        return {"executorid": ex.executorid, "state": "approved"}

    def _h_reject_executor(self, identity: str, payload: dict) -> dict:
        ex = self.db.get_executor(payload["executorid"])
        self._require_colony_owner(identity, ex.colonyname)
        self.db.set_executor_state(ex.executorid, "rejected")
        return {"executorid": ex.executorid, "state": "rejected"}

    def _h_remove_executor(self, identity: str, payload: dict) -> dict:
        ex = self.db.get_executor(payload["executorid"])
        self._require_colony_owner(identity, ex.colonyname)
        self.db.remove_executor(ex.executorid)
        return {"executorid": ex.executorid, "removed": True}

    def _h_list_executors(self, identity: str, payload: dict) -> list[dict]:
        colony = payload["colonyname"]
        self._require_member(identity, colony)
        return [e.to_dict() for e in self.db.list_executors(colony)]

    def _h_add_user(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        self._require_colony_owner(identity, colony)
        user = {
            "userid": payload["userid"],
            "username": payload.get("username", ""),
            "colonyname": colony,
        }
        self.db.kv_put(USERS_TABLE, payload["userid"], user)
        return user

    def _h_add_function(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        ex = self._require_executor(identity, colony)
        self.db.add_function(ex.executorid, colony, payload["funcname"])
        return {"executorid": ex.executorid, "funcname": payload["funcname"]}

    def _h_list_functions(self, identity: str, payload: dict) -> list[dict]:
        colony = payload["colonyname"]
        self._require_member(identity, colony)
        return self.db.list_functions(colony, payload.get("executorid"))

    # -- submit -------------------------------------------------------------
    def _h_submit(self, identity: str, payload: dict) -> dict:
        spec = FunctionSpec.from_dict(payload.get("spec", payload))
        if not spec.conditions.colonyname:
            raise ValidationError("spec.conditions.colonyname required")
        if not spec.conditions.executortype:
            raise ValidationError("spec.conditions.executortype required")
        self._require_member(identity, spec.conditions.colonyname)
        p = Process.create(spec)
        self.db.add_process(p)
        self._notify_queue()
        return p.to_dict()

    def _h_submit_workflow(self, identity: str, payload: dict) -> dict:
        wf = WorkflowSpec.from_dict(payload.get("workflow", payload))
        colony = wf.colonyname or (
            wf.specs[0].conditions.colonyname if wf.specs else ""
        )
        if not colony:
            raise ValidationError("workflow needs a colonyname")
        self._require_member(identity, colony)
        if not wf.specs:
            raise ValidationError("empty workflow")
        for s in wf.specs:
            s.conditions.colonyname = s.conditions.colonyname or colony
        wf.validate()
        procs = self.submit_workflow_processes(wf)
        self._notify_queue()
        return {
            "workflowid": procs[0].workflowid,
            "processes": [p.to_dict() for p in procs],
        }

    def submit_workflow_processes(self, wf: WorkflowSpec) -> list[Process]:
        """DAG expansion (paper §3.4.2): one process per node, linked by ids."""
        from .workflow import expand_workflow

        procs = expand_workflow(wf)
        for p in procs:
            self.db.add_process(p)
        return procs

    # -- assign ---------------------------------------------------------------
    def _h_assign(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        timeout = float(payload.get("timeout", 10.0))
        ex = self._require_executor(identity, colony)
        p = self.assign(colony, ex, timeout)
        if p is None:
            raise TimeoutError_("no process assigned within timeout")
        return p.to_dict()

    def assign(self, colony: str, ex: Executor, timeout: float) -> Process | None:
        """Long-poll assignment (paper §3.3: the server *hangs* the request)."""
        deadline = now_ns() + int(timeout * 1e9)
        while not self._stop.is_set():
            if not self._is_leader():
                raise NotLeaderError("assign must be served by the leader")
            p = self._try_assign_once(colony, ex)
            if p is not None:
                return p
            remaining = (deadline - now_ns()) / 1e9
            if remaining <= 0:
                return None
            with self._queue_cv:
                self._queue_cv.wait(timeout=min(remaining, 0.5))
        return None

    def _try_assign_once(self, colony: str, ex: Executor) -> Process | None:
        with self._assign_lock:
            cands = self.db.candidates(colony, ex.executortype, ex.executorname)
            for p in cands:
                op = {
                    "op": "assign",
                    "processid": p.processid,
                    "executorid": ex.executorid,
                    "ts": now_ns(),
                }
                if self._propose_assign is not None:
                    # HA path: serialize through the Raft log before applying.
                    self._propose_assign(op)
                else:
                    self.apply_assign(op)
                return self.db.get_process(p.processid)
        return None

    def apply_assign(self, op: dict) -> None:
        """State-machine apply for an assign op (also invoked by Raft commit)."""
        p = self.db.get_process(op["processid"])
        if p.state != WAITING:
            raise ConflictError("process no longer waiting")
        ts = op["ts"]
        p.state = RUNNING
        p.isassigned = True
        p.assignedexecutorid = op["executorid"]
        p.starttime_ns = ts
        if p.spec.maxexectime and p.spec.maxexectime > 0:
            p.deadline_ns = ts + p.spec.maxexectime * 10**9
        else:
            p.deadline_ns = 0
        # Dataflow (Table 4): inputs = concatenated parent outputs.
        if p.parents:
            inputs: list[Any] = []
            for parent_id in p.parents:
                parent = self.db.get_process(parent_id)
                inputs.extend(parent.output)
            p.inputs = inputs
        self.db.update_process(p)

    # -- close ---------------------------------------------------------------
    def _h_close(self, identity: str, payload: dict) -> dict:
        pid = payload["processid"]
        p = self.db.get_process(pid)
        ex = self._require_executor(identity, p.colonyname)
        if p.assignedexecutorid != ex.executorid or p.state != RUNNING:
            # e.g. the failsafe already reset this process (paper §4.1:
            # "The previous executor then receives an error").
            raise ConflictError("process is not assigned to this executor")
        succeeded = bool(payload.get("successful", True))
        output = payload.get("out", [])
        errors = payload.get("errors", [])
        self.close_process(p, succeeded, output, errors)
        return self.db.get_process(pid).to_dict()

    def close_process(
        self, p: Process, succeeded: bool, output: list[Any], errors: list[str]
    ) -> None:
        """Close + stateless DAG propagation (paper §3.4.2).

        No synchronization needed: exactly one executor owns the process.
        """
        p.state = SUCCESSFUL if succeeded else FAILED
        p.endtime_ns = now_ns()
        p.output = list(output)
        p.errors = list(errors)
        p.deadline_ns = 0
        self.db.update_process(p)
        if succeeded:
            for child_id in p.children:
                self._maybe_release_child(child_id)
        else:
            # Fail descendants so workflows terminate instead of hanging.
            self._fail_descendants(p, f"parent process {p.processid} failed")
        self._notify_queue()

    def _maybe_release_child(self, child_id: str) -> None:
        child = self.db.get_process(child_id)
        if not child.wait_for_parents:
            return
        for parent_id in child.parents:
            if self.db.get_process(parent_id).state != SUCCESSFUL:
                return
        child.wait_for_parents = False
        self.db.update_process(child)
        if hasattr(self.db, "requeue"):
            self.db.requeue(child)

    def _fail_descendants(self, p: Process, reason: str) -> None:
        for child_id in p.children:
            child = self.db.get_process(child_id)
            if child.state in (WAITING, RUNNING):
                child.state = FAILED
                child.endtime_ns = now_ns()
                child.errors = [reason]
                self.db.update_process(child)
                self._fail_descendants(child, reason)

    # -- dynamic children (MapReduce on the fly, paper §3.4.2) ----------------
    def _h_add_child(self, identity: str, payload: dict) -> dict:
        parent_id = payload["processid"]
        parent = self.db.get_process(parent_id)
        ex = self._require_executor(identity, parent.colonyname)
        if parent.assignedexecutorid != ex.executorid or parent.state != RUNNING:
            raise AuthError("only the assigned executor may extend the DAG")
        spec = FunctionSpec.from_dict(payload["spec"])
        spec.conditions.colonyname = parent.colonyname
        child = Process.create(spec)
        child.workflowid = parent.workflowid
        insert_after_parent = bool(payload.get("waitforparent", False))
        if insert_after_parent:
            child.parents = [parent_id]
            child.wait_for_parents = True
        self.db.add_process(child)
        parent.children = parent.children + [child.processid]
        self.db.update_process(parent)
        self._notify_queue()
        return child.to_dict()

    # -- introspection ---------------------------------------------------------
    def _h_get_process(self, identity: str, payload: dict) -> dict:
        p = self.db.get_process(payload["processid"])
        self._require_member(identity, p.colonyname)
        return p.to_dict()

    def _h_get_processes(self, identity: str, payload: dict) -> list[dict]:
        colony = payload["colonyname"]
        self._require_member(identity, colony)
        return [
            p.to_dict()
            for p in self.db.list_processes(
                colony, payload.get("state"), int(payload.get("count", 100))
            )
        ]

    def _h_stats(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        self._require_member(identity, colony)
        stats = {s: 0 for s in (WAITING, RUNNING, SUCCESSFUL, FAILED)}
        for p in self.db.list_processes(colony, count=10**9):
            stats[p.state] += 1
        stats["executors"] = len(self.db.list_executors(colony))
        return stats

    # -- failsafe (paper §3.4) --------------------------------------------------
    def failsafe_scan(self) -> dict:
        """One stateless scan pass; returns counters (also used by tests)."""
        ts = now_ns()
        reset = failed = expired = 0
        for p in self.db.running_past_deadline(ts):
            if p.retries + 1 > max(p.spec.maxretries, 0):
                p.state = FAILED
                p.endtime_ns = ts
                p.errors = p.errors + ["maxretries exceeded after maxexectime reset"]
                self.db.update_process(p)
                self._fail_descendants(p, f"parent process {p.processid} failed")
                failed += 1
            else:
                # Reset back to the queue — another executor will pick it up.
                p.state = WAITING
                p.isassigned = False
                p.assignedexecutorid = ""
                p.starttime_ns = 0
                p.deadline_ns = 0
                p.retries += 1
                self.db.update_process(p)
                if hasattr(self.db, "requeue"):
                    self.db.requeue(p)
                reset += 1
        for p in self.db.waiting_past_deadline(ts):
            p.state = FAILED
            p.endtime_ns = ts
            p.errors = p.errors + ["maxwaittime exceeded"]
            self.db.update_process(p)
            self._fail_descendants(p, f"parent process {p.processid} failed")
            expired += 1
        if reset:
            self._notify_queue()
        return {"reset": reset, "failed": failed, "waitexpired": expired}

    def start_background(self, failsafe_interval: float = 0.25) -> None:
        """Start the periodic failsafe scanner (leader-gated in HA mode)."""

        def loop() -> None:
            while not self._stop.wait(failsafe_interval):
                if self._is_leader():
                    self.failsafe_scan()
                for ext in self.extensions:
                    tick = getattr(ext, "tick", None)
                    if tick is not None and self._is_leader():
                        tick()

        self._failsafe_thread = threading.Thread(target=loop, daemon=True)
        self._failsafe_thread.start()

    def stop(self) -> None:
        self._stop.set()
        self._notify_queue()
        if self._failsafe_thread is not None:
            self._failsafe_thread.join(timeout=2)

    def _notify_queue(self) -> None:
        with self._queue_cv:
            self._queue_cv.notify_all()

    # -- HA wiring ----------------------------------------------------------------
    def set_leader_check(self, fn: Callable[[], bool]) -> None:
        self._is_leader = fn

    def set_assign_proposer(self, fn: Callable[[dict], None]) -> None:
        self._propose_assign = fn
