"""The Colonies server — stateless broker at the heart of ColonyOS (paper §3).

Every request alters or reads database state; no session data lives in
memory between requests (§3.4.3), so any replica can serve any request —
except ``assign``, the single synchronized operation (§3.4.1), which in
HA deployments is serialized through the Raft leader (see cluster.py).

Responsibilities implemented here:
  * process submission / assignment / close (Tables 1–2, Fig. 2)
  * the Eq. (1) priority queue via the database backends
  * the ``maxexectime``/``maxwaittime`` stateless failsafe scanner (§3.4)
  * workflow DAGs with ``wait_for_parents`` + dynamic children (§3.4.2)
  * zero-trust authorization of every envelope (§3.4.6)

Concurrency model (this file plus database.py):

* Assignment, close, and failsafe mutations for one colony serialize on
  that colony's ``db.colony_lock`` — colonies never contend with each
  other, and a stale executor's close can no longer interleave with a
  failsafe reset (the close re-validates state + ownership under the
  lock before mutating).
* Long-polling executors park on a per-(colony, executortype) condition
  variable and are woken only when *their* queue gains work (submit,
  child release, failsafe requeue), instead of polling a global CV.
  A monotonically bumped version per queue closes the classic
  check-then-wait race without holding any lock across the DB probe.

Cron, generators and CFS are separate modules wired in by this server.
"""

from __future__ import annotations

import logging
import threading
from typing import Any, Callable

from ..analysis import authtrack
from ..analysis.authtrack import requires_auth
from ..analysis.contracts import no_locks_held
from ..analysis.locktrack import make_lock
from ..runtime import faults
from . import blobstore, idempotency
from .database import Database, MemoryDatabase
from .errors import (
    AuthError,
    ColoniesError,
    ConflictError,
    NotFoundError,
    NotLeaderError,
    TimeoutError_,
    ValidationError,
)
from .process import (
    FAILED,
    RUNNING,
    STATES,
    SUCCESSFUL,
    WAITING,
    Colony,
    Executor,
    Process,
    new_id,
    now_ns,
)
from .security import open_envelope
from .spec import FunctionSpec, WorkflowSpec

# The seed's kv bucket for colony users; survives only as the sqlite
# migration source (users are a first-class indexed table now).
USERS_TABLE = "users"


class _QueueSignal:
    """Wakeup channel for one (colony, executortype) ready queue."""

    __slots__ = ("cv", "version")

    def __init__(self, key: tuple[str, str] = ("", "")) -> None:
        self.cv = threading.Condition(make_lock(f"queuecv:{key[0]}:{key[1]}"))
        self.version = 0


class ColoniesServer:
    """A single Colonies server replica.

    ``serverid`` is the identity of the server owner (SHA3 of their public
    key); only that identity may create colonies. In HA mode, ``is_leader``
    and ``propose_assign`` are overridden by the cluster layer.
    """

    # HA replicas re-check leadership at this cadence while parked in
    # ``assign``; standalone servers sleep the full long-poll timeout and
    # rely purely on queue notifications.
    HA_LEADER_RECHECK_S = 0.5

    def __init__(
        self,
        serverid: str,
        db: Database | None = None,
        verify_signatures: bool = True,
        name: str = "colonies-0",
    ) -> None:
        self.name = name
        self.serverid = serverid
        self.db = db if db is not None else MemoryDatabase()
        self.verify_signatures = verify_signatures
        # Per-(colony, executortype) wakeup channels for long-poll assign.
        self._signals: dict[tuple[str, str], _QueueSignal] = {}
        self._signals_guard = make_lock("signals")
        # Leader-local per-colony assign serialization for the HA path (the
        # shared db.colony_lock cannot be held across a Raft proposal: the
        # commit is applied on another thread that needs that same lock).
        self._local_assign_locks: dict[str, threading.RLock] = {}
        self._handlers: dict[str, Callable[[str, dict], Any]] = {
            "addcolony": self._h_add_colony,
            "addexecutor": self._h_add_executor,
            "approveexecutor": self._h_approve_executor,
            "rejectexecutor": self._h_reject_executor,
            "removeexecutor": self._h_remove_executor,
            "listexecutors": self._h_list_executors,
            "adduser": self._h_add_user,
            "listusers": self._h_list_users,
            "addfunction": self._h_add_function,
            "listfunctions": self._h_list_functions,
            "submitfunctionspec": self._h_submit,
            "submitworkflow": self._h_submit_workflow,
            "assign": self._h_assign,
            "close": self._h_close,
            "addchild": self._h_add_child,
            "getprocess": self._h_get_process,
            "getprocesses": self._h_get_processes,
            "colonystats": self._h_stats,
        }
        # Extension points (cron/generator/fs register their handlers here).
        self.extensions: list[Any] = []
        # HA hooks — standalone servers are always leader. ``_propose_op``
        # serializes replicated ops (assign, close) through the Raft log;
        # every proposed entry carries a leader-stamped ``ts`` and a
        # stable ``opid`` so the apply is deterministic and replay-safe
        # (see REPLICATION.md, repro.analysis.replint).
        self._ha = False
        self._is_leader: Callable[[], bool] = lambda: True
        self._propose_op: Callable[[dict], None] | None = None
        self._stop = threading.Event()
        self._failsafe_thread: threading.Thread | None = None
        # Exceptions swallowed (but counted) by the failsafe loop; the
        # first one is logged with traceback. Surfaced via colonystats.
        self.failsafe_errors = 0

    # ------------------------------------------------------------------ RPC
    def handle(self, envelope: dict, external: bool = False) -> dict:
        """Entry point for all transports. Returns {"error":...} or {"result":...}.

        ``external=True`` (set by network transports) forces signature
        verification regardless of ``verify_signatures``: the unverified
        path exists only for in-process benchmark/test harnesses, never
        for envelopes that crossed a trust boundary (paper §3.4.6).
        """
        try:
            verify = self.verify_signatures or external
            identity, ptype, payload = open_envelope(
                envelope, verify=verify, allow_unverified=not verify
            )
            # Injected server death before dispatch: the request has no
            # effect and the transport sees a dead connection. The raise
            # (FaultInjected is a ConnectionError, not a ColoniesError)
            # deliberately escapes the handlers below.
            faults.hit("server.pre_dispatch", payloadtype=ptype)
            handler = self._handlers.get(ptype)
            if handler is None:
                for ext in self.extensions:
                    handler = ext.handlers().get(ptype)
                    if handler is not None:
                        break
            if handler is None:
                raise ValidationError(f"unknown payloadtype {ptype!r}")
            # Exactly-once mutating RPCs (ROBUSTNESS.md): a keyed envelope
            # whose (identity, msgid) already has a recorded reply is a
            # client retry of a committed operation — replay the reply
            # without re-running the handler. The msgid is covered by the
            # envelope signature, so only the original signer can replay.
            msgid = str(envelope.get("msgid") or "")
            dedup_key = ""
            if msgid and idempotency.classify(ptype) == idempotency.KEYED:
                dedup_key = f"{identity}:{msgid}"
                cached = self.db.dedup_get(dedup_key)
                if cached is not None:
                    return {"result": cached, "replayed": True}
            # Under REPRO_AUTH_CHECK=1 the scope arms the database guards:
            # colony-scoped access inside this dispatch requires a recorded
            # auth fact (see repro/analysis/authtrack.py).
            token = idempotency.set_current(msgid)
            try:
                with authtrack.request_scope():
                    result = handler(identity, payload)
            finally:
                idempotency.reset_current(token)
            # Record successes only: an error reply implies nothing
            # committed (handlers raise before mutating), so the retry
            # must re-execute, not replay the failure.
            if dedup_key:
                self.db.dedup_put(
                    dedup_key,
                    idempotency.reply_colony(ptype, payload, result),
                    now_ns(),
                    result,
                )
            # The crash-after-commit-before-reply window: effect and dedup
            # record are durable, the reply is lost.
            faults.hit("server.post_commit", payloadtype=ptype)
            return {"result": result}
        except NotLeaderError as e:
            return {"error": str(e), "status": e.status, "leader": e.leader}
        except ColoniesError as e:
            return {"error": str(e), "status": e.status}

    # ------------------------------------------------------------ auth utils
    # Each check records its verified (identity, colony, role) as an auth
    # fact for the current request (a no-op unless REPRO_AUTH_CHECK=1);
    # colony-scoped database access without a matching fact then raises.
    def _require_server_owner(self, identity: str) -> None:
        if identity != self.serverid:
            raise AuthError("requires server owner")
        authtrack.record(identity, authtrack.ANY_COLONY, "server")

    def _require_colony_owner(self, identity: str, colonyname: str) -> Colony:
        colony = self.db.get_colony(colonyname)
        if identity != colony.colonyid:
            raise AuthError("requires colony owner")
        authtrack.record(identity, colonyname, "owner")
        return colony

    def _require_member(self, identity: str, colonyname: str) -> Executor | None:
        """Approved executor OR registered user OR colony owner."""
        colony = self.db.get_colony(colonyname)
        if identity == colony.colonyid:
            authtrack.record(identity, colonyname, "owner")
            return None
        try:
            ex = self.db.get_executor(identity)
            if ex.colonyname == colonyname and ex.state == "approved":
                authtrack.record(identity, colonyname, "executor")
                self.db.touch_executor(identity, now_ns())
                return ex
        except NotFoundError:
            pass
        user = self.db.user_get(identity)
        if user is not None and user.get("colonyname") == colonyname:
            authtrack.record(identity, colonyname, "member")
            return None
        raise AuthError("identity is not a member of the colony")

    def _require_executor(self, identity: str, colonyname: str) -> Executor:
        try:
            ex = self.db.get_executor(identity)
        except NotFoundError as e:
            raise AuthError("unknown executor identity") from e
        if ex.colonyname != colonyname:
            raise AuthError("executor belongs to another colony")
        if ex.state != "approved":
            raise AuthError(f"executor not approved (state={ex.state})")
        authtrack.record(identity, colonyname, "executor")
        self.db.touch_executor(identity, now_ns())
        return ex

    # -------------------------------------------------------------- handlers
    def _h_add_colony(self, identity: str, payload: dict) -> dict:
        self._require_server_owner(identity)
        colony = Colony.from_dict(payload.get("colony", payload))
        if not colony.colonyname or not colony.colonyid:
            raise ValidationError("colony needs colonyname and colonyid")
        self.db.add_colony(colony)
        return colony.to_dict()

    def _h_add_executor(self, identity: str, payload: dict) -> dict:
        ex = Executor.from_dict(payload.get("executor", payload))
        self._require_colony_owner(identity, ex.colonyname)
        if not ex.executorid or not ex.executortype:
            raise ValidationError("executor needs executorid and executortype")
        ex.state = "pending"
        ex.commissiontime_ns = now_ns()
        self.db.add_executor(ex)
        return ex.to_dict()

    def _h_approve_executor(self, identity: str, payload: dict) -> dict:
        ex = self.db.get_executor(payload["executorid"])
        self._require_colony_owner(identity, ex.colonyname)
        self.db.set_executor_state(ex.executorid, "approved")
        return {"executorid": ex.executorid, "state": "approved"}

    def _h_reject_executor(self, identity: str, payload: dict) -> dict:
        ex = self.db.get_executor(payload["executorid"])
        self._require_colony_owner(identity, ex.colonyname)
        self.db.set_executor_state(ex.executorid, "rejected")
        return {"executorid": ex.executorid, "state": "rejected"}

    def _h_remove_executor(self, identity: str, payload: dict) -> dict:
        ex = self.db.get_executor(payload["executorid"])
        self._require_colony_owner(identity, ex.colonyname)
        self.db.remove_executor(ex.executorid)
        return {"executorid": ex.executorid, "removed": True}

    def _h_list_executors(self, identity: str, payload: dict) -> list[dict]:
        colony = payload["colonyname"]
        self._require_member(identity, colony)
        return [e.to_dict() for e in self.db.list_executors(colony)]

    def _h_add_user(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        self._require_colony_owner(identity, colony)
        user = {
            "userid": payload["userid"],
            "username": payload.get("username", ""),
            "colonyname": colony,
        }
        self.db.user_put(user)
        return user

    def _h_list_users(self, identity: str, payload: dict) -> list[dict]:
        colony = payload["colonyname"]
        self._require_member(identity, colony)
        return self.db.user_list(colony)

    def _h_add_function(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        ex = self._require_executor(identity, colony)
        self.db.add_function(ex.executorid, colony, payload["funcname"])
        return {"executorid": ex.executorid, "funcname": payload["funcname"]}

    def _h_list_functions(self, identity: str, payload: dict) -> list[dict]:
        colony = payload["colonyname"]
        self._require_member(identity, colony)
        return self.db.list_functions(colony, payload.get("executorid"))

    # -- submit -------------------------------------------------------------
    def _h_submit(self, identity: str, payload: dict) -> dict:
        spec = FunctionSpec.from_dict(payload.get("spec", payload))
        if not spec.conditions.colonyname:
            raise ValidationError("spec.conditions.colonyname required")
        if not spec.conditions.executortype:
            raise ValidationError("spec.conditions.executortype required")
        self._require_member(identity, spec.conditions.colonyname)
        p = Process.create(spec)
        self.db.add_process(p)
        self._notify_queue([self._queue_key(p)])
        return p.to_dict()

    def _h_submit_workflow(self, identity: str, payload: dict) -> dict:
        wf = WorkflowSpec.from_dict(payload.get("workflow", payload))
        colony = wf.colonyname or (
            wf.specs[0].conditions.colonyname if wf.specs else ""
        )
        if not colony:
            raise ValidationError("workflow needs a colonyname")
        self._require_member(identity, colony)
        if not wf.specs:
            raise ValidationError("empty workflow")
        for s in wf.specs:
            s.conditions.colonyname = s.conditions.colonyname or colony
        wf.validate()
        procs = self.submit_workflow_processes(wf)
        self._notify_queue(
            [self._queue_key(p) for p in procs if not p.wait_for_parents]
        )
        return {
            "workflowid": procs[0].workflowid,
            "processes": [p.to_dict() for p in procs],
        }

    @requires_auth("member")
    def submit_workflow_processes(self, wf: WorkflowSpec) -> list[Process]:
        """DAG expansion (paper §3.4.2): one process per node, linked by ids."""
        from .workflow import expand_workflow

        procs = expand_workflow(wf)
        for p in procs:
            self.db.add_process(p)
        return procs

    # -- assign ---------------------------------------------------------------
    def _h_assign(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        timeout = float(payload.get("timeout", 10.0))
        ex = self._require_executor(identity, colony)
        p = self.assign(colony, ex, timeout)
        if p is None:
            raise TimeoutError_("no process assigned within timeout")
        return p.to_dict()

    @no_locks_held()
    def assign(self, colony: str, ex: Executor, timeout: float) -> Process | None:
        """Long-poll assignment (paper §3.3: the server *hangs* the request).

        Event-driven: the request parks on the (colony, executortype)
        signal and is woken exactly when that queue gains work.
        """
        deadline = now_ns() + int(timeout * 1e9)
        sig = self._signal((colony, ex.executortype))
        while not self._stop.is_set():
            if not self._is_leader():
                raise NotLeaderError("assign must be served by the leader")
            with sig.cv:
                version = sig.version
            p = self._try_assign_once(colony, ex)
            if p is not None:
                return p
            remaining = (deadline - now_ns()) / 1e9
            if remaining <= 0:
                return None
            # HA replicas wake periodically to notice lost leadership;
            # standalone servers sleep until notified (or timeout).
            tick = self.HA_LEADER_RECHECK_S if self._ha else remaining
            with sig.cv:
                if sig.version == version:  # nothing arrived since we probed
                    sig.cv.wait(timeout=min(remaining, tick))
        return None

    def _local_assign_lock(self, colony: str) -> threading.RLock:
        with self._signals_guard:
            lk = self._local_assign_locks.get(colony)
            if lk is None:
                lk = self._local_assign_locks[colony] = make_lock(
                    f"assignlocal:{colony}"
                )
            return lk

    def _try_assign_once(self, colony: str, ex: Executor) -> Process | None:
        if self._propose_op is not None:
            # HA: leader-local serialization; Raft log order plus the
            # WAITING CAS in apply_assign make assignment exactly-once.
            lock = self._local_assign_lock(colony)
        else:
            lock = self.db.colony_lock(colony)
        with lock:
            cands = self.db.candidates(colony, ex.executortype, ex.executorname)
            for p in cands:
                # Leader-stamped entry: the wall clock and the op identity
                # are fixed HERE, before the Raft log, so the apply cone
                # stays deterministic (replint REP001/REP004).
                op = {
                    "op": "assign",
                    "opid": new_id(),
                    "processid": p.processid,
                    "executorid": ex.executorid,
                    "ts": now_ns(),
                    # The request's idempotency key rides the Raft entry so
                    # followers see which client RPC produced this op
                    # (ROBUSTNESS.md; "" for unkeyed/internal callers).
                    "msgid": idempotency.current(),
                }
                if self._propose_op is not None:
                    # HA path: serialize through the Raft log before applying.
                    # The apply's WAITING CAS may lose (failsafe expiry,
                    # leader churn) and the cluster swallows that conflict —
                    # so confirm this op actually won before handing the
                    # process to the executor.
                    self._propose_op(op)
                    assigned = self.db.get_process(p.processid)
                    if (
                        assigned.state != RUNNING
                        or assigned.assignedexecutorid != ex.executorid
                    ):
                        continue  # lost the race — try the next candidate
                    return assigned
                self.apply_assign(op)
                return self.db.get_process(p.processid)
        return None

    @requires_auth("executor")
    def apply_assign(self, op: dict) -> None:
        """State-machine apply for an assign op (also invoked by Raft commit).

        Compare-and-swap on ``state == WAITING`` — idempotent under Raft
        replay, and safe against a failsafe reset racing the assignment.
        """
        p = self.db.get_process(op["processid"])
        with self.db.colony_lock(p.colonyname):
            p = self.db.get_process(op["processid"])  # re-read under the lock
            if p.state != WAITING:
                raise ConflictError("process no longer waiting")
            ts = op["ts"]
            p.state = RUNNING
            p.isassigned = True
            p.assignedexecutorid = op["executorid"]
            p.starttime_ns = ts
            if p.spec.maxexectime and p.spec.maxexectime > 0:
                p.deadline_ns = ts + p.spec.maxexectime * 10**9
            else:
                p.deadline_ns = 0
            # Dataflow (Table 4): inputs = concatenated parent outputs.
            if p.parents:
                inputs: list[Any] = []
                for parent_id in p.parents:
                    parent = self.db.get_process(parent_id)
                    inputs.extend(parent.output)
                p.inputs = inputs
            self.db.update_process(p)

    # -- close ---------------------------------------------------------------
    def _h_close(self, identity: str, payload: dict) -> dict:
        pid = payload["processid"]
        p = self.db.get_process(pid)
        ex = self._require_executor(identity, p.colonyname)
        if p.assignedexecutorid != ex.executorid or p.state != RUNNING:
            # e.g. the failsafe already reset this process (paper §4.1:
            # "The previous executor then receives an error").
            raise ConflictError("process is not assigned to this executor")
        # Leader-stamped entry (REP001/REP004): the end timestamp is fixed
        # before the Raft log so close replays identically on every replica.
        op = {
            "op": "close",
            "opid": new_id(),
            "processid": pid,
            "executorid": ex.executorid,
            "successful": bool(payload.get("successful", True)),
            "out": payload.get("out", []),
            "errors": payload.get("errors", []),
            "ts": now_ns(),
            # Idempotency key of the originating RPC, replicated so
            # followers can attribute the close (ROBUSTNESS.md).
            "msgid": idempotency.current(),
        }
        if self._propose_op is not None:
            # HA path: serialize close through the Raft log. The apply's
            # RUNNING + owner CAS may lose (failsafe reset interleaving)
            # and the cluster swallows that conflict — confirm this close
            # actually won by checking the leader-stamped end time landed.
            self._propose_op(op)
            closed = self.db.get_process(pid)
            if (
                closed.state not in (SUCCESSFUL, FAILED)
                or closed.endtime_ns != op["ts"]
            ):
                raise ConflictError("process is not assigned to this executor")
        else:
            self.apply_close(op)
        return self.db.get_process(pid).to_dict()

    @requires_auth("executor")
    def apply_close(self, op: dict) -> None:
        """State-machine apply for a close op (also invoked by Raft commit).

        Deterministic by construction: the wall clock arrives leader-stamped
        as ``op["ts"]`` and the RUNNING + owner CAS inside ``close_process``
        turns a Raft replay into a clean ConflictError instead of a double
        mutation.
        """
        p = self.db.get_process(op["processid"])
        self.close_process(
            p,
            bool(op.get("successful", True)),
            op.get("out", []),
            op.get("errors", []),
            op["executorid"],
            ts=op["ts"],
        )

    @requires_auth("executor")
    def close_process(
        self,
        p: Process,
        succeeded: bool,
        output: list[Any],
        errors: list[str],
        expected_executorid: str | None = None,
        *,
        ts: int,
    ) -> None:
        """Close + stateless DAG propagation (paper §3.4.2).

        Serialized against assign/failsafe on the colony lock: the process
        is re-read and CAS-checked (still RUNNING, still owned by
        ``expected_executorid``) before any mutation, so a failsafe reset
        that interleaved after the caller's precheck turns this into a
        clean ConflictError instead of silently overwriting a re-queued
        or re-assigned process.

        ``ts`` is the leader-stamped end time from the replicated close
        entry — reading the wall clock inside this mutation would make
        the apply nondeterministic across replicas (replint REP001), so
        it is required, never defaulted.
        """
        released: list[tuple[str, str]] = []
        with self.db.colony_lock(p.colonyname):
            fresh = self.db.get_process(p.processid)
            if fresh.state != RUNNING:
                raise ConflictError("process is not running")
            if (
                expected_executorid is not None
                and fresh.assignedexecutorid != expected_executorid
            ):
                raise ConflictError("process is not assigned to this executor")
            fresh.state = SUCCESSFUL if succeeded else FAILED
            fresh.endtime_ns = ts
            fresh.output = list(output)
            fresh.errors = list(errors)
            fresh.deadline_ns = 0
            self.db.update_process(fresh)
            if succeeded:
                for child_id in fresh.children:
                    child = self._maybe_release_child(child_id)
                    if child is not None:
                        released.append(self._queue_key(child))
            else:
                # Fail descendants so workflows terminate instead of hanging.
                self._fail_descendants(
                    fresh, f"parent process {fresh.processid} failed", ts
                )
        if released:
            self._notify_queue(released)

    def _maybe_release_child(self, child_id: str) -> Process | None:
        child = self.db.get_process(child_id)
        if not child.wait_for_parents:
            return None
        for parent_id in child.parents:
            if self.db.get_process(parent_id).state != SUCCESSFUL:
                return None
        child.wait_for_parents = False
        self.db.update_process(child)
        if hasattr(self.db, "requeue"):
            self.db.requeue(child)
        return child

    def _fail_descendants(self, p: Process, reason: str, ts: int) -> None:
        # ``ts`` is the leader-stamped (or failsafe-scan) timestamp of the
        # triggering mutation — descendants inherit it so the whole cascade
        # is deterministic under Raft replay (replint REP001).
        for child_id in p.children:
            child = self.db.get_process(child_id)
            if child.state in (WAITING, RUNNING):
                child.state = FAILED
                child.endtime_ns = ts
                child.errors = [reason]
                self.db.update_process(child)
                self._fail_descendants(child, reason, ts)

    # -- dynamic children (MapReduce on the fly, paper §3.4.2) ----------------
    def _h_add_child(self, identity: str, payload: dict) -> dict:
        parent_id = payload["processid"]
        parent = self.db.get_process(parent_id)
        ex = self._require_executor(identity, parent.colonyname)
        if parent.assignedexecutorid != ex.executorid or parent.state != RUNNING:
            raise AuthError("only the assigned executor may extend the DAG")
        spec = FunctionSpec.from_dict(payload["spec"])
        spec.conditions.colonyname = parent.colonyname
        child = Process.create(spec)
        insert_after_parent = bool(payload.get("waitforparent", False))
        # Serialized against close/failsafe on the colony lock, with a
        # CAS-revalidation like close_process: without it, a close (or
        # failsafe reset) interleaving between the precheck above and the
        # children append below would either lose the child edge entirely
        # or strand a waitforparent child whose parent already succeeded.
        with self.db.colony_lock(parent.colonyname):
            parent = self.db.get_process(parent_id)  # re-read under the lock
            if parent.assignedexecutorid != ex.executorid or parent.state != RUNNING:
                raise ConflictError("parent closed or reassigned while extending the DAG")
            child.workflowid = parent.workflowid
            if insert_after_parent:
                child.parents = [parent_id]
                child.wait_for_parents = True
            self.db.add_process(child)
            parent.children = parent.children + [child.processid]
            self.db.update_process(parent)
        if not child.wait_for_parents:
            self._notify_queue([self._queue_key(child)])
        return child.to_dict()

    # -- introspection ---------------------------------------------------------
    def _h_get_process(self, identity: str, payload: dict) -> dict:
        p = self.db.get_process(payload["processid"])
        self._require_member(identity, p.colonyname)
        return p.to_dict()

    def _h_get_processes(self, identity: str, payload: dict) -> list[dict]:
        colony = payload["colonyname"]
        self._require_member(identity, colony)
        return [
            p.to_dict()
            for p in self.db.list_processes(
                colony, payload.get("state"), int(payload.get("count", 100))
            )
        ]

    def _h_stats(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        self._require_member(identity, colony)
        # O(1) counter read — total over every state ever observed, so a
        # process in an unexpected state can never KeyError the endpoint.
        stats: dict[str, int] = {s: 0 for s in STATES}
        for state, n in self.db.colony_stats(colony).items():
            stats[state] = stats.get(state, 0) + n
        stats["executors"] = len(self.db.list_executors(colony))
        stats["failsafe_errors"] = self.failsafe_errors
        # Blob-plane health (STORAGE.md): per-shard op/byte/repair
        # counters aggregated over every live ShardedStorage in the
        # process (broker + executors share one process in this repro,
        # exactly like the InProc transport).
        stats["blob"] = blobstore.aggregate_stats()
        return stats

    # -- failsafe (paper §3.4) --------------------------------------------------
    @no_locks_held()
    def failsafe_scan(self) -> dict:
        """One failsafe pass; returns counters (also used by tests).

        The deadline indexes hand back only expired processes, and each
        mutation re-validates under the colony lock so a concurrent close
        (or another replica's scan) can't be clobbered.
        """
        ts = now_ns()
        reset = failed = expired = 0
        woken: list[tuple[str, str]] = []
        for p in self.db.running_past_deadline(ts):
            with self.db.colony_lock(p.colonyname):
                try:
                    cur = self.db.get_process(p.processid)
                except NotFoundError:
                    continue
                if (
                    cur.state != RUNNING
                    or not cur.deadline_ns
                    or cur.deadline_ns >= ts
                ):
                    continue  # closed or re-assigned since the index read
                if cur.retries + 1 > max(cur.spec.maxretries, 0):
                    cur.state = FAILED
                    cur.endtime_ns = ts
                    cur.errors = cur.errors + [
                        "maxretries exceeded after maxexectime reset"
                    ]
                    self.db.update_process(cur)
                    self._fail_descendants(
                        cur, f"parent process {cur.processid} failed", ts
                    )
                    failed += 1
                else:
                    # Reset back to the queue — another executor picks it up.
                    cur.state = WAITING
                    cur.isassigned = False
                    cur.assignedexecutorid = ""
                    cur.starttime_ns = 0
                    cur.deadline_ns = 0
                    cur.retries += 1
                    self.db.update_process(cur)
                    if hasattr(self.db, "requeue"):
                        self.db.requeue(cur)
                    woken.append(self._queue_key(cur))
                    reset += 1
        for p in self.db.waiting_past_deadline(ts):
            with self.db.colony_lock(p.colonyname):
                try:
                    cur = self.db.get_process(p.processid)
                except NotFoundError:
                    continue
                if (
                    cur.state != WAITING
                    or not cur.waitdeadline_ns
                    or cur.waitdeadline_ns >= ts
                ):
                    continue
                cur.state = FAILED
                cur.endtime_ns = ts
                cur.errors = cur.errors + ["maxwaittime exceeded"]
                self.db.update_process(cur)
                self._fail_descendants(
                    cur, f"parent process {cur.processid} failed", ts
                )
                expired += 1
        if woken:
            self._notify_queue(woken)
        return {"reset": reset, "failed": failed, "waitexpired": expired}

    def start_background(self, failsafe_interval: float = 0.25) -> None:
        """Start the periodic failsafe scanner (leader-gated in HA mode).

        The loop must survive anything a scan or extension tick throws —
        a dead failsafe thread silently disables the paper's §3.4 story.
        Failures are counted (``failsafe_errors``, surfaced via
        ``colonystats``) and the first traceback is logged once."""

        def loop() -> None:
            while not self._stop.wait(failsafe_interval):
                try:
                    if self._is_leader():
                        self.failsafe_scan()
                    for ext in self.extensions:
                        tick = getattr(ext, "tick", None)
                        if tick is not None and self._is_leader():
                            tick()
                except Exception:
                    if self.failsafe_errors == 0:
                        logging.getLogger(__name__).exception(
                            "failsafe loop error on %s (counting further "
                            "errors silently; see colonystats.failsafe_errors)",
                            self.name,
                        )
                    self.failsafe_errors += 1

        self._failsafe_thread = threading.Thread(target=loop, daemon=True)
        self._failsafe_thread.start()

    def stop(self) -> None:
        self._stop.set()
        self._notify_queue()
        if self._failsafe_thread is not None:
            self._failsafe_thread.join(timeout=2)

    # -- queue wakeups -------------------------------------------------------
    @staticmethod
    def _queue_key(p: Process) -> tuple[str, str]:
        return (p.colonyname, p.spec.conditions.executortype)

    def _signal(self, key: tuple[str, str]) -> _QueueSignal:
        with self._signals_guard:
            sig = self._signals.get(key)
            if sig is None:
                sig = self._signals[key] = _QueueSignal(key)
            return sig

    def _notify_queue(self, keys: list[tuple[str, str]] | None = None) -> None:
        """Wake long-poll waiters. ``keys=None`` (extensions, stop) wakes all."""
        if keys is None:
            with self._signals_guard:
                sigs = list(self._signals.values())
        else:
            sigs = [self._signal(k) for k in set(keys)]
        for sig in sigs:
            with sig.cv:
                sig.version += 1
                sig.cv.notify_all()

    # -- HA wiring ----------------------------------------------------------------
    def set_leader_check(self, fn: Callable[[], bool]) -> None:
        self._ha = True
        self._is_leader = fn

    def set_op_proposer(self, fn: Callable[[dict], None]) -> None:
        """Route replicated ops (assign, close, …) through the Raft log.

        The callable must block until the entry is committed and applied
        locally (``ThreadedRaftCluster.propose_and_wait`` semantics).
        """
        self._propose_op = fn

    # Back-compat: PR 1 named the hook after its only op at the time.
    set_assign_proposer = set_op_proposer
