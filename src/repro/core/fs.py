"""CFS — the Colony FileSystem (paper §3.4.5).

A *meta*-filesystem: the Colonies database stores only metadata (names,
labels, checksums, sizes, storage references); bytes live in pluggable
storage backends (S3/IPFS in the paper; content-addressed local/memory
stores here — same contract).

Invariants implemented exactly as the paper argues:
  * **Immutability** — a file revision is never altered; re-adding the
    same (label, name) creates a new revision. Caching and race-freedom
    follow.
  * **Snapshots** — immutable pins of a whole label tree (directory), so
    queued processes see frozen inputs no matter how long they wait.
  * **Sync directives** — function specs carry ``fs.snapshots``/``fs.dirs``
    blocks; executors materialize them before execution and upload
    results after (see runtime/jax_executor.py).
"""

from __future__ import annotations

import hashlib
import os
import secrets
import threading
from typing import Any, Callable

from .database import Database
from .errors import AuthError, ConflictError, NotFoundError, ValidationError
from .process import now_ns

FILES_TABLE = "cfs_files"
SNAPSHOTS_TABLE = "cfs_snapshots"


def checksum(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


# ---------------------------------------------------------------------------
# Storage backends (the bytes plane)
# ---------------------------------------------------------------------------


class Storage:
    """Content-addressed blob store."""

    scheme = "abstract"

    def put(self, data: bytes) -> str:
        """Store bytes, return an URL."""
        raise NotImplementedError

    def get(self, url: str) -> bytes:
        raise NotImplementedError


class MemoryStorage(Storage):
    scheme = "mem"

    def __init__(self) -> None:
        self._blobs: dict[str, bytes] = {}
        self._lock = threading.Lock()

    def put(self, data: bytes) -> str:
        key = checksum(data)
        with self._lock:
            self._blobs[key] = bytes(data)
        return f"mem://{key}"

    def get(self, url: str) -> bytes:
        key = url.split("://", 1)[1]
        with self._lock:
            if key not in self._blobs:
                raise NotFoundError(f"blob {url} not found")
            return self._blobs[key]


class LocalStorage(Storage):
    """Directory-backed content-addressed store (stands in for S3)."""

    scheme = "local"

    def __init__(self, root: str) -> None:
        self.root = root
        os.makedirs(root, exist_ok=True)

    def put(self, data: bytes) -> str:
        key = checksum(data)
        path = os.path.join(self.root, key)
        if not os.path.exists(path):  # immutable: same content = same blob
            tmp = path + f".tmp{secrets.token_hex(4)}"
            with open(tmp, "wb") as f:
                f.write(data)
            os.replace(tmp, path)
        return f"local://{key}"

    def get(self, url: str) -> bytes:
        key = url.split("://", 1)[1]
        path = os.path.join(self.root, key)
        if not os.path.exists(path):
            raise NotFoundError(f"blob {url} not found")
        with open(path, "rb") as f:
            return f.read()


# ---------------------------------------------------------------------------
# Server-side extension: metadata handlers
# ---------------------------------------------------------------------------


class CFSExtension:
    """Registers CFS metadata RPCs on a ColoniesServer."""

    def __init__(self, server) -> None:
        self.server = server
        self.db: Database = server.db
        server.extensions.append(self)

    def handlers(self) -> dict[str, Callable[[str, dict], Any]]:
        return {
            "addfile": self._h_add_file,
            "getfile": self._h_get_file,
            "getfiles": self._h_get_files,
            "removefile": self._h_remove_file,
            "createsnapshot": self._h_create_snapshot,
            "getsnapshot": self._h_get_snapshot,
            "removesnapshot": self._h_remove_snapshot,
        }

    # no periodic work
    def tick(self) -> None:
        pass

    @staticmethod
    def _norm_label(label: str) -> str:
        if not label.startswith("/"):
            label = "/" + label
        return label.rstrip("/") or "/"

    def _h_add_file(self, identity: str, payload: dict) -> dict:
        f = payload["file"]
        colony = f.get("colonyname", "")
        self.server._require_member(identity, colony)
        label = self._norm_label(f.get("label", "/"))
        name = f.get("name", "")
        if not name:
            raise ValidationError("file needs a name")
        if not f.get("checksum"):
            raise ValidationError("file needs a checksum (immutability contract)")
        prev = self._latest(colony, label, name)
        entry = {
            "fileid": secrets.token_hex(16),
            "colonyname": colony,
            "label": label,
            "name": name,
            "size": int(f.get("size", 0)),
            "checksum": f["checksum"],
            "revision": (prev["revision"] + 1) if prev else 1,
            "storage": dict(f.get("storage", {})),  # {"backend": scheme, "url": ...}
            "added": now_ns(),
            "addedby": identity,
        }
        self.db.kv_put(FILES_TABLE, entry["fileid"], entry)
        return entry

    def _files(self, colony: str) -> list[dict]:
        return [
            e for e in self.db.kv_list(FILES_TABLE) if e["colonyname"] == colony
        ]

    def _latest(self, colony: str, label: str, name: str) -> dict | None:
        best = None
        for e in self._files(colony):
            if e["label"] == label and e["name"] == name:
                if best is None or e["revision"] > best["revision"]:
                    best = e
        return best

    def _h_get_file(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        if "fileid" in payload:
            e = self.db.kv_get(FILES_TABLE, payload["fileid"])
            if e is None or e["colonyname"] != colony:
                raise NotFoundError("file not found")
            return e
        label = self._norm_label(payload["label"])
        e = self._latest(colony, label, payload["name"])
        if e is None:
            raise NotFoundError(f"file {label}/{payload['name']} not found")
        return e

    def _h_get_files(self, identity: str, payload: dict) -> list[dict]:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        label = self._norm_label(payload["label"])
        latest: dict[str, dict] = {}
        for e in self._files(colony):
            if e["label"] == label or e["label"].startswith(label + "/"):
                key = e["label"] + "/" + e["name"]
                if key not in latest or e["revision"] > latest[key]["revision"]:
                    latest[key] = e
        return sorted(latest.values(), key=lambda e: (e["label"], e["name"]))

    def _h_remove_file(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        fileid = payload["fileid"]
        e = self.db.kv_get(FILES_TABLE, fileid)
        if e is None or e["colonyname"] != colony:
            raise NotFoundError("file not found")
        # Immutability: a revision pinned by a snapshot cannot be removed.
        for s in self.db.kv_list(SNAPSHOTS_TABLE):
            if fileid in s.get("fileids", []):
                raise ConflictError("file revision pinned by snapshot " + s["snapshotid"])
        self.db.kv_del(FILES_TABLE, fileid)
        return {"fileid": fileid, "removed": True}

    def _h_create_snapshot(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        label = self._norm_label(payload["label"])
        name = payload.get("name", "")
        files = self._h_get_files(identity, {"colonyname": colony, "label": label})
        snap = {
            "snapshotid": secrets.token_hex(16),
            "colonyname": colony,
            "name": name,
            "label": label,
            "fileids": [f["fileid"] for f in files],
            "added": now_ns(),
        }
        self.db.kv_put(SNAPSHOTS_TABLE, snap["snapshotid"], snap)
        return snap

    def _h_get_snapshot(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        s = self.db.kv_get(SNAPSHOTS_TABLE, payload["snapshotid"])
        if s is None or s["colonyname"] != colony:
            raise NotFoundError("snapshot not found")
        s = dict(s)
        s["files"] = [self.db.kv_get(FILES_TABLE, fid) for fid in s["fileids"]]
        return s

    def _h_remove_snapshot(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        sid = payload["snapshotid"]
        if self.db.kv_get(SNAPSHOTS_TABLE, sid) is None:
            raise NotFoundError("snapshot not found")
        self.db.kv_del(SNAPSHOTS_TABLE, sid)
        return {"snapshotid": sid, "removed": True}


# ---------------------------------------------------------------------------
# Client-side sync helper (what executors use)
# ---------------------------------------------------------------------------


class CFSClient:
    """Upload/download helper pairing the metadata plane with a Storage."""

    def __init__(self, client, storage: Storage, prvkey: str) -> None:
        self.client = client
        self.storage = storage
        self.prvkey = prvkey

    # -- single files -------------------------------------------------------
    def upload_bytes(self, colony: str, label: str, name: str, data: bytes) -> dict:
        url = self.storage.put(data)
        return self.client.add_file(
            {
                "colonyname": colony,
                "label": label,
                "name": name,
                "size": len(data),
                "checksum": checksum(data),
                "storage": {"backend": self.storage.scheme, "url": url},
            },
            self.prvkey,
        )

    def download_bytes(self, colony: str, label: str, name: str) -> bytes:
        meta = self.client.get_file(colony, label, name, self.prvkey)
        data = self.storage.get(meta["storage"]["url"])
        if checksum(data) != meta["checksum"]:
            raise ConflictError(f"checksum mismatch for {label}/{name}")
        return data

    # -- directory sync -------------------------------------------------------
    def sync_up(self, colony: str, label: str, localdir: str) -> list[dict]:
        """Upload every file under localdir to the label (new revisions)."""
        out = []
        for root, _dirs, files in os.walk(localdir):
            for fn in sorted(files):
                path = os.path.join(root, fn)
                rel = os.path.relpath(path, localdir)
                sub = os.path.dirname(rel)
                lbl = label if not sub else label.rstrip("/") + "/" + sub.replace(os.sep, "/")
                with open(path, "rb") as f:
                    out.append(self.upload_bytes(colony, lbl, os.path.basename(rel), f.read()))
        return out

    def sync_down(self, colony: str, label: str, localdir: str) -> list[str]:
        """Materialize the latest revision of every file under label."""
        os.makedirs(localdir, exist_ok=True)
        written = []
        for meta in self.client.get_files(colony, label, self.prvkey):
            rel_label = meta["label"][len(self._norm(label)) :].lstrip("/")
            dest_dir = os.path.join(localdir, rel_label) if rel_label else localdir
            os.makedirs(dest_dir, exist_ok=True)
            data = self.storage.get(meta["storage"]["url"])
            if checksum(data) != meta["checksum"]:
                raise ConflictError(f"checksum mismatch for {meta['name']}")
            path = os.path.join(dest_dir, meta["name"])
            with open(path, "wb") as f:
                f.write(data)
            written.append(path)
        return written

    def materialize_snapshot(self, colony: str, snapshotid: str, localdir: str) -> list[str]:
        """Write a pinned snapshot's exact revisions into localdir."""
        snap = self.client.get_snapshot(colony, snapshotid, self.prvkey)
        os.makedirs(localdir, exist_ok=True)
        written = []
        for meta in snap["files"]:
            data = self.storage.get(meta["storage"]["url"])
            if checksum(data) != meta["checksum"]:
                raise ConflictError(f"checksum mismatch for {meta['name']}")
            rel_label = meta["label"][len(snap["label"]) :].lstrip("/")
            dest_dir = os.path.join(localdir, rel_label) if rel_label else localdir
            os.makedirs(dest_dir, exist_ok=True)
            path = os.path.join(dest_dir, meta["name"])
            with open(path, "wb") as f:
                f.write(data)
            written.append(path)
        return written

    @staticmethod
    def _norm(label: str) -> str:
        if not label.startswith("/"):
            label = "/" + label
        return label.rstrip("/") or "/"
