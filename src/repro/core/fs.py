"""CFS — the Colony FileSystem (paper §3.4.5).

A *meta*-filesystem: the Colonies database stores only metadata (names,
labels, checksums, sizes, storage references); bytes live in pluggable
storage backends (S3/IPFS in the paper; content-addressed local/memory
stores here — same contract).

Invariants implemented exactly as the paper argues:
  * **Immutability** — a file revision is never altered; re-adding the
    same (label, name) creates a new revision. Caching and race-freedom
    follow.
  * **Snapshots** — immutable pins of a whole label tree (directory), so
    queued processes see frozen inputs no matter how long they wait.
  * **Sync directives** — function specs carry ``fs.snapshots``/``fs.dirs``
    blocks; executors materialize them before execution and upload
    results after (see runtime/jax_executor.py).
"""

from __future__ import annotations

import hashlib
import os
import secrets
import time
from typing import Any, Callable

from ..analysis.locktrack import make_lock
from .database import Database
from .errors import ConflictError, NotFoundError, TransportError, ValidationError
from .process import now_ns
from .retry import RetryPolicy


def checksum(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


def _write_atomic(path: str, data: bytes) -> None:
    """Crash-safe destination write: tmp + ``os.replace``, so a crash
    mid-write can never leave a torn file under the final name (the same
    contract ``LocalStorage.put`` already keeps for blobs)."""
    tmp = path + f".tmp{secrets.token_hex(4)}"
    try:
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


# ---------------------------------------------------------------------------
# Storage backends (the bytes plane)
# ---------------------------------------------------------------------------


class Storage:
    """Content-addressed blob store.

    The content-address contract cuts both ways: ``put`` derives the key
    from the bytes, and ``get`` re-verifies ``checksum(data) == key`` so
    a blob corrupted at rest raises ``ConflictError`` instead of
    silently propagating garbage (and so a sharded store can rotate to a
    healthy replica and read-repair the bad copy — see blobstore.py).
    """

    scheme = "abstract"

    def put(self, data: bytes) -> str:
        """Store bytes, return an URL."""
        raise NotImplementedError

    def get(self, url: str) -> bytes:
        raise NotImplementedError

    def keys(self) -> list[str]:
        """All stored content-address keys (for scrub/anti-entropy)."""
        raise NotImplementedError

    def quarantine(self, key: str) -> None:
        """Move a corrupt blob aside: the key reads as missing afterwards
        (so read-repair can rewrite it) but the bad bytes are kept for
        forensics instead of destroyed."""
        raise NotImplementedError


class MemoryStorage(Storage):
    scheme = "mem"

    def __init__(self) -> None:
        self._blobs: dict[str, bytes] = {}
        self._quarantined: dict[str, bytes] = {}
        self._lock = make_lock("storage")

    def put(self, data: bytes) -> str:
        key = checksum(data)
        with self._lock:
            self._blobs[key] = bytes(data)
        return f"mem://{key}"

    def get(self, url: str) -> bytes:
        key = url.split("://", 1)[1]
        with self._lock:
            if key not in self._blobs:
                raise NotFoundError(f"blob {url} not found")
            data = self._blobs[key]
        if checksum(data) != key:
            raise ConflictError(f"blob {url} failed its content-address check")
        return data

    def keys(self) -> list[str]:
        with self._lock:
            return sorted(self._blobs)

    def quarantine(self, key: str) -> None:
        with self._lock:
            data = self._blobs.pop(key, None)
            if data is not None:
                self._quarantined[key] = data


class LocalStorage(Storage):
    """Directory-backed content-addressed store (stands in for S3)."""

    scheme = "local"

    def __init__(self, root: str) -> None:
        self.root = root
        os.makedirs(root, exist_ok=True)

    def put(self, data: bytes) -> str:
        key = checksum(data)
        path = os.path.join(self.root, key)
        if not os.path.exists(path):  # immutable: same content = same blob
            _write_atomic(path, data)
        return f"local://{key}"

    def get(self, url: str) -> bytes:
        key = url.split("://", 1)[1]
        path = os.path.join(self.root, key)
        if not os.path.exists(path):
            raise NotFoundError(f"blob {url} not found")
        with open(path, "rb") as f:
            data = f.read()
        if checksum(data) != key:
            raise ConflictError(f"blob {url} failed its content-address check")
        return data

    def keys(self) -> list[str]:
        # Blob files are bare hex keys; tmp files and quarantined copies
        # carry a dotted suffix and never count as stored content.
        return sorted(n for n in os.listdir(self.root) if "." not in n)

    def quarantine(self, key: str) -> None:
        path = os.path.join(self.root, key)
        if os.path.exists(path):
            os.replace(path, path + f".quarantined-{secrets.token_hex(4)}")


# ---------------------------------------------------------------------------
# Server-side extension: metadata handlers
# ---------------------------------------------------------------------------


class CFSExtension:
    """Registers CFS metadata RPCs on a ColoniesServer.

    All handlers ride the database's indexed CFS plane (label trees,
    revision heads, pin refcounts) — no handler lists the file table, so
    every RPC does work bounded by its own result, not by how many files
    the deployment has ever stored (mirrors the broker's PR 1 rework).
    """

    def __init__(self, server) -> None:
        self.server = server
        self.db: Database = server.db
        server.extensions.append(self)

    def handlers(self) -> dict[str, Callable[[str, dict], Any]]:
        return {
            "addfile": self._h_add_file,
            "getfile": self._h_get_file,
            "getfiles": self._h_get_files,
            "removefile": self._h_remove_file,
            "createsnapshot": self._h_create_snapshot,
            "getsnapshot": self._h_get_snapshot,
            "getsnapshots": self._h_get_snapshots,
            "removesnapshot": self._h_remove_snapshot,
        }

    # no periodic work
    def tick(self) -> None:
        pass

    @staticmethod
    def _norm_label(label: str) -> str:
        if not label.startswith("/"):
            label = "/" + label
        return label.rstrip("/") or "/"

    def _h_add_file(self, identity: str, payload: dict) -> dict:
        f = payload["file"]
        colony = f.get("colonyname", "")
        self.server._require_member(identity, colony)
        label = self._norm_label(f.get("label", "/"))
        name = f.get("name", "")
        if not name:
            raise ValidationError("file needs a name")
        if name in (".", "..") or "/" in name or "\\" in name or os.sep in name:
            raise ValidationError(
                f"file name {name!r} must be a single path component"
                " (no separators, no '.'/'..')"
            )
        if not f.get("checksum"):
            raise ValidationError("file needs a checksum (immutability contract)")
        # An entry without a resolvable storage reference is metadata
        # pointing at nothing: accepting it makes every later
        # download_bytes / sync_down / materialize_snapshot die with a
        # bare KeyError — reject it at the RPC boundary instead.
        storage = f.get("storage")
        if not isinstance(storage, dict) or not storage.get("backend") or not storage.get("url"):
            raise ValidationError(
                "file needs a storage reference {'backend': ..., 'url': ...}"
            )
        if not isinstance(storage["backend"], str) or not isinstance(storage["url"], str):
            raise ValidationError("storage backend and url must be strings")
        entry = {
            "fileid": secrets.token_hex(16),
            "colonyname": colony,
            "label": label,
            "name": name,
            "size": int(f.get("size", 0)),
            "checksum": f["checksum"],
            "storage": dict(storage),  # {"backend": scheme, "url": ...}
            "added": now_ns(),
            "addedby": identity,
        }
        # The database assigns revision = head + 1 under its own lock.
        return self.db.cfs_add_file(entry)

    def _h_get_file(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        if "fileid" in payload:
            e = self.db.cfs_get_file(colony, payload["fileid"])
            if e is None:
                raise NotFoundError("file not found")
            return e
        label = self._norm_label(payload["label"])
        e = self.db.cfs_head(colony, label, payload["name"])
        if e is None:
            raise NotFoundError(f"file {label}/{payload['name']} not found")
        return e

    def _h_get_files(self, identity: str, payload: dict) -> list[dict]:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        return self.db.cfs_list(colony, self._norm_label(payload["label"]))

    def _h_remove_file(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        fileid = payload["fileid"]
        # Immutability: a revision pinned by a snapshot cannot be removed —
        # the database's refcount check raises ConflictError atomically.
        e = self.db.cfs_remove_file(colony, fileid)
        if e is None:
            raise NotFoundError("file not found")
        return {"fileid": fileid, "removed": True}

    def _h_create_snapshot(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        snap = {
            "snapshotid": secrets.token_hex(16),
            "colonyname": colony,
            "name": payload.get("name", ""),
            "label": self._norm_label(payload["label"]),
            "added": now_ns(),
        }
        return self.db.cfs_create_snapshot(snap)

    def _h_get_snapshot(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        s = self.db.cfs_get_snapshot(colony, payload["snapshotid"])
        if s is None:
            raise NotFoundError("snapshot not found")
        # A backfilled or hand-edited database may reference revisions that
        # no longer exist; surface them under "missing" instead of handing
        # clients None entries that explode in materialize_snapshot.
        files, missing = [], []
        for fid, e in zip(s["fileids"], self.db.cfs_get_files_by_ids(colony, s["fileids"])):
            (files.append(e) if e is not None else missing.append(fid))
        s["files"] = files
        if missing:
            s["missing"] = missing
        return s

    def _h_get_snapshots(self, identity: str, payload: dict) -> list[dict]:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        return self.db.cfs_list_snapshots(colony)

    def _h_remove_snapshot(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        sid = payload["snapshotid"]
        if self.db.cfs_remove_snapshot(colony, sid) is None:
            raise NotFoundError("snapshot not found")
        return {"snapshotid": sid, "removed": True}


# ---------------------------------------------------------------------------
# Client-side sync helper (what executors use)
# ---------------------------------------------------------------------------


class CFSClient:
    """Upload/download helper pairing the metadata plane with a Storage.

    ``retry=RetryPolicy(...)`` makes every blob put/get survive transient
    storage failure (a sharded store with all of one key's replicas
    momentarily unreachable, an injected ``blob.*`` fault) with the same
    capped decorrelated-jitter backoff the RPC transports use. Only
    transport-shaped errors are retried; a checksum mismatch is
    deterministic and surfaces immediately.
    """

    def __init__(
        self,
        client,
        storage: Storage,
        prvkey: str,
        retry: RetryPolicy | None = None,
    ) -> None:
        self.client = client
        self.storage = storage
        self.prvkey = prvkey
        self.retry = retry

    # -- blob-plane retry ---------------------------------------------------
    def _blob_retry(self, attempt: Callable[[], Any]) -> Any:
        """Drive one storage operation under the retry policy.

        Retries ``TransportError`` (a sharded store with zero reachable
        replicas) and ``ConnectionError``/``OSError`` (a raw backend or
        an injected fault); ``NotFoundError``/``ConflictError`` are
        answers, not failures, and propagate immediately.
        """
        if self.retry is None:
            return attempt()
        deadline = time.monotonic() + self.retry.deadline_s
        delays = self.retry.delays()
        budget = max(1, self.retry.budget)
        for i in range(budget):
            try:
                return attempt()
            except (TransportError, ConnectionError, TimeoutError):
                if i + 1 >= budget:
                    raise
                delay = delays.next_delay()
                if time.monotonic() + delay >= deadline:
                    raise
            time.sleep(delay)
        raise TransportError("blob retry budget exhausted")  # pragma: no cover

    # -- path safety --------------------------------------------------------
    @staticmethod
    def _safe_dest(localdir: str, rel_label: str, name: str) -> str:
        """Join server-supplied path pieces under ``localdir``, rejecting
        anything that could escape it (``..``, separators inside the
        name, absolute components). CFS labels/names are untrusted
        metadata: a file named ``../evil`` must never materialize outside
        the target directory."""
        parts = [c for c in rel_label.split("/") if c]
        parts.append(name)
        for c in parts:
            if (
                not c
                or c in (".", "..")
                or "/" in c
                or "\\" in c
                or os.sep in c
                or (os.altsep and os.altsep in c)
            ):
                raise ValidationError(
                    f"unsafe path component {c!r} in CFS entry"
                    f" (label {rel_label!r}, name {name!r})"
                )
        return os.path.join(localdir, *parts)

    # -- single files -------------------------------------------------------
    def upload_bytes(self, colony: str, label: str, name: str, data: bytes) -> dict:
        url = self._blob_retry(lambda: self.storage.put(data))
        return self.client.add_file(
            {
                "colonyname": colony,
                "label": label,
                "name": name,
                "size": len(data),
                "checksum": checksum(data),
                "storage": {"backend": self.storage.scheme, "url": url},
            },
            self.prvkey,
        )

    def download_bytes(self, colony: str, label: str, name: str) -> bytes:
        meta = self.client.get_file(colony, label, name, self.prvkey)
        data = self._fetch_blob(meta)
        return data

    def _fetch_blob(self, meta: dict) -> bytes:
        """Fetch + verify one CFS entry's bytes (retry-backed)."""
        storage_ref = meta.get("storage") or {}
        url = storage_ref.get("url")
        if not url:
            raise ValidationError(
                f"CFS entry {meta.get('label')!r}/{meta.get('name')!r}"
                " carries no storage url"
            )
        data = self._blob_retry(lambda: self.storage.get(url))
        if checksum(data) != meta["checksum"]:
            raise ConflictError(
                f"checksum mismatch for {meta.get('label')}/{meta.get('name')}"
            )
        return data

    # -- directory sync -------------------------------------------------------
    def sync_up(self, colony: str, label: str, localdir: str) -> list[dict]:
        """Upload every file under localdir to the label (new revisions)."""
        out = []
        for root, _dirs, files in os.walk(localdir):
            for fn in sorted(files):
                path = os.path.join(root, fn)
                rel = os.path.relpath(path, localdir)
                sub = os.path.dirname(rel)
                lbl = label if not sub else label.rstrip("/") + "/" + sub.replace(os.sep, "/")
                with open(path, "rb") as f:
                    out.append(self.upload_bytes(colony, lbl, os.path.basename(rel), f.read()))
        return out

    def _materialize_entry(self, meta: dict, base_label: str, localdir: str) -> str:
        """Fetch one entry and write it crash-safely under localdir."""
        rel_label = meta["label"][len(base_label):].lstrip("/")
        dest = self._safe_dest(localdir, rel_label, meta["name"])
        data = self._fetch_blob(meta)
        os.makedirs(os.path.dirname(dest), exist_ok=True)
        _write_atomic(dest, data)
        return dest

    def sync_down(self, colony: str, label: str, localdir: str) -> list[str]:
        """Materialize the latest revision of every file under label."""
        os.makedirs(localdir, exist_ok=True)
        base = self._norm(label)
        return [
            self._materialize_entry(meta, base, localdir)
            for meta in self.client.get_files(colony, label, self.prvkey)
        ]

    def materialize_snapshot(self, colony: str, snapshotid: str, localdir: str) -> list[str]:
        """Write a pinned snapshot's exact revisions into localdir."""
        snap = self.client.get_snapshot(colony, snapshotid, self.prvkey)
        os.makedirs(localdir, exist_ok=True)
        return [
            self._materialize_entry(meta, snap["label"], localdir)
            for meta in snap["files"]
        ]

    @staticmethod
    def _norm(label: str) -> str:
        if not label.startswith("/"):
            label = "/" + label
        return label.rstrip("/") or "/"


# Re-exported lazily (PEP 562): blobstore imports Storage/checksum from
# this module, so a module-level import here would be circular.
def __getattr__(name: str):
    if name == "ShardedStorage":
        from .blobstore import ShardedStorage

        return ShardedStorage
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
