"""CFS — the Colony FileSystem (paper §3.4.5).

A *meta*-filesystem: the Colonies database stores only metadata (names,
labels, checksums, sizes, storage references); bytes live in pluggable
storage backends (S3/IPFS in the paper; content-addressed local/memory
stores here — same contract).

Invariants implemented exactly as the paper argues:
  * **Immutability** — a file revision is never altered; re-adding the
    same (label, name) creates a new revision. Caching and race-freedom
    follow.
  * **Snapshots** — immutable pins of a whole label tree (directory), so
    queued processes see frozen inputs no matter how long they wait.
  * **Sync directives** — function specs carry ``fs.snapshots``/``fs.dirs``
    blocks; executors materialize them before execution and upload
    results after (see runtime/jax_executor.py).
"""

from __future__ import annotations

import hashlib
import os
import secrets
from typing import Any, Callable

from ..analysis.locktrack import make_lock
from .database import Database
from .errors import ConflictError, NotFoundError, ValidationError
from .process import now_ns


def checksum(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


# ---------------------------------------------------------------------------
# Storage backends (the bytes plane)
# ---------------------------------------------------------------------------


class Storage:
    """Content-addressed blob store."""

    scheme = "abstract"

    def put(self, data: bytes) -> str:
        """Store bytes, return an URL."""
        raise NotImplementedError

    def get(self, url: str) -> bytes:
        raise NotImplementedError


class MemoryStorage(Storage):
    scheme = "mem"

    def __init__(self) -> None:
        self._blobs: dict[str, bytes] = {}
        self._lock = make_lock("storage")

    def put(self, data: bytes) -> str:
        key = checksum(data)
        with self._lock:
            self._blobs[key] = bytes(data)
        return f"mem://{key}"

    def get(self, url: str) -> bytes:
        key = url.split("://", 1)[1]
        with self._lock:
            if key not in self._blobs:
                raise NotFoundError(f"blob {url} not found")
            return self._blobs[key]


class LocalStorage(Storage):
    """Directory-backed content-addressed store (stands in for S3)."""

    scheme = "local"

    def __init__(self, root: str) -> None:
        self.root = root
        os.makedirs(root, exist_ok=True)

    def put(self, data: bytes) -> str:
        key = checksum(data)
        path = os.path.join(self.root, key)
        if not os.path.exists(path):  # immutable: same content = same blob
            tmp = path + f".tmp{secrets.token_hex(4)}"
            with open(tmp, "wb") as f:
                f.write(data)
            os.replace(tmp, path)
        return f"local://{key}"

    def get(self, url: str) -> bytes:
        key = url.split("://", 1)[1]
        path = os.path.join(self.root, key)
        if not os.path.exists(path):
            raise NotFoundError(f"blob {url} not found")
        with open(path, "rb") as f:
            return f.read()


# ---------------------------------------------------------------------------
# Server-side extension: metadata handlers
# ---------------------------------------------------------------------------


class CFSExtension:
    """Registers CFS metadata RPCs on a ColoniesServer.

    All handlers ride the database's indexed CFS plane (label trees,
    revision heads, pin refcounts) — no handler lists the file table, so
    every RPC does work bounded by its own result, not by how many files
    the deployment has ever stored (mirrors the broker's PR 1 rework).
    """

    def __init__(self, server) -> None:
        self.server = server
        self.db: Database = server.db
        server.extensions.append(self)

    def handlers(self) -> dict[str, Callable[[str, dict], Any]]:
        return {
            "addfile": self._h_add_file,
            "getfile": self._h_get_file,
            "getfiles": self._h_get_files,
            "removefile": self._h_remove_file,
            "createsnapshot": self._h_create_snapshot,
            "getsnapshot": self._h_get_snapshot,
            "getsnapshots": self._h_get_snapshots,
            "removesnapshot": self._h_remove_snapshot,
        }

    # no periodic work
    def tick(self) -> None:
        pass

    @staticmethod
    def _norm_label(label: str) -> str:
        if not label.startswith("/"):
            label = "/" + label
        return label.rstrip("/") or "/"

    def _h_add_file(self, identity: str, payload: dict) -> dict:
        f = payload["file"]
        colony = f.get("colonyname", "")
        self.server._require_member(identity, colony)
        label = self._norm_label(f.get("label", "/"))
        name = f.get("name", "")
        if not name:
            raise ValidationError("file needs a name")
        if not f.get("checksum"):
            raise ValidationError("file needs a checksum (immutability contract)")
        entry = {
            "fileid": secrets.token_hex(16),
            "colonyname": colony,
            "label": label,
            "name": name,
            "size": int(f.get("size", 0)),
            "checksum": f["checksum"],
            "storage": dict(f.get("storage", {})),  # {"backend": scheme, "url": ...}
            "added": now_ns(),
            "addedby": identity,
        }
        # The database assigns revision = head + 1 under its own lock.
        return self.db.cfs_add_file(entry)

    def _h_get_file(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        if "fileid" in payload:
            e = self.db.cfs_get_file(colony, payload["fileid"])
            if e is None:
                raise NotFoundError("file not found")
            return e
        label = self._norm_label(payload["label"])
        e = self.db.cfs_head(colony, label, payload["name"])
        if e is None:
            raise NotFoundError(f"file {label}/{payload['name']} not found")
        return e

    def _h_get_files(self, identity: str, payload: dict) -> list[dict]:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        return self.db.cfs_list(colony, self._norm_label(payload["label"]))

    def _h_remove_file(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        fileid = payload["fileid"]
        # Immutability: a revision pinned by a snapshot cannot be removed —
        # the database's refcount check raises ConflictError atomically.
        e = self.db.cfs_remove_file(colony, fileid)
        if e is None:
            raise NotFoundError("file not found")
        return {"fileid": fileid, "removed": True}

    def _h_create_snapshot(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        snap = {
            "snapshotid": secrets.token_hex(16),
            "colonyname": colony,
            "name": payload.get("name", ""),
            "label": self._norm_label(payload["label"]),
            "added": now_ns(),
        }
        return self.db.cfs_create_snapshot(snap)

    def _h_get_snapshot(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        s = self.db.cfs_get_snapshot(colony, payload["snapshotid"])
        if s is None:
            raise NotFoundError("snapshot not found")
        # A backfilled or hand-edited database may reference revisions that
        # no longer exist; surface them under "missing" instead of handing
        # clients None entries that explode in materialize_snapshot.
        files, missing = [], []
        for fid, e in zip(s["fileids"], self.db.cfs_get_files_by_ids(colony, s["fileids"])):
            (files.append(e) if e is not None else missing.append(fid))
        s["files"] = files
        if missing:
            s["missing"] = missing
        return s

    def _h_get_snapshots(self, identity: str, payload: dict) -> list[dict]:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        return self.db.cfs_list_snapshots(colony)

    def _h_remove_snapshot(self, identity: str, payload: dict) -> dict:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        sid = payload["snapshotid"]
        if self.db.cfs_remove_snapshot(colony, sid) is None:
            raise NotFoundError("snapshot not found")
        return {"snapshotid": sid, "removed": True}


# ---------------------------------------------------------------------------
# Client-side sync helper (what executors use)
# ---------------------------------------------------------------------------


class CFSClient:
    """Upload/download helper pairing the metadata plane with a Storage."""

    def __init__(self, client, storage: Storage, prvkey: str) -> None:
        self.client = client
        self.storage = storage
        self.prvkey = prvkey

    # -- single files -------------------------------------------------------
    def upload_bytes(self, colony: str, label: str, name: str, data: bytes) -> dict:
        url = self.storage.put(data)
        return self.client.add_file(
            {
                "colonyname": colony,
                "label": label,
                "name": name,
                "size": len(data),
                "checksum": checksum(data),
                "storage": {"backend": self.storage.scheme, "url": url},
            },
            self.prvkey,
        )

    def download_bytes(self, colony: str, label: str, name: str) -> bytes:
        meta = self.client.get_file(colony, label, name, self.prvkey)
        data = self.storage.get(meta["storage"]["url"])
        if checksum(data) != meta["checksum"]:
            raise ConflictError(f"checksum mismatch for {label}/{name}")
        return data

    # -- directory sync -------------------------------------------------------
    def sync_up(self, colony: str, label: str, localdir: str) -> list[dict]:
        """Upload every file under localdir to the label (new revisions)."""
        out = []
        for root, _dirs, files in os.walk(localdir):
            for fn in sorted(files):
                path = os.path.join(root, fn)
                rel = os.path.relpath(path, localdir)
                sub = os.path.dirname(rel)
                lbl = label if not sub else label.rstrip("/") + "/" + sub.replace(os.sep, "/")
                with open(path, "rb") as f:
                    out.append(self.upload_bytes(colony, lbl, os.path.basename(rel), f.read()))
        return out

    def sync_down(self, colony: str, label: str, localdir: str) -> list[str]:
        """Materialize the latest revision of every file under label."""
        os.makedirs(localdir, exist_ok=True)
        written = []
        for meta in self.client.get_files(colony, label, self.prvkey):
            rel_label = meta["label"][len(self._norm(label)) :].lstrip("/")
            dest_dir = os.path.join(localdir, rel_label) if rel_label else localdir
            os.makedirs(dest_dir, exist_ok=True)
            data = self.storage.get(meta["storage"]["url"])
            if checksum(data) != meta["checksum"]:
                raise ConflictError(f"checksum mismatch for {meta['name']}")
            path = os.path.join(dest_dir, meta["name"])
            with open(path, "wb") as f:
                f.write(data)
            written.append(path)
        return written

    def materialize_snapshot(self, colony: str, snapshotid: str, localdir: str) -> list[str]:
        """Write a pinned snapshot's exact revisions into localdir."""
        snap = self.client.get_snapshot(colony, snapshotid, self.prvkey)
        os.makedirs(localdir, exist_ok=True)
        written = []
        for meta in snap["files"]:
            data = self.storage.get(meta["storage"]["url"])
            if checksum(data) != meta["checksum"]:
                raise ConflictError(f"checksum mismatch for {meta['name']}")
            rel_label = meta["label"][len(snap["label"]) :].lstrip("/")
            dest_dir = os.path.join(localdir, rel_label) if rel_label else localdir
            os.makedirs(dest_dir, exist_ok=True)
            path = os.path.join(dest_dir, meta["name"])
            with open(path, "wb") as f:
                f.write(data)
            written.append(path)
        return written

    @staticmethod
    def _norm(label: str) -> str:
        if not label.startswith("/"):
            label = "/" + label
        return label.rstrip("/") or "/"
