"""High-availability deployment (paper §3.4.1, Fig. 3).

N Colonies server replicas share one database (the paper's shared
Postgres); a Raft cluster elects the leader. Only the leader serves
``assign`` — followers answer 421 and the SDK transport retries against
the next replica. Assign operations are serialized through the Raft log
before being applied, guaranteeing exactly one executor per process even
across leader failovers; the apply is idempotent so replay is safe.

Cron/generator scanning and the failsafe run on the leader only.
"""

from __future__ import annotations

from collections import deque

from ..analysis import statehash
from ..analysis.locktrack import make_lock
from .cron import CronExtension
from .database import Database, MemoryDatabase
from .errors import ConflictError, NotFoundError
from .fs import CFSExtension
from .generator import GeneratorExtension
from .raft import ThreadedRaftCluster
from .server import ColoniesServer

# The replicated-op matrix (REPLICATION.md is generated from this literal
# by ``python -m repro.analysis.replmap``; replint roots its apply cone
# here). Every op MUST carry the leader-stamped fields — wall-clock and
# identity are fixed before the Raft log so the apply is deterministic —
# and every apply MUST be CAS-guarded under the colony lock so replaying
# the entry after a failover is a no-op.
REPLICATED_OPS: dict[str, dict] = {
    "assign": {
        "apply": "ColoniesServer.apply_assign",
        "required": ("op", "opid", "processid", "executorid", "ts", "msgid"),
        "leader_stamped": ("opid", "ts", "msgid"),
        "cas": "state == WAITING under db.colony_lock",
    },
    "close": {
        "apply": "ColoniesServer.apply_close",
        "required": (
            "op",
            "opid",
            "processid",
            "executorid",
            "successful",
            "out",
            "errors",
            "ts",
            "msgid",
        ),
        "leader_stamped": ("opid", "ts", "msgid"),
        "cas": "state == RUNNING and executor ownership under db.colony_lock",
    },
}


class HAColonyCluster:
    """A replicated Colonies service: ``cluster.servers`` are the replicas."""

    def __init__(
        self,
        serverid: str,
        replicas: int = 3,
        db: Database | None = None,
        verify_signatures: bool = True,
        seed: int = 0,
    ) -> None:
        # One shared database: its per-colony locks (db.colony_lock) are the
        # serialization point for assign/close/failsafe across ALL replicas.
        self.db = db if db is not None else MemoryDatabase()
        self.servers: list[ColoniesServer] = []
        self._applied_lock = make_lock("applied")
        # Bounded replay-dedup window; the per-op CAS (see REPLICATED_OPS)
        # is the authoritative idempotence guard for anything older.
        self._applied_ops: set[str] = set()
        self._applied_order: deque[str] = deque(maxlen=4096)
        # REPRO_REPL_CHECK state: one incremental digest per colony, and
        # the effect digest journaled by the first node to apply each
        # index. All applies run on the single Raft event-loop thread, but
        # a lagging node may apply index i after the leader already
        # applied i+1 — digesting the live shared DB again would falsely
        # diverge, so replays reuse the first applier's effect.
        self._digests: dict[str, statehash.ColonyDigest] = {}
        self._effect_by_index: dict[int, str] = {}
        self._effect_order: deque[int] = deque(maxlen=65536)

        self.raft = ThreadedRaftCluster(replicas, self._apply, seed=seed)

        for i in range(replicas):
            srv = ColoniesServer(
                serverid,
                self.db,
                verify_signatures=verify_signatures,
                name=f"colonies-{i}",
            )
            CronExtension(srv)
            GeneratorExtension(srv)
            CFSExtension(srv)
            nid = f"n{i}"
            node = self.raft.nodes[nid]
            srv.set_leader_check(node.is_leader)
            srv.set_op_proposer(
                (lambda nid_: lambda op: self._propose(nid_, op))(nid)
            )
            self.servers.append(srv)

    def _propose(self, nid: str, op: dict) -> int:
        spec = REPLICATED_OPS.get(op.get("op", ""))
        if spec is None:
            raise ValueError(f"not a replicated op: {op.get('op')!r}")
        missing = [f for f in spec["required"] if f not in op]
        if missing:
            # Leader-side contract: an entry missing its stamped fields
            # would force the apply to improvise them per replica —
            # exactly the nondeterminism replint REP004 guards against.
            raise ValueError(
                f"replicated {op['op']} entry missing fields: {missing}"
            )
        return self.raft.propose_and_wait(nid, op)

    # Replicated state machine apply — idempotent against the shared DB.
    # Returns the effect digest under REPRO_REPL_CHECK (folded into the
    # per-node apply journal by ThreadedRaftCluster), else None.
    def _apply(self, node_id: str, entry: dict, index: int) -> str | None:
        spec = REPLICATED_OPS.get(entry.get("op", ""))
        if spec is None:
            return None
        apply_op = getattr(self.servers[0], spec["apply"].split(".", 1)[1])
        key = entry.get("opid") or (
            f"{entry['processid']}:{entry['executorid']}:{entry['ts']}"
        )
        with self._applied_lock:
            if key in self._applied_ops:
                # Replay of an index another node already applied: the
                # shared DB may have moved on, so report the effect the
                # first applier journaled for this index.
                return self._effect_by_index.get(index)
            if len(self._applied_order) == self._applied_order.maxlen:
                self._applied_ops.discard(self._applied_order[0])
            self._applied_order.append(key)
            self._applied_ops.add(key)
        if not statehash.is_enabled():
            try:
                apply_op(entry)
            except ConflictError:
                # Same op replayed after a failover — already applied.
                pass
            return None
        return self._apply_checked(apply_op, entry, index)

    def _apply_checked(self, apply_op, entry: dict, index: int) -> str | None:
        """First apply of ``entry`` under REPRO_REPL_CHECK.

        Applies, folds the touched rows into the colony digest, then runs
        the double-apply harness: re-applies the same entry and requires
        the digest to be a fixpoint, proving the CAS makes replay a no-op.
        Holding the (reentrant) colony lock across observe → re-apply →
        re-observe keeps the leader's failsafe thread from mutating the
        colony mid-harness. Never raises on the event-loop thread —
        divergence is noted in the journal and re-raised by
        ``propose_and_wait`` / ``check_divergence``.
        """
        try:
            colony = self.db.get_process(entry["processid"]).colonyname
        except NotFoundError:
            return None
        digest = self._digests.get(colony)
        if digest is None:
            digest = self._digests[colony] = statehash.ColonyDigest()
        with self.db.colony_lock(colony):
            try:
                apply_op(entry)
            except ConflictError:
                pass
            self._observe(digest, entry)
            effect = digest.digest()
            try:
                apply_op(entry)
            except ConflictError:
                pass
            self._observe(digest, entry)
            if digest.digest() != effect and self.raft.journal is not None:
                self.raft.journal.note(
                    statehash.ReplicationDivergenceError(
                        f"apply of {entry.get('op')} entry"
                        f" {entry.get('opid', '?')[:16]} at raft index"
                        f" {index} is not idempotent: double-apply moved"
                        f" the colony digest {effect[:16]}… →"
                        f" {digest.digest()[:16]}…"
                    )
                )
        with self._applied_lock:
            if len(self._effect_order) == self._effect_order.maxlen:
                self._effect_by_index.pop(self._effect_order[0], None)
            self._effect_order.append(index)
            self._effect_by_index[index] = effect
        return effect

    def _observe(self, digest: statehash.ColonyDigest, entry: dict) -> None:
        """Fold the rows a replicated apply may touch into the digest:
        the primary process and (close cascades) its direct children."""
        pids = [entry["processid"]]
        if entry.get("op") == "close":
            try:
                pids.extend(self.db.get_process(entry["processid"]).children)
            except NotFoundError:
                pass
        for pid in pids:
            try:
                p = self.db.get_process(pid)
            except NotFoundError:
                digest.forget(pid)
                continue
            digest.observe(pid, statehash.process_state_tuple(p))

    def start(self, failsafe_interval: float = 0.25) -> None:
        self.raft.start()
        for srv in self.servers:
            srv.start_background(failsafe_interval)

    def stop(self) -> None:
        for srv in self.servers:
            srv.stop()
        self.raft.stop()

    def leader_server(self) -> ColoniesServer | None:
        lid = self.raft.leader_id()
        if lid is None:
            return None
        return self.servers[int(lid[1:])]

    def kill_server(self, index: int) -> None:
        """Chaos: partition a replica away (its raft node stops hearing)."""
        self.raft.kill(f"n{index}")

    def revive_server(self, index: int) -> None:
        self.raft.revive(f"n{index}")

    def wait_for_leader(self, timeout: float = 10.0) -> str | None:
        import time

        deadline = time.time() + timeout
        while time.time() < deadline:
            lid = self.raft.leader_id()
            if lid is not None:
                return lid
            time.sleep(0.02)
        return None


def standalone_server(
    serverid: str,
    db: Database | None = None,
    verify_signatures: bool = True,
) -> ColoniesServer:
    """Single-replica deployment with all extensions wired."""
    srv = ColoniesServer(serverid, db, verify_signatures=verify_signatures)
    CronExtension(srv)
    GeneratorExtension(srv)
    CFSExtension(srv)
    return srv
