"""High-availability deployment (paper §3.4.1, Fig. 3).

N Colonies server replicas share one database (the paper's shared
Postgres); a Raft cluster elects the leader. Only the leader serves
``assign`` — followers answer 421 and the SDK transport retries against
the next replica. Assign operations are serialized through the Raft log
before being applied, guaranteeing exactly one executor per process even
across leader failovers; the apply is idempotent so replay is safe.

Cron/generator scanning and the failsafe run on the leader only.
"""

from __future__ import annotations

from collections import deque

from ..analysis.locktrack import make_lock
from .cron import CronExtension
from .database import Database, MemoryDatabase
from .errors import ConflictError
from .fs import CFSExtension
from .generator import GeneratorExtension
from .raft import ThreadedRaftCluster
from .server import ColoniesServer


class HAColonyCluster:
    """A replicated Colonies service: ``cluster.servers`` are the replicas."""

    def __init__(
        self,
        serverid: str,
        replicas: int = 3,
        db: Database | None = None,
        verify_signatures: bool = True,
        seed: int = 0,
    ) -> None:
        # One shared database: its per-colony locks (db.colony_lock) are the
        # serialization point for assign/close/failsafe across ALL replicas.
        self.db = db if db is not None else MemoryDatabase()
        self.servers: list[ColoniesServer] = []
        self._applied_lock = make_lock("applied")
        # Bounded replay-dedup window; apply_assign's WAITING CAS is the
        # authoritative idempotence guard for anything older.
        self._applied_ops: set[str] = set()
        self._applied_order: deque[str] = deque(maxlen=4096)

        self.raft = ThreadedRaftCluster(replicas, self._apply, seed=seed)

        for i in range(replicas):
            srv = ColoniesServer(
                serverid,
                self.db,
                verify_signatures=verify_signatures,
                name=f"colonies-{i}",
            )
            CronExtension(srv)
            GeneratorExtension(srv)
            CFSExtension(srv)
            nid = f"n{i}"
            node = self.raft.nodes[nid]
            srv.set_leader_check(node.is_leader)
            srv.set_assign_proposer(
                (lambda nid_: lambda op: self.raft.propose_and_wait(nid_, op))(nid)
            )
            self.servers.append(srv)

    # Replicated state machine apply — idempotent against the shared DB.
    def _apply(self, node_id: str, entry: dict, index: int) -> None:
        if entry.get("op") != "assign":
            return
        key = f"{entry['processid']}:{entry['executorid']}:{entry['ts']}"
        with self._applied_lock:
            if key in self._applied_ops:
                return
            if len(self._applied_order) == self._applied_order.maxlen:
                self._applied_ops.discard(self._applied_order[0])
            self._applied_order.append(key)
            self._applied_ops.add(key)
        try:
            self.servers[0].apply_assign(entry)
        except ConflictError:
            # Same op replayed after a failover — already applied.
            pass

    def start(self, failsafe_interval: float = 0.25) -> None:
        self.raft.start()
        for srv in self.servers:
            srv.start_background(failsafe_interval)

    def stop(self) -> None:
        for srv in self.servers:
            srv.stop()
        self.raft.stop()

    def leader_server(self) -> ColoniesServer | None:
        lid = self.raft.leader_id()
        if lid is None:
            return None
        return self.servers[int(lid[1:])]

    def kill_server(self, index: int) -> None:
        """Chaos: partition a replica away (its raft node stops hearing)."""
        self.raft.kill(f"n{index}")

    def revive_server(self, index: int) -> None:
        self.raft.revive(f"n{index}")

    def wait_for_leader(self, timeout: float = 10.0) -> str | None:
        import time

        deadline = time.time() + timeout
        while time.time() < deadline:
            lid = self.raft.leader_id()
            if lid is not None:
                return lid
            time.sleep(0.02)
        return None


def standalone_server(
    serverid: str,
    db: Database | None = None,
    verify_signatures: bool = True,
) -> ColoniesServer:
    """Single-replica deployment with all extensions wired."""
    srv = ColoniesServer(serverid, db, verify_signatures=verify_signatures)
    CronExtension(srv)
    GeneratorExtension(srv)
    CFSExtension(srv)
    return srv
