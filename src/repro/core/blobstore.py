"""Sharded, self-healing blob plane for CFS (STORAGE.md).

The paper keeps CFS bytes in S3/IPFS-style distributed stores while the
Colonies database holds only metadata (§3.4.5). :class:`ShardedStorage`
is that distributed store: a content-addressed façade that
consistent-hashes every checksum key onto N child :class:`Storage`
shards with a configurable replication factor R.

Semantics (all machine-checked in tests/test_blobstore.py):

* **put** writes to all R replicas of the key and succeeds as long as at
  least one write lands (tolerating up to R−1 shard failures per put);
  a put that reaches zero replicas raises ``TransportError`` — the
  transport-shaped failure ``CFSClient``'s retry policy knows to retry.
* **get** walks the key's replicas in ring order and rotates to the
  next replica when one is unreachable, missing, or checksum-corrupt.
* **read-repair** — a get that found a healthy copy rewrites every
  replica it *observed* broken on the way (missing or corrupt) from the
  healthy bytes, and **quarantines** corrupt copies (the child keeps the
  bad bytes aside for forensics; the slot is freed for the repair
  write). :meth:`scrub` extends this to every replica of every key —
  the self-healing pass a revived shard needs to regain full
  replication.
* **fault sites** — every child-shard operation passes through the
  compiled-in ``blob.put``/``blob.get`` fault points
  (``repro.runtime.faults``) with ``shard``/``key`` context, so a
  seeded :class:`~repro.runtime.faults.FaultPlan` can kill exactly one
  shard mid-soak and the chaos gate can prove snapshots still
  materialize byte-identical.
* **counters** — per-shard op/byte/repair/quarantine counters, guarded
  by a ``blobshard`` lock (never held across a child-storage call; see
  CONCURRENCY.md), surfaced through the ``colonystats`` RPC via
  :func:`aggregate_stats`.

The ring is plain consistent hashing with virtual nodes: stable SHA-256
points, no RNG, no wall clock — fully deterministic, so tests and the
replication plane can rely on the shard map never moving under them.
"""

from __future__ import annotations

import bisect
import hashlib
import weakref

from ..analysis.locktrack import make_lock
from ..runtime import faults
from .errors import ConflictError, NotFoundError, TransportError
from .fs import Storage, checksum

# Virtual nodes per shard: enough that a 3-shard ring splits keys within
# a few percent of evenly (bench_storage.py prints the observed split).
VNODES = 64

# Child-shard failures that mean "this replica is unreachable right now"
# (rotate / tolerate), as opposed to "the bytes are provably absent or
# wrong" (NotFoundError / ConflictError, handled separately).
_TRANSIENT = (ConnectionError, TimeoutError, OSError, TransportError)

_COUNTERS = (
    "puts",
    "gets",
    "put_bytes",
    "get_bytes",
    "put_failures",
    "get_failures",
    "missing",
    "corrupt",
    "repairs",
    "repair_failures",
    "quarantined",
)

# Live stores, for colonystats aggregation (the broker and executors run
# in one process in this repro, exactly like the InProc transport).
_registry_lock = make_lock("blobshard:registry")
_registry: list[weakref.ref] = []
_seq = 0


def _register(store: "ShardedStorage") -> int:
    global _seq
    with _registry_lock:
        _seq += 1
        _registry.append(weakref.ref(store))
        return _seq


def aggregate_stats() -> dict:
    """Fleet-wide blob-plane counters for ``colonystats``.

    Snapshots the registry under its lock, then queries each live store
    outside it (no blobshard lock ever nests another).
    """
    with _registry_lock:
        refs = list(_registry)
    stores = [s for s in (r() for r in refs) if s is not None]
    if len(stores) < len(refs):
        with _registry_lock:
            _registry[:] = [r for r in _registry if r() is not None]
    out: dict = {"stores": len(stores), "shards": 0}
    totals = {k: 0 for k in _COUNTERS}
    for store in stores:
        st = store.stats()
        out["shards"] += st["shards"]
        for shard_stats in st["per_shard"].values():
            for k in _COUNTERS:
                totals[k] += shard_stats[k]
    out.update(totals)
    return out


def _ring_point(data: str) -> int:
    return int.from_bytes(hashlib.sha256(data.encode()).digest()[:8], "big")


class ShardedStorage(Storage):
    """Content-addressed store over N child shards with R-way replication."""

    scheme = "shard"

    def __init__(
        self,
        shards: list[Storage],
        replicas: int = 2,
        vnodes: int = VNODES,
    ) -> None:
        if not shards:
            raise ValueError("ShardedStorage needs at least one child shard")
        if replicas < 1:
            raise ValueError("replication factor must be >= 1")
        self.shards = list(shards)
        self.replicas = min(replicas, len(self.shards))
        # Consistent-hash ring: sorted (point, shard_index) pairs, VNODES
        # stable SHA-256 points per shard. Key placement = first R
        # distinct shards clockwise from the key's own point.
        points: list[tuple[int, int]] = []
        for i in range(len(self.shards)):
            for v in range(vnodes):
                points.append((_ring_point(f"shard-{i}-vnode-{v}"), i))
        points.sort()
        self._ring_points = [p for p, _ in points]
        self._ring_shards = [s for _, s in points]
        # Counter lock: guards the per-shard counter dicts and the
        # quarantine log only — never held across a child put/get (the
        # children take their own `storage` locks; see CONCURRENCY.md).
        self._seq = _register(self)
        self._stats_lock = make_lock(f"blobshard:{self._seq}")
        self._per_shard = [dict.fromkeys(_COUNTERS, 0) for _ in self.shards]
        self.quarantine_log: list[tuple[int, str]] = []  # (shard, key)

    # ------------------------------------------------------------- placement
    def replicas_for(self, key: str) -> list[int]:
        """The key's R distinct shard indices, in ring (preference) order."""
        pos = bisect.bisect(self._ring_points, int(key[:16] or "0", 16))
        out: list[int] = []
        n = len(self._ring_points)
        for step in range(n):
            idx = self._ring_shards[(pos + step) % n]
            if idx not in out:
                out.append(idx)
                if len(out) == self.replicas:
                    break
        return out

    @staticmethod
    def _key_of(url: str) -> str:
        return url.split("://", 1)[1] if "://" in url else url

    def _bump(self, shard: int, counter: str, delta: int = 1) -> None:
        with self._stats_lock:
            self._per_shard[shard][counter] += delta

    # ---------------------------------------------------------- child shards
    # Both wrappers pass through the compiled-in fault points BEFORE
    # touching the child, so an injected crash models a shard that never
    # saw the request (the FaultInjected raise is a ConnectionError —
    # transient, tolerated by put and rotated past by get).
    def _shard_put(self, shard: int, key: str, data: bytes) -> None:
        faults.hit("blob.put", shard=shard, key=key)
        self.shards[shard].put(data)
        self._bump(shard, "puts")
        self._bump(shard, "put_bytes", len(data))

    def _shard_get(self, shard: int, key: str) -> bytes:
        faults.hit("blob.get", shard=shard, key=key)
        child = self.shards[shard]
        data = child.get(f"{child.scheme}://{key}")
        self._bump(shard, "gets")
        self._bump(shard, "get_bytes", len(data))
        return data

    def _quarantine(self, shard: int, key: str) -> None:
        """Move a checksum-corrupt copy aside on the child (best effort:
        a shard too broken to quarantine is already effectively empty)."""
        try:
            self.shards[shard].quarantine(key)
        except (NotFoundError, NotImplementedError, *_TRANSIENT):
            pass
        with self._stats_lock:
            self._per_shard[shard]["quarantined"] += 1
            self.quarantine_log.append((shard, key))

    def _repair(self, shard: int, key: str, data: bytes) -> bool:
        """Rewrite one broken replica from healthy bytes (read-repair)."""
        try:
            self._shard_put(shard, key, data)
        except _TRANSIENT:
            self._bump(shard, "repair_failures")
            return False
        self._bump(shard, "repairs")
        return True

    # ------------------------------------------------------------- Storage
    def put(self, data: bytes) -> str:
        key = checksum(data)
        ok = 0
        last: Exception | None = None
        for shard in self.replicas_for(key):
            try:
                self._shard_put(shard, key, data)
                ok += 1
            except _TRANSIENT as e:
                self._bump(shard, "put_failures")
                last = e
        if ok == 0:
            raise TransportError(
                f"blob put {key[:12]}…: all {self.replicas} replicas failed"
            ) from last
        return f"shard://{key}"

    def get(self, url: str) -> bytes:
        key = self._key_of(url)
        broken: list[int] = []  # replicas observed missing/corrupt
        transient = False
        data: bytes | None = None
        for shard in self.replicas_for(key):
            try:
                candidate = self._shard_get(shard, key)
            except NotFoundError:
                self._bump(shard, "missing")
                broken.append(shard)
                continue
            except ConflictError:
                # The child's own content-address check tripped.
                self._bump(shard, "corrupt")
                self._quarantine(shard, key)
                broken.append(shard)
                continue
            except _TRANSIENT:
                self._bump(shard, "get_failures")
                transient = True
                continue
            if checksum(candidate) != key:  # child without its own check
                self._bump(shard, "corrupt")
                self._quarantine(shard, key)
                broken.append(shard)
                continue
            data = candidate
            break
        if data is None:
            if transient:
                raise TransportError(
                    f"blob get {key[:12]}…: no healthy replica reachable"
                )
            raise NotFoundError(f"blob shard://{key} not found on any replica")
        for shard in broken:
            self._repair(shard, key, data)
        return data

    # ----------------------------------------------------------- self-healing
    def keys(self) -> list[str]:
        """Union of keys across reachable shards (sorted)."""
        seen: set[str] = set()
        for i, child in enumerate(self.shards):
            try:
                seen.update(child.keys())
            except _TRANSIENT:
                self._bump(i, "get_failures")
        return sorted(seen)

    def scrub(self) -> dict:
        """Probe EVERY replica of every key and repair the broken ones.

        ``get`` only repairs replicas it visited before finding a healthy
        copy; a scrub closes the gap — run it after reviving a shard to
        restore full replication. Unreachable shards are skipped (their
        copies are neither declared broken nor repaired). Returns
        ``{"keys", "repaired", "lost"}`` where ``lost`` counts keys with
        no healthy replica anywhere.
        """
        repaired = lost = 0
        all_keys = self.keys()
        for key in all_keys:
            healthy: bytes | None = None
            broken: list[int] = []
            for shard in self.replicas_for(key):
                try:
                    candidate = self._shard_get(shard, key)
                except NotFoundError:
                    broken.append(shard)
                    continue
                except ConflictError:
                    self._bump(shard, "corrupt")
                    self._quarantine(shard, key)
                    broken.append(shard)
                    continue
                except _TRANSIENT:
                    self._bump(shard, "get_failures")
                    continue
                if checksum(candidate) != key:
                    self._bump(shard, "corrupt")
                    self._quarantine(shard, key)
                    broken.append(shard)
                    continue
                if healthy is None:
                    healthy = candidate
            if healthy is None:
                lost += 1
                continue
            for shard in broken:
                if self._repair(shard, key, healthy):
                    repaired += 1
        return {"keys": len(all_keys), "repaired": repaired, "lost": lost}

    def replica_count(self, key: str) -> int:
        """How many of the key's replicas currently hold healthy bytes."""
        n = 0
        for shard in self.replicas_for(key):
            try:
                if checksum(self._shard_get(shard, key)) == key:
                    n += 1
            except (NotFoundError, ConflictError, *_TRANSIENT):
                pass
        return n

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        with self._stats_lock:
            per_shard = {i: dict(c) for i, c in enumerate(self._per_shard)}
        totals = {k: sum(c[k] for c in per_shard.values()) for k in _COUNTERS}
        return {
            "shards": len(self.shards),
            "replicas": self.replicas,
            "per_shard": per_shard,
            **totals,
        }
