"""Error types for the ColonyOS core."""


class ColoniesError(Exception):
    """Base error for all colony operations."""

    status = 500


class AuthError(ColoniesError):
    """Signature invalid or identity not authorized for the operation."""

    status = 403


class NotFoundError(ColoniesError):
    """Referenced entity does not exist."""

    status = 404


class ConflictError(ColoniesError):
    """Write conflicted with the current state (e.g. double close)."""

    status = 409


class TimeoutError_(ColoniesError):
    """Long-poll assign expired without a matching process."""

    status = 408


class NotLeaderError(ColoniesError):
    """Synchronized request hit a follower replica; retry against leader."""

    status = 421

    def __init__(self, msg: str = "not leader", leader: str | None = None):
        super().__init__(msg)
        self.leader = leader


class ValidationError(ColoniesError):
    """Malformed function spec / workflow / request payload."""

    status = 400


class TransportError(ColoniesError):
    """Request never produced a server reply (refused/reset/timed out).

    The mutation may or may not have committed server-side — safe to
    retry only because mutating RPCs carry an idempotency key (msgid)."""

    status = 503
