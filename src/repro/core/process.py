"""Processes and the priority-time queue ordering (paper §3, Table 1, Eq. 1).

A process is the meta-information record of one computation: the function
spec plus execution context (state, assigned executor, retries, deadlines,
dataflow input/output, and DAG linkage).
"""

from __future__ import annotations

import json
import secrets
import time
from dataclasses import dataclass, field
from typing import Any

from .spec import FunctionSpec

# Process states (paper Fig. 2 / Table 1)
WAITING = "waiting"
RUNNING = "running"
SUCCESSFUL = "successful"
FAILED = "failed"

STATES = (WAITING, RUNNING, SUCCESSFUL, FAILED)

# Eq. (1): priority_time = submission_ns - priority * 1e9 * 60 * 60 * 24
# i.e. each priority level buys a full day of virtual queue seniority.
PRIORITY_NS_PER_LEVEL = 10**9 * 60 * 60 * 24


def priority_time(submission_ns: int, priority: int) -> int:
    """Paper Eq. (1) for a nanosecond timestamp."""
    return submission_ns - priority * PRIORITY_NS_PER_LEVEL


def new_id() -> str:
    return secrets.token_hex(32)


def now_ns() -> int:
    return time.time_ns()


@dataclass
class Process:
    processid: str = field(default_factory=new_id)
    colonyname: str = ""
    spec: FunctionSpec = field(default_factory=FunctionSpec)
    state: str = WAITING
    assignedexecutorid: str = ""
    isassigned: bool = False
    wait_for_parents: bool = False
    submissiontime_ns: int = 0
    starttime_ns: int = 0
    endtime_ns: int = 0
    deadline_ns: int = 0  # maxexectime deadline; 0 = none
    waitdeadline_ns: int = 0  # maxwaittime deadline; 0 = none
    retries: int = 0
    priority_time: int = 0
    # Dataflow (paper Table 4)
    inputs: list[Any] = field(default_factory=list)
    output: list[Any] = field(default_factory=list)
    errors: list[str] = field(default_factory=list)
    # DAG linkage (paper Table 3)
    workflowid: str = ""
    parents: list[str] = field(default_factory=list)  # parent process ids
    children: list[str] = field(default_factory=list)  # child process ids

    @staticmethod
    def create(spec: FunctionSpec, submission_ns: int | None = None) -> "Process":
        ts = now_ns() if submission_ns is None else submission_ns
        p = Process(
            colonyname=spec.conditions.colonyname,
            spec=spec,
            submissiontime_ns=ts,
            priority_time=priority_time(ts, spec.priority),
        )
        if spec.maxwaittime and spec.maxwaittime > 0:
            p.waitdeadline_ns = ts + spec.maxwaittime * 10**9
        return p

    @property
    def queue_ready(self) -> bool:
        """True iff this process may occupy a ready queue (assignable)."""
        return self.state == WAITING and not self.wait_for_parents

    def to_dict(self) -> dict:
        return {
            "processid": self.processid,
            "colonyname": self.colonyname,
            "spec": self.spec.to_dict(),
            "state": self.state,
            "assignedexecutorid": self.assignedexecutorid,
            "isassigned": self.isassigned,
            "waitforparents": self.wait_for_parents,
            "submissiontime": self.submissiontime_ns,
            "starttime": self.starttime_ns,
            "endtime": self.endtime_ns,
            "deadline": self.deadline_ns,
            "waitdeadline": self.waitdeadline_ns,
            "retries": self.retries,
            "prioritytime": self.priority_time,
            "in": list(self.inputs),
            "out": list(self.output),
            "errors": list(self.errors),
            "workflowid": self.workflowid,
            "parents": list(self.parents),
            "children": list(self.children),
        }

    @staticmethod
    def from_dict(d: dict) -> "Process":
        return Process(
            processid=d["processid"],
            colonyname=d.get("colonyname", ""),
            spec=FunctionSpec.from_dict(d.get("spec", {})),
            state=d.get("state", WAITING),
            assignedexecutorid=d.get("assignedexecutorid", ""),
            isassigned=bool(d.get("isassigned", False)),
            wait_for_parents=bool(d.get("waitforparents", False)),
            submissiontime_ns=int(d.get("submissiontime", 0)),
            starttime_ns=int(d.get("starttime", 0)),
            endtime_ns=int(d.get("endtime", 0)),
            deadline_ns=int(d.get("deadline", 0)),
            waitdeadline_ns=int(d.get("waitdeadline", 0)),
            retries=int(d.get("retries", 0)),
            priority_time=int(d.get("prioritytime", 0)),
            inputs=list(d.get("in", []) or []),
            output=list(d.get("out", []) or []),
            errors=list(d.get("errors", []) or []),
            workflowid=d.get("workflowid", ""),
            parents=list(d.get("parents", []) or []),
            children=list(d.get("children", []) or []),
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "Process":
        return Process.from_dict(json.loads(s))


@dataclass
class Executor:
    """A registered colony member (paper Table 5)."""

    executorid: str = ""
    executorname: str = ""
    executortype: str = ""
    colonyname: str = ""
    state: str = "pending"  # pending -> approved | rejected
    commissiontime_ns: int = field(default_factory=now_ns)
    lastheardfrom_ns: int = 0
    capabilities: dict[str, Any] = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {
            "executorid": self.executorid,
            "executorname": self.executorname,
            "executortype": self.executortype,
            "colonyname": self.colonyname,
            "state": self.state,
            "commissiontime": self.commissiontime_ns,
            "lastheardfrom": self.lastheardfrom_ns,
            "capabilities": dict(self.capabilities),
        }

    @staticmethod
    def from_dict(d: dict) -> "Executor":
        return Executor(
            executorid=d.get("executorid", ""),
            executorname=d.get("executorname", ""),
            executortype=d.get("executortype", ""),
            colonyname=d.get("colonyname", d.get("colonyid", "")),
            state=d.get("state", "pending"),
            commissiontime_ns=int(d.get("commissiontime", 0)),
            lastheardfrom_ns=int(d.get("lastheardfrom", 0)),
            capabilities=dict(d.get("capabilities", {}) or {}),
        )


@dataclass
class Colony:
    colonyname: str = ""
    colonyid: str = ""  # identity (SHA3 of colony owner pubkey)

    def to_dict(self) -> dict:
        return {"colonyname": self.colonyname, "colonyid": self.colonyid}

    @staticmethod
    def from_dict(d: dict) -> "Colony":
        return Colony(colonyname=d.get("colonyname", ""), colonyid=d.get("colonyid", ""))
