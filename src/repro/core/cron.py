"""Cron — stateless time-based workflow triggering (paper §3.4.3).

Two-step leader protocol, exactly as the paper describes:
  1. the elected leader computes a *future deadline* for each cron entry
     and stores it in the cron table;
  2. the leader periodically scans the table; when ``now > deadline`` it
     submits the workflow and writes the next deadline.

No session state lives in memory between scans, so leader failover simply
resumes scanning from the table.

Supports plain intervals and 5-field cron expressions
(minute hour day-of-month month day-of-week; ``*``, ``*/n``, lists, ranges).
"""

from __future__ import annotations

import secrets
import time
from typing import Any, Callable

from .database import Database
from .errors import NotFoundError, ValidationError
from .process import now_ns
from .spec import WorkflowSpec


# ---------------------------------------------------------------------------
# Tiny 5-field cron expression parser
# ---------------------------------------------------------------------------


def _parse_field(expr: str, lo: int, hi: int) -> set[int]:
    out: set[int] = set()
    for part in expr.split(","):
        orig = part
        step = 1
        if "/" in part:
            part, step_s = part.split("/", 1)
            step = int(step_s)
            if step <= 0:
                raise ValidationError(f"cron step must be positive in {expr!r}")
        if part in ("*", ""):
            start, stop = lo, hi
        elif "-" in part:
            a, b = part.split("-", 1)
            start, stop = int(a), int(b)
        else:
            # Vixie expands a lone number before '/' to an N-to-max range:
            # '5/15' in the minute field is {5, 20, 35, 50}, not {5}.
            start = int(part)
            stop = hi if "/" in orig else start
        # Steps anchor at the range start (standard cron): 11-20/5 is
        # {11, 16}, not the field-minimum-anchored {15, 20}.
        for v in range(start, stop + 1, step):
            if lo <= v <= hi:
                out.add(v)
    if not out:
        raise ValidationError(f"empty cron field {expr!r}")
    return out


def next_cron_deadline_ns(cronexpr: str, after_ns: int) -> int:
    """Next matching minute boundary strictly after ``after_ns``."""
    fields = cronexpr.split()
    if len(fields) != 5:
        raise ValidationError("cron expression must have 5 fields")
    minutes = _parse_field(fields[0], 0, 59)
    hours = _parse_field(fields[1], 0, 23)
    doms = _parse_field(fields[2], 1, 31)
    months = _parse_field(fields[3], 1, 12)
    # Standard cron day-of-week: 0 = Sunday, with 7 accepted as Sunday too.
    # Python's tm_wday is 0 = Monday, so translate at match time.
    dows = {d % 7 for d in _parse_field(fields[4], 0, 7)}
    # Vixie-cron day rule: if BOTH day fields are restricted, a day matches
    # when EITHER does ('0 0 13 * 5' = every 13th and every Friday, not
    # just Friday-the-13th); otherwise the restricted one decides. Like
    # Vixie's DOM_STAR/DOW_STAR, a '*'-prefixed field ('*/2') counts as a
    # star field even though it constrains the match.
    dom_any = fields[2].strip().startswith("*")
    dow_any = fields[4].strip().startswith("*")
    t = (after_ns // (60 * 10**9) + 1) * 60  # next minute boundary, seconds
    for _ in range(366 * 24 * 60):  # bounded search: one year of minutes
        st = time.localtime(t)
        dom_ok = st.tm_mday in doms
        dow_ok = (st.tm_wday + 1) % 7 in dows
        day_ok = (dom_ok or dow_ok) if not dom_any and not dow_any else (
            dom_ok and dow_ok
        )
        if (
            st.tm_min in minutes
            and st.tm_hour in hours
            and st.tm_mon in months
            and day_ok
        ):
            return t * 10**9
        t += 60
    raise ValidationError(f"cron expression {cronexpr!r} never fires")


# ---------------------------------------------------------------------------
# Server extension
# ---------------------------------------------------------------------------


class CronExtension:
    """Leader-scanned cron table; registered on a ColoniesServer."""

    def __init__(self, server) -> None:
        self.server = server
        self.db: Database = server.db
        server.extensions.append(self)
        self.triggered = 0  # observability for tests/benchmarks

    def handlers(self) -> dict[str, Callable[[str, dict], Any]]:
        return {
            "addcron": self._h_add_cron,
            "getcrons": self._h_get_crons,
            "removecron": self._h_remove_cron,
            "runcron": self._h_run_cron,
        }

    def _h_add_cron(self, identity: str, payload: dict) -> dict:
        c = payload["cron"]
        colony = c.get("colonyname", "")
        self.server._require_member(identity, colony)
        wf = WorkflowSpec.from_dict(c.get("workflow", {}))
        if not wf.specs:
            raise ValidationError("cron needs a workflow")
        for s in wf.specs:
            s.conditions.colonyname = s.conditions.colonyname or colony
        wf.colonyname = colony
        wf.validate()
        interval = float(c.get("interval", 0))
        cronexpr = c.get("cronexpr", "")
        if interval <= 0 and not cronexpr:
            raise ValidationError("cron needs interval > 0 or a cronexpr")
        ts = now_ns()
        entry = {
            "cronid": secrets.token_hex(16),
            "colonyname": colony,
            "name": c.get("name", ""),
            "interval": interval,
            "cronexpr": cronexpr,
            "workflow": wf.to_dict(),
            # Step 1 of the two-step protocol: the future deadline.
            "deadline": self._next_deadline(interval, cronexpr, ts),
            "lastrun": 0,
            "runs": 0,
            "lastworkflowid": "",
            "added": ts,
        }
        self.db.cron_put(entry)
        return entry

    @staticmethod
    def _next_deadline(interval: float, cronexpr: str, after_ns: int) -> int:
        if cronexpr:
            return next_cron_deadline_ns(cronexpr, after_ns)
        return after_ns + int(interval * 1e9)

    def _h_get_crons(self, identity: str, payload: dict) -> list[dict]:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        return self.db.cron_list(colony)

    def _h_remove_cron(self, identity: str, payload: dict) -> dict:
        cronid = payload["cronid"]
        entry = self.db.cron_get(cronid)
        if entry is None:
            raise NotFoundError("cron not found")
        self.server._require_member(identity, entry["colonyname"])
        self.db.cron_del(cronid)
        return {"cronid": cronid, "removed": True}

    def _h_run_cron(self, identity: str, payload: dict) -> dict:
        """Force-fire a cron now (CLI convenience)."""
        cronid = payload["cronid"]
        entry = self.db.cron_get(cronid)
        if entry is None:
            raise NotFoundError("cron not found")
        self.server._require_member(identity, entry["colonyname"])
        return self._fire(entry, now_ns())

    # -- leader scan (step 2) -------------------------------------------------
    def tick(self) -> int:
        """Fire everything past deadline via the deadline index. Leader-only.

        ``cron_due`` reads the database's deadline index (a heap in memdb,
        a B-tree range scan in sqlite), so the 250 ms leader tick does
        O(due) work instead of scanning every colony's crons.
        """
        ts = now_ns()
        fired = 0
        for entry in self.db.cron_due(ts):
            self._fire(entry, ts)
            fired += 1
        return fired

    def _fire(self, entry: dict, ts: int) -> dict:
        wf = WorkflowSpec.from_dict(entry["workflow"])
        procs = self.server.submit_workflow_processes(wf)
        entry = dict(entry)
        entry["deadline"] = self._next_deadline(entry["interval"], entry["cronexpr"], ts)
        entry["lastrun"] = ts
        entry["runs"] = entry.get("runs", 0) + 1
        entry["lastworkflowid"] = procs[0].workflowid
        self.db.cron_put(entry)
        self.server._notify_queue()
        self.triggered += 1
        return entry
