"""Raft consensus (paper §3.4.1, after Ongaro & Ousterhout).

The paper uses Raft to elect a single Colonies server replica as leader:
only the leader serves ``assign`` (the one synchronized request) and runs
the cron/generator scanners. We implement a compact but real Raft —
randomized election timeouts, RequestVote/AppendEntries, log replication,
majority commit — over an abstract message-passing network so tests can
drive it deterministically (virtual clock, message drops, partitions)
and the HA cluster can drive it in real time.

Entries are opaque dicts; on commit every node invokes ``apply_fn(entry,
index)``. The cluster layer registers an idempotent apply (shared-DB
deployment, as in the paper's shared-Postgres architecture), so replay
on leader change is safe.
"""

from __future__ import annotations

import random
import threading
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable

from ..analysis import statehash
from ..analysis.contracts import no_locks_held
from ..analysis.locktrack import allow_wait, make_lock
from ..runtime import faults

# propose_and_wait parks on a node's commit_cv (built on the raft lock)
# while the HA assign path still holds the leader-local assignlocal lock
# — the one hold its contract permits. Deadlock-free: commit_cv is
# notified from the event-loop thread (_apply_committed / _step_down),
# which never acquires assignlocal; the parked hold only serializes
# same-colony assigns, which is assignlocal's whole job.
allow_wait("raft", "assignlocal")


def _node_seed(node_id: str) -> int:
    """Deterministic per-node RNG seed. ``hash(str)`` is salted per
    process (PYTHONHASHSEED), so two identically-configured runs would
    draw different election jitter; CRC32 is stable everywhere."""
    return zlib.crc32(node_id.encode("utf-8"))

FOLLOWER = "follower"
CANDIDATE = "candidate"
LEADER = "leader"


@dataclass
class LogEntry:
    term: int
    entry: dict

    def to_dict(self) -> dict:
        return {"term": self.term, "entry": self.entry}


@dataclass
class Msg:
    src: str
    dst: str
    kind: str  # request_vote | vote_reply | append_entries | append_reply
    body: dict = field(default_factory=dict)


class RaftNode:
    def __init__(
        self,
        node_id: str,
        peers: list[str],
        send: Callable[[Msg], None],
        apply_fn: Callable[[dict, int], None] | None = None,
        rng: random.Random | None = None,
        election_timeout_ms: tuple[int, int] = (150, 300),
        heartbeat_ms: int = 50,
    ) -> None:
        self.id = node_id
        self.peers = [p for p in peers if p != node_id]
        self._send = send
        self.apply_fn = apply_fn or (lambda e, i: None)
        self.rng = rng or random.Random(_node_seed(node_id))
        self.election_timeout_ms = election_timeout_ms
        self.heartbeat_ms = heartbeat_ms

        # Persistent state
        self.current_term = 0
        self.voted_for: str | None = None
        self.log: list[LogEntry] = []

        # Volatile state
        self.state = FOLLOWER
        self.commit_index = -1
        self.last_applied = -1
        self.leader_hint: str | None = None
        self.next_index: dict[str, int] = {}
        self.match_index: dict[str, int] = {}
        self._votes: set[str] = set()
        self._last_heard_ms = 0
        self._last_heartbeat_ms = 0
        self._timeout_ms = self._new_timeout()
        self._peer_contact_ms: dict[str, int] = {}
        self.lock = make_lock(f"raft:{node_id}")
        # Notified on every commit apply and on step-down, so a
        # propose_and_wait parks here instead of polling (see
        # ThreadedRaftCluster.propose_and_wait).
        self.commit_cv = threading.Condition(self.lock)

    # ------------------------------------------------------------------ util
    def _new_timeout(self) -> int:
        lo, hi = self.election_timeout_ms
        return self.rng.randint(lo, hi)

    def last_log_index(self) -> int:
        return len(self.log) - 1

    def last_log_term(self) -> int:
        return self.log[-1].term if self.log else 0

    def is_leader(self) -> bool:
        with self.lock:
            return self.state == LEADER

    # ------------------------------------------------------------------ time
    def tick(self, now_ms: int) -> None:
        with self.lock:
            if self.state == LEADER:
                # Check-quorum: a partitioned leader that cannot reach a
                # majority steps down, so stale leaders never serve assigns.
                if self.peers:
                    window = 2 * self.election_timeout_ms[1]
                    heard = 1 + sum(
                        1
                        for p in self.peers
                        if now_ms - self._peer_contact_ms.get(p, now_ms) <= window
                    )
                    if heard * 2 <= len(self.peers) + 1:
                        self._step_down(self.current_term)
                        self._last_heard_ms = now_ms
                        return
                if now_ms - self._last_heartbeat_ms >= self.heartbeat_ms:
                    self._broadcast_append(now_ms)
            else:
                if now_ms - self._last_heard_ms >= self._timeout_ms:
                    self._start_election(now_ms)

    def _start_election(self, now_ms: int) -> None:
        self.state = CANDIDATE
        self.current_term += 1
        self.voted_for = self.id
        self._votes = {self.id}
        self._last_heard_ms = now_ms
        self._timeout_ms = self._new_timeout()
        for p in self.peers:
            self._send(
                Msg(
                    self.id,
                    p,
                    "request_vote",
                    {
                        "term": self.current_term,
                        "candidate": self.id,
                        "last_log_index": self.last_log_index(),
                        "last_log_term": self.last_log_term(),
                    },
                )
            )
        self._maybe_win()  # single-node cluster wins immediately

    def _become_leader(self, now_ms: int = 0) -> None:
        self.state = LEADER
        self.leader_hint = self.id
        self.next_index = {p: len(self.log) for p in self.peers}
        self.match_index = {p: -1 for p in self.peers}
        self._peer_contact_ms = {p: now_ms for p in self.peers}
        self._last_heartbeat_ms = -(10**9)  # heartbeat immediately

    def _step_down(self, term: int) -> None:
        self.current_term = term
        self.state = FOLLOWER
        self.voted_for = None
        self._votes = set()
        self._timeout_ms = self._new_timeout()
        # Wake proposers parked on the commit condition: their entry can
        # no longer commit through this node (lost-leadership recheck).
        self.commit_cv.notify_all()

    # -------------------------------------------------------------- messages
    def receive(self, msg: Msg, now_ms: int) -> None:
        with self.lock:
            kind, b = msg.kind, msg.body
            self._peer_contact_ms[msg.src] = now_ms
            if b.get("term", 0) > self.current_term:
                self._step_down(b["term"])
            if kind == "request_vote":
                self._on_request_vote(msg, now_ms)
            elif kind == "vote_reply":
                self._on_vote_reply(msg, now_ms)
            elif kind == "append_entries":
                self._on_append_entries(msg, now_ms)
            elif kind == "append_reply":
                self._on_append_reply(msg, now_ms)

    def _on_request_vote(self, msg: Msg, now_ms: int) -> None:
        b = msg.body
        grant = False
        if b["term"] >= self.current_term:
            log_ok = b["last_log_term"] > self.last_log_term() or (
                b["last_log_term"] == self.last_log_term()
                and b["last_log_index"] >= self.last_log_index()
            )
            if log_ok and self.voted_for in (None, b["candidate"]):
                grant = True
                self.voted_for = b["candidate"]
                self._last_heard_ms = now_ms
        self._send(
            Msg(
                self.id,
                msg.src,
                "vote_reply",
                {"term": self.current_term, "granted": grant},
            )
        )

    def _on_vote_reply(self, msg: Msg, now_ms: int) -> None:
        b = msg.body
        if self.state != CANDIDATE or b["term"] != self.current_term:
            return
        if b["granted"]:
            self._votes.add(msg.src)
            self._maybe_win(now_ms)

    def _maybe_win(self, now_ms: int = 0) -> None:
        if self.state == CANDIDATE and len(self._votes) * 2 > len(self.peers) + 1:
            self._become_leader(now_ms)

    def _on_append_entries(self, msg: Msg, now_ms: int) -> None:
        b = msg.body
        if b["term"] < self.current_term:
            self._send(
                Msg(
                    self.id,
                    msg.src,
                    "append_reply",
                    {"term": self.current_term, "success": False, "match_index": -1},
                )
            )
            return
        # Valid leader for this term.
        self.state = FOLLOWER
        self.leader_hint = msg.src
        self._last_heard_ms = now_ms
        self._timeout_ms = self._new_timeout()
        prev_i, prev_t = b["prev_index"], b["prev_term"]
        ok = prev_i == -1 or (
            prev_i < len(self.log) and self.log[prev_i].term == prev_t
        )
        if not ok:
            self._send(
                Msg(
                    self.id,
                    msg.src,
                    "append_reply",
                    {"term": self.current_term, "success": False, "match_index": -1},
                )
            )
            return
        # Append / overwrite conflicting suffix (Raft log matching).
        idx = prev_i + 1
        for e in b["entries"]:
            entry = LogEntry(term=e["term"], entry=e["entry"])
            if idx < len(self.log):
                if self.log[idx].term != entry.term:
                    del self.log[idx:]
                    self.log.append(entry)
            else:
                self.log.append(entry)
            idx += 1
        if b["leader_commit"] > self.commit_index:
            self.commit_index = min(b["leader_commit"], len(self.log) - 1)
            self._apply_committed()
        self._send(
            Msg(
                self.id,
                msg.src,
                "append_reply",
                {
                    "term": self.current_term,
                    "success": True,
                    "match_index": prev_i + len(b["entries"]),
                },
            )
        )

    def _on_append_reply(self, msg: Msg, now_ms: int) -> None:
        b = msg.body
        if self.state != LEADER or b["term"] != self.current_term:
            return
        if b["success"]:
            self.match_index[msg.src] = max(
                self.match_index.get(msg.src, -1), b["match_index"]
            )
            self.next_index[msg.src] = self.match_index[msg.src] + 1
            self._advance_commit()
        else:
            self.next_index[msg.src] = max(0, self.next_index.get(msg.src, 0) - 1)

    def _advance_commit(self) -> None:
        # Majority-replicated entries from the current term become committed.
        for n in range(len(self.log) - 1, self.commit_index, -1):
            if self.log[n].term != self.current_term:
                continue
            count = 1 + sum(1 for p in self.peers if self.match_index.get(p, -1) >= n)
            if count * 2 > len(self.peers) + 1:
                self.commit_index = n
                self._apply_committed()
                break

    def _apply_committed(self) -> None:
        applied = False
        while self.last_applied < self.commit_index:
            self.last_applied += 1
            self.apply_fn(self.log[self.last_applied].entry, self.last_applied)
            applied = True
        if applied:
            self.commit_cv.notify_all()

    def _broadcast_append(self, now_ms: int) -> None:
        self._last_heartbeat_ms = now_ms
        for p in self.peers:
            ni = self.next_index.get(p, len(self.log))
            prev_i = ni - 1
            prev_t = self.log[prev_i].term if prev_i >= 0 else 0
            entries = [e.to_dict() for e in self.log[ni : ni + 64]]
            self._send(
                Msg(
                    self.id,
                    p,
                    "append_entries",
                    {
                        "term": self.current_term,
                        "prev_index": prev_i,
                        "prev_term": prev_t,
                        "entries": entries,
                        "leader_commit": self.commit_index,
                    },
                )
            )

    # --------------------------------------------------------------- propose
    def propose(self, entry: dict) -> int | None:
        """Append an entry to the leader log; returns its index or None."""
        with self.lock:
            if self.state != LEADER:
                return None
            self.log.append(LogEntry(term=self.current_term, entry=entry))
            idx = len(self.log) - 1
            if not self.peers:  # single-node: commit immediately
                self.commit_index = idx
                self._apply_committed()
            else:
                self._broadcast_append(self._last_heartbeat_ms)
            return idx


# ---------------------------------------------------------------------------
# Simulated network + cluster drivers
# ---------------------------------------------------------------------------


class SimNetwork:
    """Deterministic message bus with drop probability and partitions."""

    def __init__(self, rng: random.Random | None = None) -> None:
        self.rng = rng or random.Random(0)
        self.queue: list[Msg] = []
        self.drop_prob = 0.0
        self.partitions: set[frozenset[str]] = set()  # unreachable pairs
        self.delivered = 0
        self.dropped = 0

    def send(self, msg: Msg) -> None:
        self.queue.append(msg)

    def partition(self, a: str, b: str) -> None:
        self.partitions.add(frozenset((a, b)))

    def heal(self) -> None:
        self.partitions.clear()

    def _blocked(self, msg: Msg) -> bool:
        return frozenset((msg.src, msg.dst)) in self.partitions

    def pump(self, nodes: dict[str, RaftNode], now_ms: int) -> int:
        """Deliver all queued messages (dropping per policy)."""
        n = 0
        msgs, self.queue = self.queue, []
        for m in msgs:
            if self._blocked(m) or self.rng.random() < self.drop_prob:
                self.dropped += 1
                continue
            node = nodes.get(m.dst)
            if node is not None:
                node.receive(m, now_ms)
                self.delivered += 1
                n += 1
        return n


class SimRaftCluster:
    """Virtual-clock cluster for deterministic tests."""

    def __init__(
        self,
        n: int,
        apply_fn: Callable[[str, dict, int], None] | None = None,
        seed: int = 0,
    ) -> None:
        self.rng = random.Random(seed)
        self.net = SimNetwork(random.Random(seed + 1))
        ids = [f"n{i}" for i in range(n)]
        self.nodes: dict[str, RaftNode] = {}
        for nid in ids:
            fn = (lambda nid_: lambda e, i: apply_fn and apply_fn(nid_, e, i))(nid)
            self.nodes[nid] = RaftNode(
                nid,
                ids,
                self.net.send,
                fn,
                rng=random.Random(seed * 100003 + _node_seed(nid)),
            )
        self.now_ms = 0

    def step(self, ms: int = 10) -> None:
        self.now_ms += ms
        for node in self.nodes.values():
            node.tick(self.now_ms)
        # Pump until quiescent this tick (bounded).
        for _ in range(8):
            if self.net.pump(self.nodes, self.now_ms) == 0:
                break

    def run_until_leader(self, max_ms: int = 10_000) -> str | None:
        start = self.now_ms
        while self.now_ms - start < max_ms:
            self.step()
            leaders = self.leaders()
            if leaders:
                return leaders[0]
        return None

    def leaders(self) -> list[str]:
        return [nid for nid, n in self.nodes.items() if n.is_leader()]

    def leaders_of_term(self) -> dict[int, list[str]]:
        out: dict[int, list[str]] = {}
        for nid, n in self.nodes.items():
            if n.is_leader():
                out.setdefault(n.current_term, []).append(nid)
        return out

    def kill(self, nid: str) -> None:
        for other in self.nodes:
            if other != nid:
                self.net.partition(nid, other)

    def revive(self, nid: str) -> None:
        self.net.partitions = {
            p for p in self.net.partitions if nid not in p
        }


class ThreadedRaftCluster:
    """Real-time driver: one event-loop thread ticks all nodes + delivers."""

    def __init__(
        self,
        n: int,
        apply_fn: Callable[[str, dict, int], Any] | None = None,
        seed: int = 0,
        tick_ms: int = 10,
    ) -> None:
        # Under REPRO_REPL_CHECK=1 every apply is journaled as
        # (index, chained digest) per node and cross-checked — the first
        # index at which replicas disagree records a
        # ReplicationDivergenceError (re-raised by propose_and_wait and
        # check_divergence). apply_fn may return an effect digest
        # (HAColonyCluster._apply does); it is folded into the chain.
        self.journal: statehash.ClusterJournal | None = None
        if statehash.is_enabled() and apply_fn is not None:
            self.journal = statehash.ClusterJournal()
            inner = apply_fn

            def journaled(nid: str, entry: dict, index: int) -> Any:
                effect = inner(nid, entry, index)
                self.journal.record(
                    nid, index, entry, effect if isinstance(effect, str) else None
                )
                return effect

            apply_fn = journaled
        self.sim = SimRaftCluster(n, apply_fn, seed)
        self.tick_ms = tick_ms
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._lock = make_lock("cluster")

    @property
    def nodes(self) -> dict[str, RaftNode]:
        return self.sim.nodes

    def start(self) -> None:
        def loop() -> None:
            while not self._stop.wait(self.tick_ms / 1000.0):
                try:
                    # Chaos hook: a raised fault skips this tick, a delay
                    # stalls the event loop (election churn under soak).
                    faults.hit("raft.tick")
                except faults.FaultInjected:
                    continue
                with self._lock:
                    self.sim.step(self.tick_ms)

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)

    @no_locks_held("shard", "cfs", "glock", "dbcolony", "sqlite")
    def propose_and_wait(self, nid: str, entry: dict, timeout: float = 5.0) -> int:
        """Propose on node nid; block until that node has applied the entry.

        The waiter parks on the node's ``commit_cv`` — notified from
        ``_apply_committed`` after each batch of applies and from
        ``_step_down`` when leadership is lost — so commit latency is one
        notification away instead of a ``tick_ms/2`` polling round-trip.

        Contract: never entered holding a database lock — the commit is
        applied on the event-loop thread, which needs those same locks
        (the PR-1 deadlock). The leader-local ``assignlocal`` lock is the
        one lock legitimately held across this wait.
        """
        import time as _time

        node = self.nodes[nid]
        with self._lock:
            idx = node.propose(entry)
        if idx is None:
            from .errors import NotLeaderError

            raise NotLeaderError("propose on non-leader", leader=node.leader_hint)
        deadline = _time.time() + timeout
        with node.commit_cv:
            while node.last_applied < idx:
                if node.state != LEADER:
                    from .errors import NotLeaderError

                    raise NotLeaderError("lost leadership before commit")
                remaining = deadline - _time.time()
                if remaining <= 0:
                    from .errors import TimeoutError_

                    raise TimeoutError_("raft commit timeout")
                # Bounded wait as a belt-and-braces recheck; the CV is
                # notified on both commit and step-down, so this timeout
                # almost never expires.
                node.commit_cv.wait(timeout=min(remaining, 0.25))
        self.check_divergence()
        return idx

    def check_divergence(self) -> None:
        """Raise the first recorded replica divergence (REPRO_REPL_CHECK)."""
        if self.journal is not None:
            self.journal.check()

    def leader_id(self) -> str | None:
        with self._lock:
            ls = self.sim.leaders()
        return ls[0] if ls else None

    def kill(self, nid: str) -> None:
        with self._lock:
            self.sim.kill(nid)

    def revive(self, nid: str) -> None:
        with self._lock:
            self.sim.revive(nid)
