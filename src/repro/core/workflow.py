"""Workflow DAG expansion (paper §3.4.2, Tables 3–4, Fig. 4).

A workflow is *stateless*: submission expands every node into an ordinary
process-table row; ordering is enforced purely by the ``wait_for_parents``
flag which the ``close`` handler clears when all parents have finished.
"""

from __future__ import annotations

import secrets

from .process import Process
from .spec import WorkflowSpec


def expand_workflow(wf: WorkflowSpec) -> list[Process]:
    """One process per node; parent/child ids wired from nodename deps."""
    workflowid = secrets.token_hex(16)
    by_name: dict[str, Process] = {}
    procs: list[Process] = []
    ts_base = None
    for spec in wf.specs:
        p = Process.create(spec)
        if ts_base is None:
            ts_base = p.submissiontime_ns
        p.workflowid = workflowid
        by_name[spec.nodename] = p
        procs.append(p)
    for spec in wf.specs:
        p = by_name[spec.nodename]
        for dep in spec.conditions.dependencies:
            parent = by_name[dep]
            p.parents.append(parent.processid)
            parent.children.append(p.processid)
        p.wait_for_parents = len(p.parents) > 0
    return procs


def workflow_state(procs: list[Process]) -> str:
    """Aggregate state of a workflow's processes."""
    states = {p.state for p in procs}
    if not states:  # vacuously complete, not forever "waiting"
        return "successful"
    if "failed" in states:
        return "failed"
    if states == {"successful"}:
        return "successful"
    if "running" in states:
        return "running"
    return "waiting"
