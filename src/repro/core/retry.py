"""Retry policy for RPC transports (ROBUSTNESS.md).

Capped exponential backoff with decorrelated jitter (each delay is drawn
from ``uniform(base, prev * 3)`` and capped), bounded by both a retry
budget (max attempts) and an overall wall-clock deadline. Retries are
safe because every mutating RPC carries an idempotency key (``msgid``,
see idempotency.py): at-least-once delivery + server-side dedup =
exactly-once effect.

Only *transport-level* failures are retried — status 503 (connection
refused/reset/timed out, surfaced by the transports as a synthetic error
dict) and 421 (follower replica; the transports already rotate hosts
within a pass, the policy retries the whole pass so a mid-election
cluster converges). Application errors (400/403/404/408/409) mean the
server heard us and answered; retrying those is the caller's business.
"""

from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Callable

RETRYABLE_STATUSES = frozenset({503, 421})


@dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff + decorrelated jitter.

    ``budget`` counts total send attempts (1 = no retries). ``deadline_s``
    bounds the whole operation including sleeps; whichever of budget or
    deadline trips first ends the retry loop and the last error is
    returned to the caller. ``seed`` pins the jitter RNG for
    deterministic tests (None = nondeterministic, fine in production).
    """

    base_s: float = 0.02
    cap_s: float = 1.0
    deadline_s: float = 30.0
    budget: int = 8
    seed: int | None = None

    def delays(self) -> "_DelayIter":
        return _DelayIter(self)


class _DelayIter:
    """Stateful decorrelated-jitter delay sequence (AWS architecture blog)."""

    def __init__(self, policy: RetryPolicy) -> None:
        self.policy = policy
        self.rng = random.Random(policy.seed)
        self._prev = policy.base_s

    def next_delay(self) -> float:
        self._prev = min(self.policy.cap_s, self.rng.uniform(self.policy.base_s, self._prev * 3))
        return self._prev


def send_with_retry(attempt: Callable[[], dict], policy: RetryPolicy | None) -> dict:
    """Drive ``attempt`` (one full transport pass) under ``policy``.

    ``attempt`` returns the protocol reply dict; it is retried while the
    reply is an error with a status in RETRYABLE_STATUSES, until the
    budget or deadline runs out. The last reply (success or error) is
    returned — raising is the SDK layer's job.
    """
    if policy is None:
        return attempt()
    deadline = time.monotonic() + policy.deadline_s
    delays = policy.delays()
    resp: dict = {"error": "retry budget is zero", "status": 503}
    for i in range(max(1, policy.budget)):
        resp = attempt()
        if "error" not in resp or int(resp.get("status", 500)) not in RETRYABLE_STATUSES:
            return resp
        if i + 1 >= max(1, policy.budget):
            break
        delay = delays.next_delay()
        if time.monotonic() + delay >= deadline:
            break
        time.sleep(delay)
    return resp
