"""Generators — threshold-triggered workflow templates (paper §3.4.4).

Third-party systems integrate fire-and-forget: they send ``pack`` requests
carrying one input datum each. ``pack`` only *appends* to the generator's
arg bucket (no state manipulation → no synchronization, any replica can
serve it, exactly the paper's argument). The elected leader scans the
generator table and, when ``queuesize`` args have accumulated — or
``timeout`` elapsed since the first pending arg — drains the bucket and
submits the template workflow with the packed args attached.

This is also how the serving stack implements **dynamic batching**:
each inference request is a pack; the generator emits one batched
inference workflow per ``queuesize`` requests (serve/batcher.py).
"""

from __future__ import annotations

import secrets
from typing import Any, Callable

from .database import Database
from .errors import NotFoundError, ValidationError
from .process import now_ns
from .spec import WorkflowSpec

PACKS_TABLE = "generator_packs"


class GeneratorExtension:
    def __init__(self, server) -> None:
        self.server = server
        self.db: Database = server.db
        server.extensions.append(self)
        self.triggered = 0

    def handlers(self) -> dict[str, Callable[[str, dict], Any]]:
        return {
            "addgenerator": self._h_add_generator,
            "getgenerators": self._h_get_generators,
            "removegenerator": self._h_remove_generator,
            "pack": self._h_pack,
        }

    def _h_add_generator(self, identity: str, payload: dict) -> dict:
        g = payload["generator"]
        colony = g.get("colonyname", "")
        self.server._require_member(identity, colony)
        wf = WorkflowSpec.from_dict(g.get("workflow", {}))
        if not wf.specs:
            raise ValidationError("generator needs a workflow template")
        for s in wf.specs:
            s.conditions.colonyname = s.conditions.colonyname or colony
        wf.colonyname = colony
        wf.validate()
        queuesize = int(g.get("queuesize", 1))
        if queuesize < 1:
            raise ValidationError("queuesize must be >= 1")
        entry = {
            "generatorid": secrets.token_hex(16),
            "colonyname": colony,
            "name": g.get("name", ""),
            "workflow": wf.to_dict(),
            "queuesize": queuesize,
            "timeout": float(g.get("timeout", 0)),  # seconds; 0 = only threshold
            "firstpack": 0,
            "runs": 0,
            "added": now_ns(),
        }
        self.db.generator_put(entry)
        return entry

    def _h_get_generators(self, identity: str, payload: dict) -> list[dict]:
        colony = payload["colonyname"]
        self.server._require_member(identity, colony)
        out = []
        for e in self.db.generator_list(colony):
            e["pending"] = self.db.kv_len(PACKS_TABLE, e["generatorid"])
            out.append(e)
        return out

    def _h_remove_generator(self, identity: str, payload: dict) -> dict:
        gid = payload["generatorid"]
        entry = self.db.generator_get(gid)
        if entry is None:
            raise NotFoundError("generator not found")
        self.server._require_member(identity, entry["colonyname"])
        self.db.generator_del(gid)
        self.db.kv_take_all(PACKS_TABLE, gid)
        return {"generatorid": gid, "removed": True}

    def _h_pack(self, identity: str, payload: dict) -> dict:
        """Append-only: safe on any replica without synchronization (§3.4.4)."""
        gid = payload["generatorid"]
        entry = self.db.generator_get(gid)
        if entry is None:
            raise NotFoundError("generator not found")
        self.server._require_member(identity, entry["colonyname"])
        n = self.db.kv_append(
            PACKS_TABLE, gid, {"arg": payload.get("arg"), "ts": now_ns()}
        )
        if entry.get("firstpack", 0) == 0:
            entry["firstpack"] = now_ns()
            self.db.generator_put(entry)
        return {"generatorid": gid, "pending": n}

    # -- leader scan --------------------------------------------------------
    def tick(self) -> int:
        ts = now_ns()
        fired = 0
        for entry in self.db.generator_all():
            gid = entry["generatorid"]
            pending = self.db.kv_len(PACKS_TABLE, gid)
            if pending == 0:
                continue
            timed_out = (
                entry.get("timeout", 0) > 0
                and entry.get("firstpack", 0) > 0
                and ts - entry["firstpack"] > entry["timeout"] * 1e9
            )
            if pending >= entry["queuesize"] or timed_out:
                self._fire(entry, ts)
                fired += 1
        return fired

    def _fire(self, entry: dict, ts: int) -> None:
        gid = entry["generatorid"]
        packs = self.db.kv_take_all(PACKS_TABLE, gid)
        if not packs:
            return
        args = [p["arg"] for p in packs]
        wf = WorkflowSpec.from_dict(entry["workflow"])
        # Packed args are delivered to the DAG roots via kwargs.
        for s in wf.specs:
            if not s.conditions.dependencies:
                s.kwargs = dict(s.kwargs)
                s.kwargs["packed_args"] = args
        self.server.submit_workflow_processes(wf)
        entry = dict(entry)
        entry["firstpack"] = 0
        entry["runs"] = entry.get("runs", 0) + 1
        self.db.generator_put(entry)
        self.server._notify_queue()
        self.triggered += 1
