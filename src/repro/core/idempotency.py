"""Idempotency classification of every RPC payloadtype (ROBUSTNESS.md).

Retrying transports give at-least-once delivery; this spec is how the
repo turns that into exactly-once *effect*. Every payloadtype in the
dispatch table is classified:

* ``KEYED`` — mutating and not naturally idempotent: a blind replay
  would duplicate state (two processes for one submit) or conflict
  (double close). The client stamps the envelope with a fresh ``msgid``
  (64-hex, covered by the signature); the server records the reply in a
  bounded per-colony dedup table and replays it on duplicates.
* ``NATURAL`` — mutating but naturally idempotent: replaying converges
  to the same state (approve twice = approved) or fails cleanly without
  corrupting anything (remove twice = NotFoundError). No key needed.
* ``READ`` — no state change; trivially safe to retry.

The classification is drift-gated: ``python -m repro.analysis.idemlint``
statically proves every registered handler is classified and that every
handler whose call cone mutates the database is KEYED or NATURAL.
"""

from __future__ import annotations

import contextvars

KEYED = "keyed"
NATURAL = "natural"
READ = "read"

# payloadtype -> class. idemlint cross-checks this literal against the
# dispatch tables (server + extensions) — keep it exhaustive.
SPEC: dict[str, str] = {
    # keyed: replay would duplicate or conflict
    "submitfunctionspec": KEYED,
    "submitworkflow": KEYED,
    "close": KEYED,
    "addchild": KEYED,
    "assign": KEYED,
    "addcolony": KEYED,
    "addexecutor": KEYED,
    "adduser": KEYED,
    "addfunction": KEYED,
    "addcron": KEYED,
    "runcron": KEYED,
    "addgenerator": KEYED,
    "pack": KEYED,
    "addfile": KEYED,
    "createsnapshot": KEYED,
    # natural: replay converges or fails cleanly
    "approveexecutor": NATURAL,
    "rejectexecutor": NATURAL,
    "removeexecutor": NATURAL,
    "removecron": NATURAL,
    "removegenerator": NATURAL,
    "removefile": NATURAL,
    "removesnapshot": NATURAL,
    # read-only
    "listexecutors": READ,
    "listusers": READ,
    "listfunctions": READ,
    "getprocess": READ,
    "getprocesses": READ,
    "colonystats": READ,
    "getcrons": READ,
    "getgenerators": READ,
    "getfile": READ,
    "getfiles": READ,
    "getsnapshot": READ,
    "getsnapshots": READ,
}


def classify(payloadtype: str) -> str:
    """Unknown payloadtypes default to READ (no key stamped, no dedup)."""
    return SPEC.get(payloadtype, READ)


# The msgid of the request currently being dispatched, so deep callees
# (the close/assign Raft proposals in server.py) can stamp it onto the
# replicated op without threading a parameter through every layer.
_request_msgid: contextvars.ContextVar[str] = contextvars.ContextVar(
    "request_msgid", default=""
)


def set_current(msgid: str) -> contextvars.Token:
    return _request_msgid.set(msgid or "")


def reset_current(token: contextvars.Token) -> None:
    _request_msgid.reset(token)


def current() -> str:
    return _request_msgid.get()


def reply_colony(payloadtype: str, payload: dict, result) -> str:
    """Best-effort colony attribution for a dedup record (for eviction
    accounting only; correctness never depends on it)."""
    if isinstance(payload, dict):
        c = payload.get("colonyname")
        if c:
            return str(c)
        spec = payload.get("spec") or payload.get("workflow") or {}
        if isinstance(spec, dict):
            c = spec.get("conditions", {}).get("colonyname") or spec.get("colonyname")
            if c:
                return str(c)
    if isinstance(result, dict):
        c = result.get("colonyname")
        if c:
            return str(c)
        procs = result.get("processes")
        if isinstance(procs, list) and procs and isinstance(procs[0], dict):
            c = procs[0].get("spec", {}).get("conditions", {}).get("colonyname")
            if c:
                return str(c)
    return ""
