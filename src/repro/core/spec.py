"""Function specifications and workflow specs (paper §3.3, §4.2, Listings 1/2/6).

A *function specification* is the meta-description of a computation:
what function to run, under what conditions (which executor type, colony,
resources), data-synchronization directives (CFS), and the failsafe
envelope (maxwaittime / maxexectime / maxretries / priority).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any


@dataclass
class Gpu:
    count: int = 0
    name: str = ""

    def to_dict(self) -> dict:
        return {"count": self.count, "name": self.name}

    @staticmethod
    def from_dict(d: dict | None) -> "Gpu":
        d = d or {}
        return Gpu(count=int(d.get("count", 0)), name=d.get("name", ""))


@dataclass
class Conditions:
    """Assignment conditions: which executors may run this process."""

    colonyname: str = ""
    executortype: str = ""
    executornames: list[str] = field(default_factory=list)  # pin to specific executors
    dependencies: list[str] = field(default_factory=list)  # workflow node names
    nodes: int = 1
    processes_per_node: int = 1
    cpu: str = ""
    mem: str = ""
    walltime: int = 0
    gpu: Gpu = field(default_factory=Gpu)

    def to_dict(self) -> dict:
        return {
            "colonyname": self.colonyname,
            "executortype": self.executortype,
            "executornames": list(self.executornames),
            "dependencies": list(self.dependencies),
            "nodes": self.nodes,
            "processes-per-node": self.processes_per_node,
            "cpu": self.cpu,
            "mem": self.mem,
            "walltime": self.walltime,
            "gpu": self.gpu.to_dict(),
        }

    @staticmethod
    def from_dict(d: dict) -> "Conditions":
        return Conditions(
            colonyname=d.get("colonyname", d.get("colonyid", "")),
            executortype=d.get("executortype", ""),
            executornames=list(d.get("executornames", []) or []),
            dependencies=list(d.get("dependencies", []) or []),
            nodes=int(d.get("nodes", 1)),
            processes_per_node=int(d.get("processes-per-node", 1)),
            cpu=d.get("cpu", ""),
            mem=d.get("mem", ""),
            walltime=int(d.get("walltime", 0)),
            gpu=Gpu.from_dict(d.get("gpu")),
        )


@dataclass
class SnapshotMount:
    """One CFS snapshot to materialize before execution (Listing 2 ``fs.snapshots``)."""

    snapshotid: str = ""
    label: str = ""
    dir: str = ""
    keepfiles: bool = False
    keepsnapshot: bool = False

    def to_dict(self) -> dict:
        return {
            "snapshotid": self.snapshotid,
            "label": self.label,
            "dir": self.dir,
            "keepfiles": self.keepfiles,
            "keepsnaphot": self.keepsnapshot,  # sic — field name as in the paper listing
        }

    @staticmethod
    def from_dict(d: dict) -> "SnapshotMount":
        return SnapshotMount(
            snapshotid=d.get("snapshotid", ""),
            label=d.get("label", ""),
            dir=d.get("dir", ""),
            keepfiles=bool(d.get("keepfiles", False)),
            keepsnapshot=bool(d.get("keepsnaphot", d.get("keepsnapshot", False))),
        )


@dataclass
class SyncDir:
    """Bidirectional label<->dir sync directive (download before, upload after)."""

    label: str = ""
    dir: str = ""
    keepfiles: bool = True
    upload: bool = True  # upload results when the process closes

    def to_dict(self) -> dict:
        return {
            "label": self.label,
            "dir": self.dir,
            "keepfiles": self.keepfiles,
            "upload": self.upload,
        }

    @staticmethod
    def from_dict(d: dict) -> "SyncDir":
        return SyncDir(
            label=d.get("label", ""),
            dir=d.get("dir", ""),
            keepfiles=bool(d.get("keepfiles", True)),
            upload=bool(d.get("upload", True)),
        )


@dataclass
class Filesystem:
    """CFS data-synchronization block of a function spec (paper §3.4.5)."""

    mount: str = ""
    snapshots: list[SnapshotMount] = field(default_factory=list)
    dirs: list[SyncDir] = field(default_factory=list)

    def to_dict(self) -> dict:
        return {
            "mount": self.mount,
            "snapshots": [s.to_dict() for s in self.snapshots],
            "dirs": [s.to_dict() for s in self.dirs],
        }

    @staticmethod
    def from_dict(d: dict | None) -> "Filesystem":
        d = d or {}
        return Filesystem(
            mount=d.get("mount", ""),
            snapshots=[SnapshotMount.from_dict(s) for s in d.get("snapshots", []) or []],
            dirs=[SyncDir.from_dict(s) for s in d.get("dirs", []) or []],
        )


@dataclass
class FunctionSpec:
    """The paper's function specification (Listing 1 / Listing 2)."""

    funcname: str = ""
    nodename: str = ""  # set for workflow nodes
    args: list[Any] = field(default_factory=list)
    kwargs: dict[str, Any] = field(default_factory=dict)
    conditions: Conditions = field(default_factory=Conditions)
    priority: int = 0
    maxwaittime: int = -1  # seconds in queue before the process fails; -1 = forever
    maxexectime: int = -1  # seconds an executor may hold the process; -1 = unbounded
    maxretries: int = 3
    fs: Filesystem = field(default_factory=Filesystem)
    label: str = ""
    env: dict[str, str] = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {
            "funcname": self.funcname,
            "nodename": self.nodename,
            "args": list(self.args),
            "kwargs": dict(self.kwargs),
            "conditions": self.conditions.to_dict(),
            "priority": self.priority,
            "maxwaittime": self.maxwaittime,
            "maxexectime": self.maxexectime,
            "maxretries": self.maxretries,
            "fs": self.fs.to_dict(),
            "label": self.label,
            "env": dict(self.env),
        }

    @staticmethod
    def from_dict(d: dict) -> "FunctionSpec":
        return FunctionSpec(
            funcname=d.get("funcname", ""),
            nodename=d.get("nodename", ""),
            args=list(d.get("args", []) or []),
            kwargs=dict(d.get("kwargs", {}) or {}),
            conditions=Conditions.from_dict(d.get("conditions", {}) or {}),
            priority=int(d.get("priority", 0)),
            maxwaittime=int(d.get("maxwaittime", -1)),
            maxexectime=int(d.get("maxexectime", -1)),
            maxretries=int(d.get("maxretries", 3)),
            fs=Filesystem.from_dict(d.get("fs")),
            label=d.get("label", ""),
            env=dict(d.get("env", {}) or {}),
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "FunctionSpec":
        return FunctionSpec.from_dict(json.loads(s))


@dataclass
class WorkflowSpec:
    """A DAG of function specs; edges come from ``conditions.dependencies``."""

    colonyname: str = ""
    name: str = ""
    specs: list[FunctionSpec] = field(default_factory=list)

    def to_dict(self) -> dict:
        return {
            "colonyname": self.colonyname,
            "name": self.name,
            "functionspecs": [s.to_dict() for s in self.specs],
        }

    @staticmethod
    def from_dict(d: dict) -> "WorkflowSpec":
        specs = d.get("functionspecs")
        if specs is None and isinstance(d, list):  # bare JSON list (Listing 6)
            specs = d
        return WorkflowSpec(
            colonyname=d.get("colonyname", "") if isinstance(d, dict) else "",
            name=d.get("name", "") if isinstance(d, dict) else "",
            specs=[FunctionSpec.from_dict(s) for s in (specs or [])],
        )

    @staticmethod
    def from_json(s: str) -> "WorkflowSpec":
        d = json.loads(s)
        if isinstance(d, list):
            return WorkflowSpec(specs=[FunctionSpec.from_dict(x) for x in d])
        return WorkflowSpec.from_dict(d)

    def validate(self) -> None:
        from .errors import ValidationError

        names = [s.nodename for s in self.specs]
        if len(set(names)) != len(names):
            raise ValidationError("duplicate nodename in workflow")
        known = set(names)
        for s in self.specs:
            for dep in s.conditions.dependencies:
                if dep not in known:
                    raise ValidationError(f"unknown dependency {dep!r} in node {s.nodename!r}")
        # cycle check (Kahn)
        indeg = {n: 0 for n in names}
        children: dict[str, list[str]] = {n: [] for n in names}
        for s in self.specs:
            for dep in s.conditions.dependencies:
                indeg[s.nodename] += 1
                children[dep].append(s.nodename)
        queue = [n for n, d in indeg.items() if d == 0]
        seen = 0
        while queue:
            n = queue.pop()
            seen += 1
            for c in children[n]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    queue.append(c)
        if seen != len(names):
            raise ValidationError("workflow DAG contains a cycle")
