"""Zero-trust request envelopes (paper §3.4.6).

Every API request is a signed envelope::

    {"payloadtype": "submitfunctionspec", "payload": "<json>", "signature": "<hex>"}

The server recovers the signer identity from (payloadtype || payload,
signature) — *never trust, always verify* — and authorizes against the
three-role model: server owner, colony owner, executor/user member.
"""

from __future__ import annotations

import json
from typing import Any

from .crypto import Crypto
from .errors import AuthError


def canonical(payload: dict) -> str:
    return json.dumps(payload, sort_keys=True, separators=(",", ":"))


def sign_envelope(
    payloadtype: str, payload: dict, prvkey: str, msgid: str | None = None
) -> dict:
    """Sign an envelope; an idempotency key (``msgid``) is folded into the
    signed string so a replayed-by-attacker envelope cannot be re-keyed
    (tampering with msgid breaks signature recovery — see ROBUSTNESS.md).
    Envelopes without a msgid sign exactly as before (back-compat)."""
    body = canonical(payload)
    sig = Crypto.sign(payloadtype + body + (msgid or ""), prvkey)
    env = {"payloadtype": payloadtype, "payload": body, "signature": sig}
    if msgid:
        env["msgid"] = msgid
    return env


def open_envelope(
    env: dict, verify: bool = True, allow_unverified: bool = False
) -> tuple[str, str, dict[str, Any]]:
    """Returns (identity, payloadtype, payload). Raises AuthError on tamper.

    ``verify=False`` trusts the envelope's bare ``identity`` claim and is
    legitimate only for in-process benchmark/test harnesses: the caller
    must opt in with ``allow_unverified=True`` so a transport can never
    reach the unverified path by accident (network transports always
    verify — see ``ColoniesServer.handle(external=True)``).
    """
    ptype = env.get("payloadtype", "")
    body = env.get("payload", "")
    if isinstance(body, dict):  # allow pre-parsed payloads on the in-proc path
        body = canonical(body)
    payload = json.loads(body) if body else {}
    if not verify:
        if not allow_unverified:
            raise AuthError(
                "open_envelope(verify=False) requires allow_unverified=True"
                " (in-process harnesses only; never trust, always verify)"
            )
        return env.get("identity", "unverified"), ptype, payload
    sig = env.get("signature", "")
    if not sig:
        raise AuthError("missing signature")
    try:
        identity = Crypto.recover(ptype + body + env.get("msgid", ""), sig)
    except (ValueError, AssertionError) as e:
        raise AuthError(f"signature recovery failed: {e}") from e
    return identity, ptype, payload
