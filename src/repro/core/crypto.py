"""Zero-trust identity layer (paper §3.4.6).

Pure-Python ECDSA over secp256k1 with *public-key recovery*: the server
never stores public keys for request verification — it recovers the key
from (signature, message) and derives the caller identity as the
SHA3-256 hash of the recovered public key, exactly as the paper
describes ("the identity of an executor can be calculated simply as the
SHA-3 hash of the recovered signature").

Signatures are deterministic (RFC 6979-style HMAC-SHA256 nonces) so the
protocol stays stateless and replayable in tests.  Wire format is
65 bytes hex: r (32) || s (32) || recovery_id (1).
"""

from __future__ import annotations

import hashlib
import hmac
import os
from dataclasses import dataclass

# --- secp256k1 domain parameters -------------------------------------------
P = 2**256 - 2**32 - 977
N = 0xFFFFFFFFFFFFFFFFFFFFFFFFFFFFFFFEBAAEDCE6AF48A03BBFD25E8CD0364141
A = 0
B = 7
GX = 0x79BE667EF9DCBBAC55A06295CE870B07029BFCDB2DCE28D959F2815B16F81798
GY = 0x483ADA7726A3C4655DA4FBFC0E1108A8FD17B448A68554199C47D08FFB10D4B8

_Point = tuple[int, int] | None  # None is the point at infinity


def _inv(x: int, m: int) -> int:
    return pow(x, -1, m)


def _point_add(p: _Point, q: _Point) -> _Point:
    if p is None:
        return q
    if q is None:
        return p
    (x1, y1), (x2, y2) = p, q
    if x1 == x2 and (y1 + y2) % P == 0:
        return None
    if p == q:
        lam = (3 * x1 * x1) * _inv(2 * y1, P) % P
    else:
        lam = (y2 - y1) * _inv(x2 - x1, P) % P
    x3 = (lam * lam - x1 - x2) % P
    y3 = (lam * (x1 - x3) - y1) % P
    return (x3, y3)


def _point_mul(k: int, p: _Point) -> _Point:
    """Double-and-add scalar multiplication."""
    result: _Point = None
    addend = p
    while k:
        if k & 1:
            result = _point_add(result, addend)
        addend = _point_add(addend, addend)
        k >>= 1
    return result


def _lift_x(x: int, odd: bool) -> _Point:
    """Recover the curve point with the given x and y parity."""
    y2 = (pow(x, 3, P) + B) % P
    y = pow(y2, (P + 1) // 4, P)
    if pow(y, 2, P) != y2:
        raise ValueError("x is not on the curve")
    if (y & 1) != odd:
        y = P - y
    return (x, y)


def _hash_msg(msg: bytes) -> int:
    return int.from_bytes(hashlib.sha3_256(msg).digest(), "big") % N


def _rfc6979_nonce(prvkey: int, msg_hash: int) -> int:
    """Deterministic nonce per RFC 6979 (HMAC-SHA256 construction)."""
    x = prvkey.to_bytes(32, "big")
    h1 = msg_hash.to_bytes(32, "big")
    v = b"\x01" * 32
    k = b"\x00" * 32
    k = hmac.new(k, v + b"\x00" + x + h1, hashlib.sha256).digest()
    v = hmac.new(k, v, hashlib.sha256).digest()
    k = hmac.new(k, v + b"\x01" + x + h1, hashlib.sha256).digest()
    v = hmac.new(k, v, hashlib.sha256).digest()
    while True:
        v = hmac.new(k, v, hashlib.sha256).digest()
        cand = int.from_bytes(v, "big")
        if 1 <= cand < N:
            return cand
        k = hmac.new(k, v + b"\x00", hashlib.sha256).digest()
        v = hmac.new(k, v, hashlib.sha256).digest()


def _pub_bytes(point: _Point) -> bytes:
    assert point is not None
    x, y = point
    return x.to_bytes(32, "big") + y.to_bytes(32, "big")


from functools import lru_cache


@lru_cache(maxsize=4096)
def _id_cached(prvkey: str) -> str:
    d = int(prvkey, 16)
    pub = _point_mul(d, (GX, GY))
    return hashlib.sha3_256(_pub_bytes(pub)).hexdigest()


@dataclass(frozen=True)
class Signature:
    r: int
    s: int
    v: int  # recovery id (0 or 1; y-parity of the nonce point)

    def hex(self) -> str:
        return (
            self.r.to_bytes(32, "big") + self.s.to_bytes(32, "big") + bytes([self.v])
        ).hex()

    @staticmethod
    def from_hex(h: str) -> "Signature":
        raw = bytes.fromhex(h)
        if len(raw) != 65:
            raise ValueError("signature must be 65 bytes")
        return Signature(
            int.from_bytes(raw[:32], "big"), int.from_bytes(raw[32:64], "big"), raw[64]
        )


class Crypto:
    """SDK-facing crypto helper matching the paper's Python SDK surface."""

    @staticmethod
    def prvkey() -> str:
        """Generate a fresh private key (hex)."""
        while True:
            k = int.from_bytes(os.urandom(32), "big")
            if 1 <= k < N:
                return k.to_bytes(32, "big").hex()

    @staticmethod
    def id(prvkey: str) -> str:
        """Identity = SHA3-256 of the uncompressed public key (cached)."""
        return _id_cached(prvkey)

    @staticmethod
    def sign(msg: bytes | str, prvkey: str) -> str:
        if isinstance(msg, str):
            msg = msg.encode()
        d = int(prvkey, 16)
        if not 1 <= d < N:
            raise ValueError("invalid private key")
        z = _hash_msg(msg)
        while True:
            k = _rfc6979_nonce(d, z)
            point = _point_mul(k, (GX, GY))
            assert point is not None
            x1, y1 = point
            r = x1 % N
            if r == 0:
                z = (z + 1) % N  # re-derive with perturbed hash (never in practice)
                continue
            s = (_inv(k, N) * (z + r * d)) % N
            if s == 0:
                z = (z + 1) % N
                continue
            v = y1 & 1
            if s > N // 2:  # low-s normalization flips the recovery bit
                s = N - s
                v ^= 1
            return Signature(r, s, v).hex()

    @staticmethod
    def recover(msg: bytes | str, sig_hex: str) -> str:
        """Recover the signer identity (SHA3-256 of public key) from a signature."""
        if isinstance(msg, str):
            msg = msg.encode()
        sig = Signature.from_hex(sig_hex)
        if not (1 <= sig.r < N and 1 <= sig.s < N and sig.v in (0, 1)):
            raise ValueError("malformed signature")
        z = _hash_msg(msg)
        # R is the nonce point: x = r (r < P for secp256k1 in practice), parity = v
        big_r = _lift_x(sig.r, bool(sig.v))
        r_inv = _inv(sig.r, N)
        # Q = r^-1 (s*R - z*G)
        s_r = _point_mul(sig.s, big_r)
        z_g = _point_mul((N - z) % N, (GX, GY))
        q = _point_mul(r_inv, _point_add(s_r, z_g))
        if q is None:
            raise ValueError("signature recovery failed")
        return hashlib.sha3_256(_pub_bytes(q)).hexdigest()

    @staticmethod
    def verify(msg: bytes | str, sig_hex: str, identity: str) -> bool:
        try:
            return Crypto.recover(msg, sig_hex) == identity
        except (ValueError, AssertionError):
            return False
