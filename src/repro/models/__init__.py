"""Pure-JAX model substrate for the compute continuum."""

from .model import (
    BlockDef,
    Layout,
    abstract_cache,
    decode_step,
    decoder_layout,
    forward,
    model_spec,
    mtp_logits,
    pad_cache,
    prefill,
)
from .sharding import (
    DEFAULT_RULES,
    FSDP_RULES,
    ParamLeaf,
    abstract_params,
    count_params,
    init_params,
    param_pspecs,
    param_shardings,
)

__all__ = [
    "BlockDef",
    "Layout",
    "abstract_cache",
    "decode_step",
    "decoder_layout",
    "forward",
    "model_spec",
    "mtp_logits",
    "pad_cache",
    "prefill",
    "DEFAULT_RULES",
    "FSDP_RULES",
    "ParamLeaf",
    "abstract_params",
    "count_params",
    "init_params",
    "param_pspecs",
    "param_shardings",
]
