"""Shared primitive layers: norms, activations, rotary embeddings."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(dtype)


def layer_norm(
    x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    out = out + bias.astype(jnp.float32)
    return out.astype(dtype)


def group_norm_heads(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 64e-5) -> jnp.ndarray:
    """Per-head group norm over the channel dim (RWKV time-mix output).

    x: (..., H, D); scale/bias: (H*D,)
    """
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    shape = x.shape
    out = out.reshape(*shape[:-2], shape[-2] * shape[-1])
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dtype)


def apply_norm(x: jnp.ndarray, params: dict, kind: str, eps: float) -> jnp.ndarray:
    if kind == "layernorm":
        return layer_norm(x, params["scale"], params["bias"], eps)
    return rms_norm(x, params["scale"], eps)


def silu(x: jnp.ndarray) -> jnp.ndarray:
    return x * jax.nn.sigmoid(x)


def activate(gate: jnp.ndarray, up: jnp.ndarray | None, kind: str) -> jnp.ndarray:
    """Gated (swiglu/geglu) or plain (gelu) MLP nonlinearity."""
    if kind == "swiglu":
        assert up is not None
        return silu(gate) * up
    if kind == "geglu":
        assert up is not None
        return jax.nn.gelu(gate) * up
    return jax.nn.gelu(gate)


# ----------------------------------------------------------------------------
# Rotary position embeddings
# ----------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies for the rotated half of the head dim."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float
) -> jnp.ndarray:
    """Rotate (B, S, H, D) (or (B, S, D) for shared keys) by position.

    positions: (B, S) or (S,) int32.
    """
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)  # (D/2,)
    pos = positions.astype(jnp.float32)
    angles = jnp.einsum("...s,f->...sf", pos, inv)  # (..., S, D/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    if x.ndim == 4:  # (B, S, H, D) — broadcast over heads
        sin = sin[..., None, :]
        cos = cos[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def causal_mask(q_len: int, kv_len: int, q_offset: int = 0, window: int = 0) -> jnp.ndarray:
    """(q_len, kv_len) boolean mask; True = attendable.

    ``q_offset`` is the absolute position of query 0 (prefill/decode reuse).
    ``window`` > 0 restricts to a sliding window (SWA).
    """
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    kv_pos = jnp.arange(kv_len)[None, :]
    mask = kv_pos <= q_pos
    if window > 0:
        mask = mask & (kv_pos > q_pos - window)
    return mask
