"""Logical-axis sharding (MaxText-style) for the compute continuum.

Every parameter leaf is declared once with *logical* axis names
("embed", "heads", "ffn", "experts", ...). A per-architecture *rules*
table maps logical names to physical mesh axes; ``None`` replicates.
This keeps model code mesh-agnostic: the same definition lowers on the
single-pod (data, model) mesh, the multi-pod (pod, data, model) mesh,
and the 1-device CPU smoke-test mesh.

Conventions (Megatron/MaxText-ish):
  * "batch"   -> ("pod", "data")   — pure DP
  * "vocab"   -> "model"           — sharded embeddings/logits
  * "heads"   -> "model"           — tensor parallel attention
  * "ffn"     -> "model"           — tensor parallel MLP
  * "experts" -> "model"           — expert parallel MoE
  * "embed"   -> "data" (FSDP) for big configs, None for small
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = dict[str, Any]  # logical name -> mesh axis | tuple | None

# Default tensor-parallel rules (small models: no FSDP).
DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "embed_noshard": None,
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "qk_dim": None,
    "ffn": "model",
    "experts": "model",
    "expert_ffn": None,
    "expert_embed": None,
    "layers": None,
    "state": None,
    "conv": None,
    "dt_rank": None,
    "lora": None,
    "inner": "model",  # mamba/rwkv expanded inner channels
    "frames": None,
    "patches": None,
    "vision_embed": None,
}

# FSDP rules for >=100B configs: weights' "embed" axis sharded over data.
FSDP_RULES: Rules = dict(DEFAULT_RULES, embed="data")


def rules_for(cfg) -> Rules:
    """Resolve an architecture's rules: base table + per-arch overrides."""
    base = FSDP_RULES if getattr(cfg, "sharding_rules", "tp") == "fsdp" else DEFAULT_RULES
    overrides = getattr(cfg, "rules_overrides", None) or {}
    return {**base, **overrides}


def resolve_axes(axes: tuple[str | None, ...], rules: Rules, mesh: Mesh) -> P:
    """Map logical axes to a PartitionSpec valid on this mesh."""
    spec: list[Any] = []
    for name in axes:
        if name is None:
            spec.append(None)
            continue
        target = rules.get(name, None)
        if target is None:
            spec.append(None)
            continue
        if isinstance(target, (tuple, list)):
            present = tuple(a for a in target if a in mesh.axis_names)
            spec.append(present if present else None)
        else:
            spec.append(target if target in mesh.axis_names else None)
    # PartitionSpec forbids repeating a mesh axis; keep the first occurrence.
    used: set[str] = set()
    cleaned: list[Any] = []
    for s in spec:
        if s is None:
            cleaned.append(None)
        elif isinstance(s, tuple):
            keep = tuple(a for a in s if a not in used)
            used.update(keep)
            cleaned.append(keep if keep else None)
        else:
            if s in used:
                cleaned.append(None)
            else:
                used.add(s)
                cleaned.append(s)
    return P(*cleaned)


def _divisible(shape: tuple[int, ...], pspec: P, mesh: Mesh) -> P:
    """Sharding admission policy per dim:

    * divides evenly            -> shard (no waste)
    * dim >= axis size          -> shard anyway; GSPMD pads the ragged tail
      (waste < 1 shard out of ceil(dim/axis), e.g. qwen's 40 heads on a
      16-wide axis pad to 48 — 20% padding beats 16x replication)
    * dim < axis size           -> replicate (padding would exceed 100%)
    """
    out: list[Any] = []
    for dim, s in zip(shape, tuple(pspec) + (None,) * (len(shape) - len(pspec))):
        if s is None:
            out.append(None)
            continue
        axes = s if isinstance(s, tuple) else (s,)
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        # jit in_shardings require exact divisibility; uneven GSPMD padding
        # is only legal on intermediates, so params keep the strict rule.
        out.append(s if dim % total == 0 else None)
    return P(*out)


@dataclass
class ParamLeaf:
    """Declarative parameter: shape + logical axes + init."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones | embed | custom
    scale: float | None = None  # overrides the default fan-in scaling
    custom: Callable[[jax.Array], jnp.ndarray] | None = None

    def __post_init__(self) -> None:
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def stack_spec(spec: Any, n: int, axis_name: str = "layers") -> Any:
    """Prefix every leaf with a stacked layer axis (for scan-over-layers)."""
    return jax.tree.map(
        lambda leaf: ParamLeaf(
            shape=(n,) + leaf.shape,
            axes=(axis_name,) + leaf.axes,
            init=leaf.init,
            scale=leaf.scale,
            custom=leaf.custom,
        ),
        spec,
        is_leaf=lambda x: isinstance(x, ParamLeaf),
    )


def init_params(key: jax.Array, spec: Any, dtype: jnp.dtype) -> Any:
    """Materialize a parameter pytree from a spec tree."""
    leaves, treedef = jax.tree.flatten(spec, is_leaf=lambda x: isinstance(x, ParamLeaf))
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, leaf in zip(keys, leaves):
        out.append(_init_leaf(k, leaf, dtype))
    return jax.tree.unflatten(treedef, out)


def _init_leaf(key: jax.Array, leaf: ParamLeaf, dtype: jnp.dtype) -> jnp.ndarray:
    if leaf.custom is not None:
        base = leaf.custom(key)
        if base.shape != leaf.shape:
            # Custom inits produce the per-layer shape; tile over the stacked
            # leading axes (scan-over-layers) with independent keys.
            stack_dims = leaf.shape[: len(leaf.shape) - base.ndim]
            assert leaf.shape == stack_dims + base.shape, (leaf.shape, base.shape)
            n = 1
            for d in stack_dims:
                n *= d
            keys = jax.random.split(key, n)
            base = jnp.stack([leaf.custom(k) for k in keys]).reshape(leaf.shape)
        return base.astype(dtype)
    if leaf.init == "zeros":
        return jnp.zeros(leaf.shape, dtype)
    if leaf.init == "ones":
        return jnp.ones(leaf.shape, dtype)
    if leaf.init == "embed":
        scale = leaf.scale if leaf.scale is not None else 0.02
        return (jax.random.normal(key, leaf.shape, jnp.float32) * scale).astype(dtype)
    # fan-in scaled normal; fan-in = product of all dims but the last,
    # excluding a leading stacked layer axis.
    shape = leaf.shape
    fan_in = 1
    for d in shape[:-1]:
        fan_in *= d
    if leaf.scale is not None:
        scale = leaf.scale
    else:
        scale = (1.0 / max(fan_in, 1)) ** 0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def abstract_params(spec: Any, dtype: jnp.dtype) -> Any:
    """ShapeDtypeStruct tree (for dry-run lowering without allocation)."""
    return jax.tree.map(
        lambda leaf: jax.ShapeDtypeStruct(leaf.shape, dtype),
        spec,
        is_leaf=lambda x: isinstance(x, ParamLeaf),
    )


def param_pspecs(spec: Any, rules: Rules, mesh: Mesh) -> Any:
    """PartitionSpec tree matching the param tree."""
    return jax.tree.map(
        lambda leaf: _divisible(leaf.shape, resolve_axes(leaf.axes, rules, mesh), mesh),
        spec,
        is_leaf=lambda x: isinstance(x, ParamLeaf),
    )


def param_shardings(spec: Any, rules: Rules, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps),
        param_pspecs(spec, rules, mesh),
        is_leaf=lambda x: isinstance(x, P),
    )


import contextlib
import threading

_MESH_CTX = threading.local()


@contextlib.contextmanager
def activation_mesh(mesh: Mesh):
    """Make a concrete mesh visible to shard_activation during tracing.

    The classic ``with mesh:`` resource env is NOT visible via
    ``get_abstract_mesh()`` during jit tracing in this JAX version, so the
    launcher/dry-run wraps lowering in this context instead."""
    prev = getattr(_MESH_CTX, "mesh", None)
    _MESH_CTX.mesh = mesh
    try:
        yield
    finally:
        _MESH_CTX.mesh = prev


def current_activation_mesh() -> Mesh | None:
    return getattr(_MESH_CTX, "mesh", None)


def shard_activation(x: jnp.ndarray, axes: tuple[str | None, ...], rules: Rules) -> jnp.ndarray:
    """with_sharding_constraint by logical names (no-op outside a mesh ctx)."""
    mesh = current_activation_mesh()
    if mesh is None:
        return x
    try:
        pspec = _divisible(x.shape, resolve_axes(axes, rules, mesh), mesh)
        if all(s is None for s in tuple(pspec)):
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))
    except (ValueError, AttributeError, RuntimeError):
        return x


def count_params(spec: Any) -> int:
    total = 0
    for leaf in jax.tree.leaves(spec, is_leaf=lambda x: isinstance(x, ParamLeaf)):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
    return total
