"""Mixture-of-Experts with grouped capacity dispatch (GShard/GLaM style).

Dense one-hot dispatch over the full sequence costs O(T²) in the dispatch
einsum, so tokens are split into groups of ``group_size``; each group
routes independently with capacity ``C = group * top_k * cf / E``. The
dispatch/combine tensors then cost O(T · group · k · cf · d) — linear in T.

Expert-parallel sharding: the ``experts`` axis maps to the mesh "model"
axis (clean for deepseek's 256/16); when E < mesh width (mixtral's 8),
the per-expert ``expert_ffn`` hidden is sharded instead — the per-arch
rules tables pick which (configs/*.py).

Aux losses: switch-style load balancing + router z-loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import activate
from .sharding import ParamLeaf


def moe_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    e = cfg.moe.num_experts
    f = cfg.moe.expert_d_ff
    spec = {
        "router": ParamLeaf((d, e), ("embed", "experts"), scale=0.02),
        "w_gate": ParamLeaf((e, d, f), ("experts", "expert_embed", "expert_ffn")),
        "w_up": ParamLeaf((e, d, f), ("experts", "expert_embed", "expert_ffn")),
        "w_down": ParamLeaf((e, f, d), ("experts", "expert_ffn", "expert_embed")),
    }
    if cfg.moe.num_shared_experts > 0:
        fs = f * cfg.moe.num_shared_experts
        spec["shared_gate"] = ParamLeaf((d, fs), ("embed", "ffn"))
        spec["shared_up"] = ParamLeaf((d, fs), ("embed", "ffn"))
        spec["shared_down"] = ParamLeaf((fs, d), ("ffn", "embed"))
    return spec


def _route(
    x: jnp.ndarray,  # (G, S, d) grouped tokens
    router: jnp.ndarray,
    cfg: ModelConfig,
) -> tuple[jnp.ndarray, jnp.ndarray, dict]:
    """Top-k routing. Returns (dispatch (G,S,E,C), combine (G,S,E,C), aux)."""
    e = cfg.moe.num_experts
    k = cfg.moe.top_k
    g, s, _ = x.shape
    capacity = max(1, int(s * k * cfg.moe.capacity_factor / e))

    logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32), router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k gates, renormalized (mixtral/deepseek convention)
    top_gates, top_idx = jax.lax.top_k(probs, k)  # (G,S,k)
    top_gates = top_gates / jnp.maximum(top_gates.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert buffer
    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)  # (G,S,k,E)
    flat = onehot.reshape(g, s * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(g, s, k, e)
    pos = jnp.einsum("gske,gske->gsk", pos_in_expert, onehot)  # (G,S,k)
    keep = pos < capacity
    gates = top_gates * keep

    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), capacity, dtype=jnp.float32)
    # (G,S,k,E) x (G,S,k,C) -> (G,S,E,C)
    dispatch = jnp.einsum("gske,gskc->gsec", onehot * keep[..., None], pos_oh)
    combine = jnp.einsum("gske,gskc->gsec", (onehot * gates[..., None]), pos_oh)

    # Aux: switch load-balance (first-choice stats) + router z-loss.
    me = probs.mean(axis=(0, 1))  # mean gate prob per expert
    first = jax.nn.one_hot(top_idx[..., 0], e, dtype=jnp.float32)
    ce = first.mean(axis=(0, 1))  # fraction of tokens per expert
    lb_loss = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    dropped = 1.0 - (keep.sum() / (g * s * k))
    aux = {"lb_loss": lb_loss, "z_loss": z_loss, "dropped_frac": dropped}
    return dispatch, combine, aux


def moe_fwd(params: dict, x: jnp.ndarray, cfg: ModelConfig) -> tuple[jnp.ndarray, dict]:
    """x: (B, S, d) -> (B, S, d), aux losses."""
    b, s, d = x.shape
    group = min(cfg.moe.group_size, b * s)
    tokens = x.reshape(b * s, d)
    pad = (-tokens.shape[0]) % group
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    grouped = tokens.reshape(-1, group, d)  # (G, group, d)

    dispatch, combine, aux = _route(grouped, params["router"], cfg)
    dtype = x.dtype
    expert_in = jnp.einsum("gsec,gsd->gecd", dispatch.astype(dtype), grouped)
    gate = jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"])
    up = jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"])
    h = activate(gate, up, cfg.activation)
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(dtype), expert_out)

    out = out.reshape(-1, d)
    if pad:
        out = out[: b * s]
    out = out.reshape(b, s, d)

    if cfg.moe.num_shared_experts > 0:
        sg = jnp.einsum("bsd,df->bsf", x, params["shared_gate"])
        su = jnp.einsum("bsd,df->bsf", x, params["shared_up"])
        out = out + jnp.einsum("bsf,fd->bsd", activate(sg, su, cfg.activation), params["shared_down"])
    return out, aux


def moe_aux_loss(aux: dict, cfg: ModelConfig) -> jnp.ndarray:
    return (
        cfg.moe.aux_loss_weight * aux["lb_loss"]
        + cfg.moe.router_z_weight * aux["z_loss"]
    )
