"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

Queries and KV are low-rank compressed; only the compressed latent
(c_kv, kv_lora_rank) and the shared decoupled RoPE key (qk_rope_head_dim)
are cached at decode time — the architecture's signature memory win
(576 vs 2·128·128 floats per token for the 128-head config).

Decode uses the standard *matrix absorption*: w_kv_b is folded into the
query/output projections so the latent is never expanded to per-head K/V.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import apply_rope, causal_mask, rms_norm
from .sharding import ParamLeaf


def mla_spec(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    m = cfg.mla
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    return {
        "wq_a": ParamLeaf((d, m.q_lora_rank), ("embed", "lora")),
        "q_norm": {"scale": ParamLeaf((m.q_lora_rank,), ("lora",), init="ones")},
        "wq_b": ParamLeaf((m.q_lora_rank, h, dn + dr), ("lora", "heads", "qk_dim")),
        "wkv_a": ParamLeaf((d, m.kv_lora_rank + dr), ("embed", "lora")),
        "kv_norm": {"scale": ParamLeaf((m.kv_lora_rank,), ("lora",), init="ones")},
        "wk_b": ParamLeaf((m.kv_lora_rank, h, dn), ("lora", "heads", "qk_dim")),
        "wv_b": ParamLeaf((m.kv_lora_rank, h, dv), ("lora", "heads", "head_dim")),
        "wo": ParamLeaf((h, dv, d), ("heads", "head_dim", "embed")),
    }


def _project_q(params: dict, x: jnp.ndarray, cfg: ModelConfig, positions: jnp.ndarray):
    m = cfg.mla
    dn, dr = m.qk_nope_head_dim, m.qk_rope_head_dim
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, params["wq_a"]), params["q_norm"]["scale"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["wq_b"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(params: dict, x: jnp.ndarray, cfg: ModelConfig, positions: jnp.ndarray):
    m = cfg.mla
    ckv_rope = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_kv, k_rope = ckv_rope[..., : m.kv_lora_rank], ckv_rope[..., m.kv_lora_rank :]
    c_kv = rms_norm(c_kv, params["kv_norm"]["scale"], cfg.norm_eps)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)  # (B, S, dr) shared
    return c_kv, k_rope


def _pad_v(v: jnp.ndarray, to_dim: int) -> jnp.ndarray:
    """Zero-pad V's head dim so q/k/v share a head_dim (trimmed after)."""
    pad = to_dim - v.shape[-1]
    if pad <= 0:
        return v
    return jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))


def mla_fwd(
    params: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    positions: jnp.ndarray,
    *,
    return_cache: bool = False,
):
    """Full-sequence MLA (training / prefill)."""
    m = cfg.mla
    b, s, _ = x.shape
    q_nope, q_rope = _project_q(params, x, cfg, positions)
    c_kv, k_rope = _project_kv_latent(params, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["wv_b"])

    # Fold the (nope, rope) split into one key/query tensor so the shared
    # block-chunked attention path applies (MHA: KV groups == heads).
    from .attention import blockwise_attention

    h = cfg.num_heads
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, m.qk_rope_head_dim))],
        axis=-1,
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    # head_dim mismatch (qk 192 vs v 128): attention scales by qk dim.
    out = blockwise_attention(q_full, k_full, _pad_v(v, q_full.shape[-1]), cfg.q_chunk)
    out = out[..., : m.v_head_dim]
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    if return_cache:
        return y, {"c_kv": c_kv, "k_rope": k_rope}
    return y


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
    }


def abstract_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    m = cfg.mla
    return {
        "c_kv": jax.ShapeDtypeStruct((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jax.ShapeDtypeStruct((batch, max_len, m.qk_rope_head_dim), dtype),
    }


def mla_decode(
    params: dict,
    x_t: jnp.ndarray,  # (B, 1, d)
    cache: dict,
    pos: jnp.ndarray,  # scalar
    cfg: ModelConfig,
) -> tuple[jnp.ndarray, dict]:
    """Absorbed decode: score/value paths stay in the latent space."""
    m = cfg.mla
    pos_arr = jnp.reshape(pos, (1,))
    q_nope, q_rope = _project_q(params, x_t, cfg, pos_arr)  # (B,1,H,dn/dr)
    c_t, kr_t = _project_kv_latent(params, x_t, cfg, pos_arr)

    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_t.astype(cache["c_kv"].dtype), (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], kr_t.astype(cache["k_rope"].dtype), (0, pos, 0))

    # Absorption: q_eff[h] = q_nope[h] @ wk_b[:, h, :].T  -> latent space.
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, params["wk_b"])  # (B,1,H,r)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    scores = (
        jnp.einsum("bshr,btr->bhst", q_lat, c_kv)
        + jnp.einsum("bshk,btk->bhst", q_rope, k_rope)
    ).astype(jnp.float32) * scale
    ln = c_kv.shape[1]
    valid = (jnp.arange(ln) <= pos)[None, None, None, :]
    scores = jnp.where(valid, scores, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(scores, axis=-1).astype(c_kv.dtype)
    out_lat = jnp.einsum("bhst,btr->bshr", probs, c_kv)  # (B,1,H,r)
    out = jnp.einsum("bshr,rhk->bshk", out_lat, params["wv_b"])  # absorb wv_b
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, {"c_kv": c_kv, "k_rope": k_rope}
