"""GQA / sliding-window / cross attention with KV caching.

Pure-jnp paths are the defaults (they lower on any backend, including the
512-device dry-run); the Pallas flash kernel (kernels/flash_attention.py)
is selected with ``cfg.use_pallas`` for TPU execution.

Parameter spec + three entry points per block:
  * ``attn_fwd``        — full-sequence training/prefill forward
  * ``attn_decode``     — single-token decode against a cache
  * ``init_attn_cache`` — cache pytree (ring buffer when SWA bounds it)
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import apply_rope, causal_mask
from .sharding import ParamLeaf


# ---------------------------------------------------------------------------
# Parameter spec
# ---------------------------------------------------------------------------


def attn_spec(cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    spec = {
        "wq": ParamLeaf((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamLeaf((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamLeaf((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamLeaf((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cross:
        # K/V come from the encoder / vision memory (possibly different dim).
        mem_d = cfg.vision_embed_dim if cfg.family == "vlm" else cfg.d_model
        spec["wk"] = ParamLeaf((mem_d, kv, hd), ("vision_embed", "kv_heads", "head_dim"))
        spec["wv"] = ParamLeaf((mem_d, kv, hd), ("vision_embed", "kv_heads", "head_dim"))
        spec["gate"] = ParamLeaf((1,), (None,), init="zeros")  # llama-vision gating
    if cfg.qkv_bias:
        spec["bq"] = ParamLeaf((h, hd), ("heads", "head_dim"), init="zeros")
        spec["bk"] = ParamLeaf((kv, hd), ("kv_heads", "head_dim"), init="zeros")
        spec["bv"] = ParamLeaf((kv, hd), ("kv_heads", "head_dim"), init="zeros")
    return spec


# ---------------------------------------------------------------------------
# Core softmax attention on grouped heads
# ---------------------------------------------------------------------------


def gqa_scores_softmax_out(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Skv, KV, D)
    v: jnp.ndarray,  # (B, Skv, KV, D)
    mask: jnp.ndarray | None,  # broadcastable to (B, KV, G, Sq, Skv) or (Sq, Skv)
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, d)
    scale = d ** -0.5
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, h, d)


def blockwise_attention(
    q: jnp.ndarray,  # (B, S, H, D)
    k: jnp.ndarray,  # (B, S, KV, D)
    v: jnp.ndarray,  # (B, S, KV, D)
    q_chunk: int,
    window: int = 0,
    causal: bool = True,
    rules=None,
) -> jnp.ndarray:
    """Query-block chunked causal attention (XLA flash fallback).

    Never materializes the full (B,H,S,S) score tensor: a ``lax.scan``
    over query blocks computes each block against only its visible KV
    prefix (static full-K slice; masking trims the remainder). Peak
    activation is O(B·H·q_chunk·S) instead of O(B·H·S²).

    Under sequence-parallel rules ("seq" -> "model"), the shard lands on
    the WITHIN-block q dim (the scan's block dim must stay replicated for
    local slicing), so each device computes q_chunk/16 rows per block.
    """
    from .sharding import shard_activation

    b, s, h, d = q.shape
    if q_chunk <= 0 or s % q_chunk or s <= q_chunk:
        mask = causal_mask(s, s, window=window) if causal else None
        return gqa_scores_softmax_out(q, k, v, mask)
    nblk = s // q_chunk
    qb = jnp.moveaxis(q.reshape(b, nblk, q_chunk, h, d), 1, 0)  # (nblk,B,qc,H,D)
    if rules is not None:
        qb = shard_activation(qb, (None, "batch", "seq", "heads", None), rules)

    @jax.checkpoint  # per-chunk remat: backward recomputes this chunk's
    def chunk(qi, i):  # probs instead of stacking S² residuals across chunks
        offset = i * q_chunk
        if causal:
            m = causal_mask(q_chunk, s, q_offset=offset, window=window)
        else:
            m = None
        out_i = gqa_scores_softmax_out(qi, k, v, m)
        if rules is not None:
            out_i = shard_activation(out_i, ("batch", "seq", "heads", None), rules)
        return out_i

    def body(_, inp):
        i, qi = inp
        return None, chunk(qi, i)

    _, out = jax.lax.scan(body, None, (jnp.arange(nblk), qb))
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, d)


def _project_qkv(params: dict, x: jnp.ndarray, mem: jnp.ndarray | None = None):
    src = x if mem is None else mem
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return q, k, v


# ---------------------------------------------------------------------------
# Full-sequence forward (training / prefill)
# ---------------------------------------------------------------------------


def attn_fwd(
    params: dict,
    x: jnp.ndarray,  # (B, S, d_model)
    cfg: ModelConfig,
    positions: jnp.ndarray,  # (S,) or (B, S)
    *,
    return_cache: bool = False,
) -> jnp.ndarray | tuple[jnp.ndarray, dict]:
    q, k, v = _project_qkv(params, x)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    s = x.shape[1]
    if cfg.use_pallas:
        from ..kernels.ops import flash_attention

        out = flash_attention(q, k, v, causal=True, window=cfg.sliding_window)
    else:
        from .sharding import rules_for

        out = blockwise_attention(
            q, k, v, cfg.q_chunk, window=cfg.sliding_window, causal=True,
            rules=rules_for(cfg),
        )
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    if return_cache:
        return y, make_cache_from_prefill(k, v, cfg)
    return y


def cross_attn_fwd(
    params: dict,
    x: jnp.ndarray,  # (B, S, d_model)
    memory: jnp.ndarray,  # (B, M, mem_dim)
    cfg: ModelConfig,
) -> jnp.ndarray:
    q, k, v = _project_qkv(params, x, mem=memory)
    out = gqa_scores_softmax_out(q, k, v, mask=None)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    if "gate" in params:  # llama-3.2-vision: tanh-gated cross-attn residual
        y = jnp.tanh(params["gate"].astype(y.dtype)) * y
    return y


# ---------------------------------------------------------------------------
# KV cache (contiguous, or ring buffer under sliding-window attention)
# ---------------------------------------------------------------------------


def cache_len(cfg: ModelConfig, max_len: int) -> int:
    """SWA bounds the live KV window — the decode cache is a ring buffer."""
    if cfg.sliding_window > 0:
        return min(cfg.sliding_window, max_len)
    return max_len


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    ln = cache_len(cfg, max_len)
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (batch, ln, kv, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def abstract_attn_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    ln = cache_len(cfg, max_len)
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (batch, ln, kv, hd)
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
    }


def make_cache_from_prefill(k: jnp.ndarray, v: jnp.ndarray, cfg: ModelConfig) -> dict:
    """Prefill K/V -> decode cache. Under SWA, keep the last ``window``
    positions and rotate them into ring order (slot = position % window)
    so subsequent decode writes land in the right slots."""
    s = k.shape[1]
    w = cfg.sliding_window
    if w > 0 and s > w:
        k = jnp.roll(k[:, -w:], shift=s % w, axis=1)
        v = jnp.roll(v[:, -w:], shift=s % w, axis=1)
    return {"k": k, "v": v}


def attn_decode(
    params: dict,
    x_t: jnp.ndarray,  # (B, 1, d_model)
    cache: dict,
    pos: jnp.ndarray,  # scalar int32 — absolute position of this token
    cfg: ModelConfig,
) -> tuple[jnp.ndarray, dict]:
    q, k_t, v_t = _project_qkv(params, x_t)
    pos_arr = jnp.reshape(pos, (1,))
    if cfg.use_rope:
        q = apply_rope(q, pos_arr, cfg.rope_theta)
        k_t = apply_rope(k_t, pos_arr, cfg.rope_theta)

    ln = cache["k"].shape[1]
    if cfg.sliding_window > 0:
        slot = pos % ln  # ring buffer — O(window) memory at any context length
    else:
        slot = jnp.minimum(pos, ln - 1)
    k = jax.lax.dynamic_update_slice(cache["k"], k_t.astype(cache["k"].dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_t.astype(cache["v"].dtype), (0, slot, 0, 0))

    # Validity: ring slots written so far; contiguous cache positions <= pos.
    idx = jnp.arange(ln)
    if cfg.sliding_window > 0:
        valid = idx < jnp.minimum(pos + 1, ln)  # ring fully valid once wrapped
    else:
        valid = idx <= pos
    mask = valid[None, None, None, None, :]  # (1,1,1,1,Skv) -> broadcast
    out = gqa_scores_softmax_out(q, k, v, mask)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, {"k": k, "v": v}
