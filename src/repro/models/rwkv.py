"""RWKV-6 "Finch" block (arXiv:2404.05892) — attention-free, O(1) state.

Time-mix with data-dependent token-shift (ddlerp) and *data-dependent
per-channel decay* w_t = exp(-exp(w0 + lora(x_t))) — the Finch signature.

Training/prefill uses a chunked WKV: within a chunk, decay ratios are
computed pairwise in log space, exp(cum_{t-1} - cum_s) ≤ 1 for s < t, so
the formulation never overflows regardless of decay magnitude (the TPU
adaptation of the CUDA wkv6 kernel — see DESIGN.md). Cross-chunk state is
carried by a sequential scan. Decode is the O(1) recurrence.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import group_norm_heads, silu
from .sharding import ParamLeaf

_MIX_NAMES = ("r", "k", "v", "w", "g")


def _heads(cfg: ModelConfig) -> tuple[int, int]:
    hs = cfg.rwkv.head_size
    return cfg.d_model // hs, hs


def rwkv_time_mix_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    lw = cfg.rwkv.decay_lora
    lm = cfg.rwkv.mix_lora

    def w0_init(key: jax.Array) -> jnp.ndarray:
        # decay spread across channels (rwkv reference: -6..~0 pre-exp)
        ratio = jnp.arange(d, dtype=jnp.float32) / max(d - 1, 1)
        return -6.0 + 5.0 * ratio**0.9

    return {
        "mu_x": ParamLeaf((d,), ("embed",), init="zeros"),
        "mu": ParamLeaf((5, d), (None, "embed"), init="zeros"),
        "mix_a": ParamLeaf((d, 5 * lm), ("embed", "lora"), scale=0.02),
        "mix_b": ParamLeaf((5, lm, d), (None, "lora", "embed"), scale=0.02),
        "w0": ParamLeaf((d,), ("embed",), custom=w0_init),
        "w_a": ParamLeaf((d, lw), ("embed", "lora"), scale=0.02),
        "w_b": ParamLeaf((lw, d), ("lora", "embed"), scale=0.02),
        "u": ParamLeaf((d,), ("embed",), init="zeros"),
        "wr": ParamLeaf((d, d), ("embed", "inner")),
        "wk": ParamLeaf((d, d), ("embed", "inner")),
        "wv": ParamLeaf((d, d), ("embed", "inner")),
        "wg": ParamLeaf((d, d), ("embed", "inner")),
        "wo": ParamLeaf((d, d), ("inner", "embed")),
        "ln_x": {
            "scale": ParamLeaf((d,), ("embed",), init="ones"),
            "bias": ParamLeaf((d,), ("embed",), init="zeros"),
        },
    }


def rwkv_channel_mix_spec(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": ParamLeaf((d,), ("embed",), init="zeros"),
        "mu_r": ParamLeaf((d,), ("embed",), init="zeros"),
        "wk": ParamLeaf((d, f), ("embed", "ffn")),
        "wv": ParamLeaf((f, d), ("ffn", "embed")),
        "wr": ParamLeaf((d, d), ("embed", "inner")),
    }


def _token_shift(x: jnp.ndarray, x_prev: jnp.ndarray | None) -> jnp.ndarray:
    """Shift right by one along time; first slot filled by x_prev (decode state)."""
    if x_prev is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = x_prev[:, None, :].astype(x.dtype)
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _ddlerp(params: dict, x: jnp.ndarray, xs: jnp.ndarray) -> dict[str, jnp.ndarray]:
    """Data-dependent lerp producing the 5 mixed inputs (r,k,v,w,g)."""
    lm = params["mix_b"].shape[1]
    dx = xs - x
    xx = x + dx * params["mu_x"].astype(x.dtype)
    lora = jnp.tanh(jnp.einsum("btd,dk->btk", xx, params["mix_a"]))
    lora = lora.reshape(*lora.shape[:-1], 5, lm)
    dyn = jnp.einsum("btnl,nld->btnd", lora, params["mix_b"])  # (B,T,5,d)
    out = {}
    for i, name in enumerate(_MIX_NAMES):
        mix = params["mu"][i].astype(x.dtype) + dyn[:, :, i].astype(x.dtype)
        out[name] = x + dx * mix
    return out


def _decay(params: dict, xw: jnp.ndarray) -> jnp.ndarray:
    """log(w_t) = -exp(w0 + tanh(xw A) B) ∈ (-inf, 0); shape (B,T,d), fp32."""
    lora = jnp.einsum(
        "btl,ld->btd", jnp.tanh(jnp.einsum("btd,dl->btl", xw, params["w_a"])), params["w_b"]
    )
    return -jnp.exp(
        jnp.clip(params["w0"].astype(jnp.float32) + lora.astype(jnp.float32), -12.0, 4.0)
    )


def _wkv_chunked(
    r: jnp.ndarray,  # (B,T,H,K) fp32
    k: jnp.ndarray,
    v: jnp.ndarray,  # (B,T,H,V)
    logw: jnp.ndarray,  # (B,T,H,K) fp32, <= 0
    u: jnp.ndarray,  # (H,K)
    s0: jnp.ndarray,  # (B,H,K,V) fp32
    chunk: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    bsz, t, h, dk = r.shape
    dv = v.shape[-1]
    nch = t // chunk

    def re(x):  # (B,T,...) -> (nch, B, chunk, ...)
        return jnp.moveaxis(x.reshape(bsz, nch, chunk, *x.shape[2:]), 1, 0)

    rc, kc, vc, wc = re(r), re(k), re(v), re(logw)

    @jax.checkpoint  # per-chunk remat: the (B,c,c,H,K) pairwise decay
    def body(s, inp):  # tensor is recomputed in backward, never stacked
        rk, kk, vk, lw = inp  # (B,c,H,K/V)
        cum = jnp.cumsum(lw, axis=1)  # (B,c,H,K)
        cum_prev = cum - lw  # cum up to t-1 (exclusive)
        # Intra-chunk pairwise: ratio[t,s] = exp(cum_prev[t] - cum[s]) for s<t
        diff = cum_prev[:, :, None] - cum[:, None, :]  # (B,c,c,H,K), <=0 for s<t
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)[None, :, :, None, None]
        ratio = jnp.where(tri, jnp.exp(diff), 0.0)
        scores = jnp.einsum("bthk,bshk,btshk->bths", rk, kk, ratio)
        # diagonal "bonus" u term
        diag = jnp.einsum("bthk,hk,bthk->bth", rk, u, kk)
        out = jnp.einsum("bths,bshv->bthv", scores, vk)
        out = out + diag[..., None] * vk
        # cross-chunk: r_t decayed against incoming state
        rw = rk * jnp.exp(cum_prev)
        out = out + jnp.einsum("bthk,bhkv->bthv", rw, s)
        # state update: S' = diag(exp(cum_c)) S + sum_s exp(cum_c - cum_s) k_s v_s
        tail = jnp.exp(cum[:, -1][:, None] - cum)  # (B,c,H,K)
        s_new = jnp.exp(cum[:, -1])[..., None] * s + jnp.einsum(
            "bshk,bshv->bhkv", kk * tail, vk
        )
        return s_new, out

    s_final, out = jax.lax.scan(body, s0, (rc, kc, vc, wc))
    out = jnp.moveaxis(out, 0, 1).reshape(bsz, t, h, dv)
    return out, s_final


def rwkv_time_mix_fwd(
    params: dict,
    x: jnp.ndarray,  # (B,T,d)
    cfg: ModelConfig,
    *,
    chunk: int = 32,
    state: dict | None = None,
    return_cache: bool = False,
):
    bsz, t, d = x.shape
    h, hs = _heads(cfg)
    x_prev = state["x_prev"] if state is not None else None
    xs = _token_shift(x, x_prev)
    mixed = _ddlerp(params, x, xs)

    from .sharding import rules_for, shard_activation

    rules = rules_for(cfg)
    r = jnp.einsum("btd,dk->btk", mixed["r"], params["wr"])
    k = jnp.einsum("btd,dk->btk", mixed["k"], params["wk"])
    v = jnp.einsum("btd,dk->btk", mixed["v"], params["wv"])
    g = silu(jnp.einsum("btd,dk->btk", mixed["g"], params["wg"]))
    logw = _decay(params, mixed["w"])  # (B,T,d) fp32
    # Keep batch on (pod, data) and channels on model through the WKV scan
    # (same GSPMD batch-all-gather failure mode as the mamba scan).
    r, k, v, logw = (
        shard_activation(t, ("batch", "seq", "inner"), rules) for t in (r, k, v, logw)
    )

    def split_heads(a):
        return a.reshape(bsz, t, h, hs)

    rh = split_heads(r).astype(jnp.float32)
    kh = split_heads(k).astype(jnp.float32)
    vh = split_heads(v).astype(jnp.float32)
    wh = split_heads(logw)
    u = params["u"].astype(jnp.float32).reshape(h, hs)

    c = min(chunk, t)
    while t % c:
        c -= 1
    s0 = (
        state["wkv"]
        if state is not None
        else jnp.zeros((bsz, h, hs, hs), jnp.float32)
    )
    if cfg.use_pallas:
        from ..kernels.ops import rwkv6_chunked

        out, s_final = rwkv6_chunked(rh, kh, vh, wh, u, s0, chunk=c)
    else:
        out, s_final = _wkv_chunked(rh, kh, vh, wh, u, s0, c)
    out = group_norm_heads(out.astype(x.dtype), params["ln_x"]["scale"], params["ln_x"]["bias"])
    out = out.reshape(bsz, t, d) * g
    y = jnp.einsum("btd,dk->btk", out, params["wo"])
    if return_cache:
        return y, {"wkv": s_final, "x_prev": x[:, -1]}
    return y


def rwkv_channel_mix_fwd(
    params: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    state: dict | None = None,
    return_cache: bool = False,
):
    x_prev = state["x_prev"] if state is not None else None
    xs = _token_shift(x, x_prev)
    xk = x + (xs - x) * params["mu_k"].astype(x.dtype)
    xr = x + (xs - x) * params["mu_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(jnp.einsum("btd,df->btf", xk, params["wk"])))
    out = jax.nn.sigmoid(jnp.einsum("btd,dk->btk", xr, params["wr"])) * jnp.einsum(
        "btf,fd->btd", kk, params["wv"]
    )
    if return_cache:
        return out, {"x_prev": x[:, -1]}
    return out


# ---------------------------------------------------------------------------
# Decode (single token, O(1) state)
# ---------------------------------------------------------------------------


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    h, hs = _heads(cfg)
    d = cfg.d_model
    return {
        "tm": {"wkv": jnp.zeros((batch, h, hs, hs), jnp.float32), "x_prev": jnp.zeros((batch, d), dtype)},
        "cm": {"x_prev": jnp.zeros((batch, d), dtype)},
    }


def abstract_rwkv_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    h, hs = _heads(cfg)
    d = cfg.d_model
    return {
        "tm": {
            "wkv": jax.ShapeDtypeStruct((batch, h, hs, hs), jnp.float32),
            "x_prev": jax.ShapeDtypeStruct((batch, d), dtype),
        },
        "cm": {"x_prev": jax.ShapeDtypeStruct((batch, d), dtype)},
    }


def rwkv_time_mix_decode(params: dict, x_t: jnp.ndarray, state: dict, cfg: ModelConfig):
    """x_t: (B,1,d). Sequential recurrence — exact, no chunking."""
    bsz, _, d = x_t.shape
    h, hs = _heads(cfg)
    xs = state["x_prev"][:, None, :].astype(x_t.dtype)
    mixed = _ddlerp(params, x_t, xs)
    r = jnp.einsum("btd,dk->btk", mixed["r"], params["wr"]).reshape(bsz, h, hs).astype(jnp.float32)
    k = jnp.einsum("btd,dk->btk", mixed["k"], params["wk"]).reshape(bsz, h, hs).astype(jnp.float32)
    v = jnp.einsum("btd,dk->btk", mixed["v"], params["wv"]).reshape(bsz, h, hs).astype(jnp.float32)
    g = silu(jnp.einsum("btd,dk->btk", mixed["g"], params["wg"]))
    w = jnp.exp(_decay(params, mixed["w"]))[:, 0].reshape(bsz, h, hs)  # (B,H,K)
    u = params["u"].astype(jnp.float32).reshape(h, hs)

    s = state["wkv"]  # (B,H,K,V)
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    out = jnp.einsum("bhk,bhkv->bhv", r, s + u[None, :, :, None] * kv)
    s_new = w[..., None] * s + kv
    out = group_norm_heads(out[:, None].reshape(bsz, 1, h, hs).astype(x_t.dtype),
                           params["ln_x"]["scale"], params["ln_x"]["bias"])
    out = out.reshape(bsz, 1, d) * g
    y = jnp.einsum("btd,dk->btk", out, params["wo"])
    return y, {"wkv": s_new, "x_prev": x_t[:, -1]}


def rwkv_channel_mix_decode(params: dict, x_t: jnp.ndarray, state: dict, cfg: ModelConfig):
    y, new = rwkv_channel_mix_fwd(params, x_t, cfg, state=state, return_cache=True)
    return y, new
