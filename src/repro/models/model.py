"""Unified model assembly for all 10 assigned architectures.

An architecture is a *layout*: a repeating group of block definitions
(the scan unit) tiled ``num_groups`` times, plus embedding/head and an
optional encoder (seamless) or cross-attention memory (llama-vision).

  dense/moe LM : group = [attn + mlp|moe]                 (x num_layers)
  deepseek-v3  : group = [mla + moe(shared+routed)]       (x 61)
  jamba        : group of 8, attn at index 4, moe on odd  (x 9)
  rwkv6        : group = [time-mix + channel-mix]         (x 32)
  llama-vision : group of 5, cross-attn layer at index 0  (x 8)
  seamless     : 24-layer encoder + 24 x [attn+xattn+mlp] decoder

Every block provides: params spec, full-seq forward (training/prefill,
optionally returning a decode cache) and a single-token decode step.
Scan-over-groups keeps HLO size depth-independent; remat policy applies
per scanned group.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention as attn
from . import mla as mla_mod
from . import moe as moe_mod
from . import rwkv as rwkv_mod
from . import ssm as ssm_mod
from .layers import activate, apply_norm
from .sharding import ParamLeaf, shard_activation

# ---------------------------------------------------------------------------
# Layouts
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BlockDef:
    mixer: str  # attn | mla | mamba | rwkv | xattn
    mlp: str  # dense | moe | rwkv_cm | none
    causal: bool = True


@dataclass(frozen=True)
class Layout:
    group: tuple[BlockDef, ...]
    num_groups: int

    @property
    def num_layers(self) -> int:
        return len(self.group) * self.num_groups


def decoder_layout(cfg: ModelConfig) -> Layout:
    moe_on = cfg.moe.num_experts > 0
    if cfg.family == "ssm":
        return Layout((BlockDef("rwkv", "rwkv_cm"),), cfg.num_layers)
    if cfg.hybrid_period > 0:  # jamba
        blocks = []
        for i in range(cfg.hybrid_period):
            mixer = "attn" if i == cfg.hybrid_attn_index else "mamba"
            mlp = "moe" if (moe_on and i % cfg.moe.moe_every == 1) else "dense"
            blocks.append(BlockDef(mixer, mlp))
        return Layout(tuple(blocks), cfg.num_layers // cfg.hybrid_period)
    if cfg.cross_attn_every > 0:  # llama-3.2-vision
        blocks = [BlockDef("xattn", "dense")]
        blocks += [BlockDef("attn", "dense")] * (cfg.cross_attn_every - 1)
        return Layout(tuple(blocks), cfg.num_layers // cfg.cross_attn_every)
    mixer = "mla" if cfg.attention == "mla" else "attn"
    if moe_on and cfg.moe.moe_every > 1:
        blocks = tuple(
            BlockDef(mixer, "moe" if i % cfg.moe.moe_every == 0 else "dense")
            for i in range(cfg.moe.moe_every)
        )
        return Layout(blocks, cfg.num_layers // cfg.moe.moe_every)
    return Layout((BlockDef(mixer, "moe" if moe_on else "dense"),), cfg.num_layers)


def encoder_layout(cfg: ModelConfig) -> Layout:
    return Layout((BlockDef("attn", "dense", causal=False),), cfg.encoder_layers)


def prefix_layout(cfg: ModelConfig) -> Layout:
    """Dense-MLP prefix layers (deepseek: first 3 of 61)."""
    mixer = "mla" if cfg.attention == "mla" else "attn"
    return Layout((BlockDef(mixer, "dense"),), cfg.dense_prefix_layers)


def prefix_cfg(cfg: ModelConfig) -> ModelConfig:
    from ..configs.base import MoEConfig

    return cfg.copy(d_ff=cfg.prefix_d_ff or cfg.d_ff, moe=MoEConfig())


def decoder_with_cross_layout(cfg: ModelConfig) -> Layout:
    """Seamless decoder: self-attn + cross-attn + mlp per layer."""
    return Layout((BlockDef("attn_x", "dense"),), cfg.num_layers)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def _norm_spec(cfg: ModelConfig, dim: int | None = None) -> dict:
    d = dim if dim is not None else cfg.d_model
    spec = {"scale": ParamLeaf((d,), ("embed_noshard",), init="ones")}
    if cfg.norm == "layernorm":
        spec["bias"] = ParamLeaf((d,), ("embed_noshard",), init="zeros")
    return spec


def _mlp_spec(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    spec = {
        "w_in": ParamLeaf((d, f), ("embed", "ffn")),
        "w_out": ParamLeaf((f, d), ("ffn", "embed")),
    }
    if cfg.activation in ("swiglu", "geglu"):
        spec["w_gate"] = ParamLeaf((d, f), ("embed", "ffn"))
    return spec


def _block_spec(bdef: BlockDef, cfg: ModelConfig) -> dict:
    spec: dict[str, Any] = {"norm1": _norm_spec(cfg)}
    if bdef.mixer == "attn":
        spec["mixer"] = attn.attn_spec(cfg)
    elif bdef.mixer == "attn_x":
        spec["mixer"] = attn.attn_spec(cfg)
        spec["xattn"] = attn.attn_spec(cfg, cross=True)
        spec["norm_x"] = _norm_spec(cfg)
    elif bdef.mixer == "xattn":
        spec["mixer"] = attn.attn_spec(cfg, cross=True)
    elif bdef.mixer == "mla":
        spec["mixer"] = mla_mod.mla_spec(cfg)
    elif bdef.mixer == "mamba":
        spec["mixer"] = ssm_mod.mamba_spec(cfg)
    elif bdef.mixer == "rwkv":
        spec["mixer"] = rwkv_mod.rwkv_time_mix_spec(cfg)
    else:
        raise ValueError(bdef.mixer)
    if bdef.mlp == "dense":
        spec["mlp"] = _mlp_spec(cfg)
        spec["norm2"] = _norm_spec(cfg)
    elif bdef.mlp == "moe":
        spec["mlp"] = moe_mod.moe_spec(cfg)
        spec["norm2"] = _norm_spec(cfg)
    elif bdef.mlp == "rwkv_cm":
        spec["mlp"] = rwkv_mod.rwkv_channel_mix_spec(cfg)
        spec["norm2"] = _norm_spec(cfg)
    elif bdef.mlp == "none":
        pass
    else:
        raise ValueError(bdef.mlp)
    return spec


def _group_spec(layout: Layout, cfg: ModelConfig) -> dict:
    return {f"b{i}": _block_spec(b, cfg) for i, b in enumerate(layout.group)}


def model_spec(cfg: ModelConfig) -> dict:
    from .sharding import stack_spec

    layout = decoder_layout(cfg) if not cfg.is_encdec else decoder_with_cross_layout(cfg)
    spec: dict[str, Any] = {
        "embed": ParamLeaf((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), init="embed"),
        "groups": stack_spec(_group_spec(layout, cfg), layout.num_groups),
        "norm_f": _norm_spec(cfg),
    }
    if not cfg.tied_embeddings:
        spec["lm_head"] = ParamLeaf((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    if cfg.dense_prefix_layers > 0:
        pre = prefix_layout(cfg)
        spec["prefix_groups"] = stack_spec(
            _group_spec(pre, prefix_cfg(cfg)), pre.num_groups
        )
    if cfg.is_encdec:
        enc = encoder_layout(cfg)
        spec["encoder"] = {
            "proj": ParamLeaf((cfg.audio_embed_dim, cfg.d_model), ("vision_embed", "embed")),
            "groups": stack_spec(_group_spec(enc, cfg), enc.num_groups),
            "norm_f": _norm_spec(cfg),
        }
    if cfg.mtp_depth > 0:
        mtp_block = _block_spec(BlockDef("mla" if cfg.attention == "mla" else "attn", "dense"), cfg)
        spec["mtp"] = {
            "proj": ParamLeaf((2 * cfg.d_model, cfg.d_model), ("embed_noshard", "embed")),
            "norm_in": _norm_spec(cfg),
            "block": mtp_block,
        }
    return spec


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------


def _mixer_fwd(bdef: BlockDef, bparams: dict, h: jnp.ndarray, cfg: ModelConfig,
               positions: jnp.ndarray, memory: jnp.ndarray | None,
               return_cache: bool):
    """Returns (out, cache_or_None)."""
    if bdef.mixer in ("attn", "attn_x"):
        if bdef.causal:
            res = attn.attn_fwd(bparams["mixer"], h, cfg, positions, return_cache=return_cache)
        else:  # bidirectional encoder attention
            res = _bidir_attn(bparams["mixer"], h, cfg, positions, return_cache)
        return res if return_cache else (res, None)
    if bdef.mixer == "xattn":
        out = attn.cross_attn_fwd(bparams["mixer"], h, memory, cfg)
        if return_cache:
            return out, _cross_cache(bparams["mixer"], memory)
        return out, None
    if bdef.mixer == "mla":
        res = mla_mod.mla_fwd(bparams["mixer"], h, cfg, positions, return_cache=return_cache)
        return res if return_cache else (res, None)
    if bdef.mixer == "mamba":
        res = ssm_mod.mamba_fwd(bparams["mixer"], h, cfg, return_cache=return_cache)
        return res if return_cache else (res, None)
    if bdef.mixer == "rwkv":
        res = rwkv_mod.rwkv_time_mix_fwd(bparams["mixer"], h, cfg, return_cache=return_cache)
        return res if return_cache else (res, None)
    raise ValueError(bdef.mixer)


def _bidir_attn(params: dict, h: jnp.ndarray, cfg: ModelConfig, positions, return_cache):
    q, k, v = attn._project_qkv(params, h)
    q = attn.apply_rope(q, positions, cfg.rope_theta)
    k = attn.apply_rope(k, positions, cfg.rope_theta)
    out = attn.gqa_scores_softmax_out(q, k, v, mask=None)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    if return_cache:
        return y, {"k": k, "v": v}
    return y


def _cross_cache(params: dict, memory: jnp.ndarray) -> dict:
    k = jnp.einsum("bsd,dhk->bshk", memory, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, params["wv"])
    return {"xk": k, "xv": v}


def _mlp_fwd(bdef: BlockDef, bparams: dict, h: jnp.ndarray, cfg: ModelConfig,
             state: dict | None = None, return_cache: bool = False):
    """Returns (out, aux, cache)."""
    zero = jnp.zeros((), jnp.float32)
    if bdef.mlp == "dense":
        gate = jnp.einsum("bsd,df->bsf", h, bparams["mlp"].get("w_gate", bparams["mlp"]["w_in"]))
        up = jnp.einsum("bsd,df->bsf", h, bparams["mlp"]["w_in"]) if "w_gate" in bparams["mlp"] else None
        act = activate(gate, up, cfg.activation)
        act = shard_activation(act, ("batch", "seq", "ffn"), _current_rules(cfg))
        out = jnp.einsum("bsf,fd->bsd", act, bparams["mlp"]["w_out"])
        return out, {"lb_loss": zero, "z_loss": zero}, None
    if bdef.mlp == "moe":
        out, aux = moe_mod.moe_fwd(bparams["mlp"], h, cfg)
        return out, {"lb_loss": aux["lb_loss"], "z_loss": aux["z_loss"]}, None
    if bdef.mlp == "rwkv_cm":
        if return_cache:
            out, cm_state = rwkv_mod.rwkv_channel_mix_fwd(
                bparams["mlp"], h, cfg, state=state, return_cache=True
            )
            return out, {"lb_loss": zero, "z_loss": zero}, cm_state
        out = rwkv_mod.rwkv_channel_mix_fwd(bparams["mlp"], h, cfg, state=state)
        return out, {"lb_loss": zero, "z_loss": zero}, None
    return jnp.zeros_like(h), {"lb_loss": zero, "z_loss": zero}, None


def _current_rules(cfg: ModelConfig):
    from .sharding import rules_for

    return rules_for(cfg)


def _block_fwd(bdef: BlockDef, bparams: dict, x: jnp.ndarray, cfg: ModelConfig,
               positions: jnp.ndarray, memory: jnp.ndarray | None,
               return_cache: bool):
    """Pre-norm residual block. Returns (x, aux, cache)."""
    cache: dict = {}
    h = apply_norm(x, bparams["norm1"], cfg.norm, cfg.norm_eps)
    out, c = _mixer_fwd(bdef, bparams, h, cfg, positions, memory, return_cache)
    if c:
        cache.update(c)
    x = x + out
    if bdef.mixer == "attn_x":  # seamless decoder cross-attn sub-layer
        h = apply_norm(x, bparams["norm_x"], cfg.norm, cfg.norm_eps)
        out = attn.cross_attn_fwd(bparams["xattn"], h, memory, cfg)
        if return_cache:
            cache.update(_cross_cache(bparams["xattn"], memory))
        x = x + out
    aux = {"lb_loss": jnp.zeros((), jnp.float32), "z_loss": jnp.zeros((), jnp.float32)}
    if bdef.mlp != "none":
        h = apply_norm(x, bparams["norm2"], cfg.norm, cfg.norm_eps)
        out, aux, mlp_cache = _mlp_fwd(bdef, bparams, h, cfg, return_cache=return_cache)
        if mlp_cache:
            cache["cm"] = mlp_cache
        x = x + out
    x = shard_activation(x, ("batch", "seq", "embed_noshard"), _current_rules(cfg))
    return x, aux, cache


def _group_fwd(layout: Layout, gparams: dict, x: jnp.ndarray, cfg: ModelConfig,
               positions: jnp.ndarray, memory: jnp.ndarray | None,
               return_cache: bool):
    caches = {}
    aux_sum = {"lb_loss": jnp.zeros((), jnp.float32), "z_loss": jnp.zeros((), jnp.float32)}
    for i, bdef in enumerate(layout.group):
        x, aux, cache = _block_fwd(
            bdef, gparams[f"b{i}"], x, cfg, positions, memory, return_cache
        )
        aux_sum = {k: aux_sum[k] + aux[k] for k in aux_sum}
        caches[f"b{i}"] = cache
    return x, aux_sum, caches


def _run_stack(layout: Layout, groups_params: dict, x: jnp.ndarray, cfg: ModelConfig,
               positions: jnp.ndarray, memory: jnp.ndarray | None,
               return_cache: bool):
    """Scan the group stack. groups_params leaves have leading num_groups axis."""
    zero_aux = {"lb_loss": jnp.zeros((), jnp.float32), "z_loss": jnp.zeros((), jnp.float32)}

    def body(carry, gparams):
        x, aux_sum = carry
        x, aux, caches = _group_fwd(layout, gparams, x, cfg, positions, memory, return_cache)
        aux_sum = {k: aux_sum[k] + aux[k] for k in aux_sum}
        return (x, aux_sum), caches

    if cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif cfg.remat == "dots":
        body = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            prevent_cse=False,
        )

    if cfg.scan_layers and layout.num_groups > 1:
        (x, aux), caches = jax.lax.scan(body, (x, zero_aux), groups_params)
        return x, aux, caches  # cache leaves: (num_groups, ...)
    # unrolled
    aux_sum = zero_aux
    all_caches = []
    for g in range(layout.num_groups):
        gparams = jax.tree.map(lambda p: p[g], groups_params)
        (x, aux_sum), caches = body((x, aux_sum), gparams)
        all_caches.append(caches)
    if return_cache and all_caches:
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *all_caches)
    else:
        caches = {}
    return x, aux_sum, caches


def _encode(params: dict, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    enc = encoder_layout(cfg)
    x = jnp.einsum("bsa,ad->bsd", frames, params["encoder"]["proj"])
    positions = jnp.arange(x.shape[1])
    x, _, _ = _run_stack(enc, params["encoder"]["groups"], x, cfg, positions, None, False)
    return apply_norm(x, params["encoder"]["norm_f"], cfg.norm, cfg.norm_eps)


def _memory_from_batch(params: dict, cfg: ModelConfig, batch: dict) -> jnp.ndarray | None:
    if cfg.is_encdec:
        return _encode(params, cfg, batch["src_frames"].astype(_cdtype(cfg)))
    if cfg.cross_attn_every > 0:
        return batch["image_embeds"].astype(_cdtype(cfg))
    return None


def _cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def _logits(params: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.tied_embeddings:
        head = params["embed"].T
    else:
        head = params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.dtype(cfg.logits_dtype))


def forward(params: dict, cfg: ModelConfig, batch: dict,
            *, return_cache: bool = False, return_hidden: bool = False):
    """Full-sequence forward. batch: {"tokens": (B,S) int32, ...extras}.

    Returns (logits, aux[, cache][, hidden]).
    """
    tokens = batch["tokens"]
    layout = decoder_layout(cfg) if not cfg.is_encdec else decoder_with_cross_layout(cfg)
    x = params["embed"][tokens].astype(_cdtype(cfg))
    x = shard_activation(x, ("batch", "seq", "embed_noshard"), _current_rules(cfg))
    positions = jnp.arange(tokens.shape[1])
    memory = _memory_from_batch(params, cfg, batch)
    prefix_caches = {}
    if cfg.dense_prefix_layers > 0:
        x, _, prefix_caches = _run_stack(
            prefix_layout(cfg), params["prefix_groups"], x, prefix_cfg(cfg),
            positions, memory, return_cache,
        )
    x, aux, caches = _run_stack(layout, params["groups"], x, cfg, positions, memory, return_cache)
    hidden = apply_norm(x, params["norm_f"], cfg.norm, cfg.norm_eps)
    logits = _logits(params, cfg, hidden)
    out = [logits, aux]
    if return_cache:
        cache_out = {"layers": caches, "memory": memory}
        if cfg.dense_prefix_layers > 0:
            cache_out["prefix_layers"] = prefix_caches
        out.append(cache_out)
    if return_hidden:
        out.append(hidden)
    return tuple(out)


def mtp_logits(params: dict, cfg: ModelConfig, hidden: jnp.ndarray, tokens: jnp.ndarray):
    """DeepSeek MTP: predict token t+2 from (hidden_t, embed(token_{t+1}))."""
    mtp = params["mtp"]
    h = hidden[:, :-1]  # positions 0..S-2
    nxt = params["embed"][tokens[:, 1:]].astype(h.dtype)  # embed of t+1
    both = jnp.concatenate([apply_norm(h, mtp["norm_in"], cfg.norm, cfg.norm_eps), nxt], axis=-1)
    x = jnp.einsum("bsk,kd->bsd", both, mtp["proj"])
    bdef = BlockDef("mla" if cfg.attention == "mla" else "attn", "dense")
    positions = jnp.arange(x.shape[1])
    x, _, _ = _block_fwd(bdef, mtp["block"], x, cfg, positions, None, False)
    return _logits(params, cfg, x)  # aligned with targets t+2


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------


def _block_abstract_cache(bdef: BlockDef, cfg: ModelConfig, batch: int, max_len: int, dtype, mem_len: int):
    cache: dict[str, Any] = {}
    if bdef.mixer in ("attn", "attn_x"):
        cache.update(attn.abstract_attn_cache(cfg, batch, max_len, dtype))
    if bdef.mixer in ("xattn", "attn_x"):
        kv, hd = cfg.num_kv_heads, cfg.head_dim
        cache["xk"] = jax.ShapeDtypeStruct((batch, mem_len, kv, hd), dtype)
        cache["xv"] = jax.ShapeDtypeStruct((batch, mem_len, kv, hd), dtype)
    if bdef.mixer == "mla":
        cache.update(mla_mod.abstract_mla_cache(cfg, batch, max_len, dtype))
    if bdef.mixer == "mamba":
        cache.update(ssm_mod.abstract_mamba_cache(cfg, batch, dtype))
    if bdef.mixer == "rwkv":
        rc = rwkv_mod.abstract_rwkv_cache(cfg, batch, dtype)
        cache.update(rc["tm"])
    if bdef.mlp == "rwkv_cm":
        cache["cm"] = {"x_prev": jax.ShapeDtypeStruct((batch, cfg.d_model), dtype)}
    return cache


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, mem_len: int = 0):
    """ShapeDtypeStruct cache pytree matching prefill's return structure."""
    layout = decoder_layout(cfg) if not cfg.is_encdec else decoder_with_cross_layout(cfg)
    dtype = _cdtype(cfg)

    def group_stack(lo: Layout) -> dict:
        gc = {
            f"b{i}": _block_abstract_cache(b, cfg, batch, max_len, dtype, mem_len)
            for i, b in enumerate(lo.group)
        }
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((lo.num_groups,) + s.shape, s.dtype), gc
        )

    out = {"layers": group_stack(layout)}
    if cfg.dense_prefix_layers > 0:
        out["prefix_layers"] = group_stack(prefix_layout(cfg))
    if cfg.is_encdec or cfg.cross_attn_every > 0:
        mdim = cfg.d_model if cfg.is_encdec else cfg.vision_embed_dim
        out["memory"] = jax.ShapeDtypeStruct((batch, mem_len, mdim), dtype)
    else:
        out["memory"] = None
    return out


def _block_decode(bdef: BlockDef, bparams: dict, x: jnp.ndarray, cache: dict,
                  pos: jnp.ndarray, cfg: ModelConfig, memory: jnp.ndarray | None):
    new_cache = dict(cache)
    h = apply_norm(x, bparams["norm1"], cfg.norm, cfg.norm_eps)
    if bdef.mixer in ("attn", "attn_x"):
        out, upd = attn.attn_decode(bparams["mixer"], h, {"k": cache["k"], "v": cache["v"]}, pos, cfg)
        new_cache.update(upd)
    elif bdef.mixer == "xattn":
        out = _xattn_decode(bparams["mixer"], h, cache)
    elif bdef.mixer == "mla":
        out, upd = mla_mod.mla_decode(
            bparams["mixer"], h, {"c_kv": cache["c_kv"], "k_rope": cache["k_rope"]}, pos, cfg
        )
        new_cache.update(upd)
    elif bdef.mixer == "mamba":
        out, upd = ssm_mod.mamba_decode(bparams["mixer"], h, {"h": cache["h"], "conv": cache["conv"]}, cfg)
        new_cache.update(upd)
    elif bdef.mixer == "rwkv":
        out, upd = rwkv_mod.rwkv_time_mix_decode(
            bparams["mixer"], h, {"wkv": cache["wkv"], "x_prev": cache["x_prev"]}, cfg
        )
        new_cache.update(upd)
    else:
        raise ValueError(bdef.mixer)
    x = x + out
    if bdef.mixer == "attn_x":
        h = apply_norm(x, bparams["norm_x"], cfg.norm, cfg.norm_eps)
        out = _xattn_decode(bparams["xattn"], h, cache)
        x = x + out
    if bdef.mlp != "none":
        h = apply_norm(x, bparams["norm2"], cfg.norm, cfg.norm_eps)
        if bdef.mlp == "rwkv_cm":
            out, cm = rwkv_mod.rwkv_channel_mix_decode(bparams["mlp"], h, cache["cm"], cfg)
            new_cache["cm"] = cm
        else:
            out, _, _ = _mlp_fwd(bdef, bparams, h, cfg)
        x = x + out
    return x, new_cache


def _xattn_decode(params: dict, h: jnp.ndarray, cache: dict) -> jnp.ndarray:
    q = jnp.einsum("bsd,dhk->bshk", h, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    out = attn.gqa_scores_softmax_out(q, cache["xk"], cache["xv"], mask=None)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    if "gate" in params:
        y = jnp.tanh(params["gate"].astype(y.dtype)) * y
    return y


def _decode_stack(layout: Layout, groups_params: dict, layer_caches: dict,
                  x: jnp.ndarray, pos: jnp.ndarray, cfg: ModelConfig,
                  memory: jnp.ndarray | None):
    def body(x, xs):
        gparams, gcache = xs
        new_caches = {}
        for i, bdef in enumerate(layout.group):
            x, nc = _block_decode(bdef, gparams[f"b{i}"], x, gcache[f"b{i}"], pos, cfg, memory)
            new_caches[f"b{i}"] = nc
        return x, new_caches

    if cfg.scan_layers and layout.num_groups > 1:
        return jax.lax.scan(body, x, (groups_params, layer_caches))
    outs = []
    for g in range(layout.num_groups):
        gparams = jax.tree.map(lambda p: p[g], groups_params)
        gcache = jax.tree.map(lambda c: c[g], layer_caches)
        x, nc = body(x, (gparams, gcache))
        outs.append(nc)
    return x, jax.tree.map(lambda *xs: jnp.stack(xs), *outs)


def decode_step(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
                cache: dict, pos: jnp.ndarray):
    """One token for the whole batch. tokens: (B,1). Returns (logits, cache)."""
    layout = decoder_layout(cfg) if not cfg.is_encdec else decoder_with_cross_layout(cfg)
    x = params["embed"][tokens].astype(_cdtype(cfg))
    memory = cache.get("memory")

    new_cache = {"memory": memory}
    if cfg.dense_prefix_layers > 0:
        x, new_cache["prefix_layers"] = _decode_stack(
            prefix_layout(cfg), params["prefix_groups"], cache["prefix_layers"],
            x, pos, prefix_cfg(cfg), memory,
        )
    x, new_cache["layers"] = _decode_stack(
        layout, params["groups"], cache["layers"], x, pos, cfg, memory
    )
    hidden = apply_norm(x, params["norm_f"], cfg.norm, cfg.norm_eps)
    logits = _logits(params, cfg, hidden)
    return logits, new_cache


_SEQ_CACHE_KEYS = ("k", "v", "c_kv", "k_rope")  # leaves with a seq axis at dim 2


def pad_cache(cache: dict, cfg: ModelConfig, max_len: int) -> dict:
    """Grow sequence-indexed cache leaves to ``max_len`` decode slots.

    Leaves are stacked (groups, B, S, ...); state caches (mamba/rwkv) and
    cross-attention memories are untouched. Ring buffers (SWA) are already
    bounded by the window and never grow.
    """
    target = attn.cache_len(cfg, max_len)

    def fix(path_leaf):
        def walk(tree):
            if not isinstance(tree, dict):
                return tree
            out = {}
            for k, val in tree.items():
                if isinstance(val, dict):
                    out[k] = walk(val)
                elif k in _SEQ_CACHE_KEYS and hasattr(val, "ndim") and val.ndim >= 3:
                    s = val.shape[2]
                    tgt = target if k in ("k", "v") else max_len
                    if k in ("c_kv", "k_rope"):
                        tgt = max_len
                    if s < tgt:
                        pad = [(0, 0)] * val.ndim
                        pad[2] = (0, tgt - s)
                        val = jnp.pad(val, pad)
                    out[k] = val
                else:
                    out[k] = val
            return out

        return walk(path_leaf)

    new = dict(cache)
    new["layers"] = fix(cache["layers"])
    if "prefix_layers" in cache:
        new["prefix_layers"] = fix(cache["prefix_layers"])
    return new


def prefill(params: dict, cfg: ModelConfig, batch: dict, max_len: int | None = None):
    """Full-context forward returning last-position logits + decode cache."""
    logits, aux, cache = forward(params, cfg, batch, return_cache=True)
    if max_len is not None:
        cache = pad_cache(cache, cfg, max_len)
    return logits[:, -1:], cache
