"""Mamba-1 selective SSM block (jamba's sequence mixer, arXiv:2403.19887).

Training/prefill uses a *chunked* selective scan: a sequential
``lax.scan`` over chunks with an intra-chunk associative scan, so the
(B, T, d_inner, state) discretized tensor is only ever materialized one
chunk at a time (the TPU adaptation of the paper's hardware-aware CUDA
scan — see DESIGN.md). Decode is the O(1) state update.

Jamba-style extras: RMS norms on dt/B/C projections.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import rms_norm, silu
from .sharding import ParamLeaf


def _d_inner(cfg: ModelConfig) -> int:
    return cfg.mamba.expand * cfg.d_model


def mamba_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = _d_inner(cfg)
    n = cfg.mamba.state_dim
    r = cfg.mamba.dt_rank
    cw = cfg.mamba.conv_width

    def a_log_init(key: jax.Array) -> jnp.ndarray:
        a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
        return jnp.log(a)

    def dt_bias_init(key: jax.Array) -> jnp.ndarray:
        # dt in [1e-3, 1e-1] after softplus (mamba reference init)
        dt = jnp.exp(
            jax.random.uniform(key, (di,), jnp.float32)
            * (math.log(0.1) - math.log(1e-3))
            + math.log(1e-3)
        )
        return dt + jnp.log(-jnp.expm1(-dt))

    return {
        "in_proj": ParamLeaf((d, 2 * di), ("embed", "inner")),
        "conv_w": ParamLeaf((cw, di), ("conv", "inner"), scale=(1.0 / cw) ** 0.5),
        "conv_b": ParamLeaf((di,), ("inner",), init="zeros"),
        "x_proj": ParamLeaf((di, r + 2 * n), ("inner", "dt_rank")),
        "dt_w": ParamLeaf((r, di), ("dt_rank", "inner"), scale=r**-0.5),
        "dt_b": ParamLeaf((di,), ("inner",), custom=dt_bias_init),
        "a_log": ParamLeaf((di, n), ("inner", "state"), custom=a_log_init),
        "d_skip": ParamLeaf((di,), ("inner",), init="ones"),
        "out_proj": ParamLeaf((di, d), ("inner", "embed")),
        "dt_norm": {"scale": ParamLeaf((r,), ("dt_rank",), init="ones")},
        "b_norm": {"scale": ParamLeaf((n,), ("state",), init="ones")},
        "c_norm": {"scale": ParamLeaf((n,), ("state",), init="ones")},
    }


def _conv1d_causal(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, state: jnp.ndarray | None):
    """Depthwise causal conv. x: (B,T,di), w: (cw,di). state: (B,cw-1,di)."""
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, T+cw-1, di)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(cw))
    new_state = xp[:, -(cw - 1) :, :] if cw > 1 else jnp.zeros_like(pad)
    return out + b[None, None, :], new_state


def _ssm_inputs(params: dict, x: jnp.ndarray, cfg: ModelConfig):
    """Project to (dt, B, C) with jamba norms; returns fp32 scan operands."""
    n = cfg.mamba.state_dim
    r = cfg.mamba.dt_rank
    dbc = jnp.einsum("btd,dk->btk", x, params["x_proj"])
    dt, b_mat, c_mat = jnp.split(dbc, [r, r + n], axis=-1)
    dt = rms_norm(dt, params["dt_norm"]["scale"], cfg.norm_eps)
    b_mat = rms_norm(b_mat, params["b_norm"]["scale"], cfg.norm_eps)
    c_mat = rms_norm(c_mat, params["c_norm"]["scale"], cfg.norm_eps)
    dt = jax.nn.softplus(
        jnp.einsum("btr,rd->btd", dt, params["dt_w"]).astype(jnp.float32)
        + params["dt_b"].astype(jnp.float32)
    )  # (B,T,di) fp32
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # (di, n)
    return dt, b_mat.astype(jnp.float32), c_mat.astype(jnp.float32), a


def _chunk_scan(dt, b_mat, c_mat, a, x, h0, chunk: int):
    """Chunked selective scan.

    dt, x: (B,T,di) fp32/bf16; b_mat,c_mat: (B,T,n); a: (di,n); h0: (B,di,n).
    Returns y (B,T,di) fp32 and final state (B,di,n).
    """
    bsz, t, di = dt.shape
    n = a.shape[1]
    nchunks = t // chunk

    dt_c = dt.reshape(bsz, nchunks, chunk, di)
    x_c = x.astype(jnp.float32).reshape(bsz, nchunks, chunk, di)
    b_c = b_mat.reshape(bsz, nchunks, chunk, n)
    c_c = c_mat.reshape(bsz, nchunks, chunk, n)

    @jax.checkpoint  # per-chunk remat: backward recomputes the (B,c,di,n)
    def body(h, inp):  # discretized tensors instead of stacking them
        dtk, xk, bk, ck = inp  # (B, chunk, ...)
        # discretize: da (B,c,di,n) = exp(dt*a); dbx = dt*x*B
        da = jnp.exp(jnp.einsum("bcd,dn->bcdn", dtk, a))
        dbx = jnp.einsum("bcd,bcn->bcdn", dtk * xk, bk)
        # intra-chunk associative scan over the chunk axis
        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        acc_a, acc_b = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        h_all = acc_a * h[:, None] + acc_b  # (B,c,di,n)
        yk = jnp.einsum("bcdn,bcn->bcd", h_all, ck)
        return h_all[:, -1], yk

    h_final, y = jax.lax.scan(
        body,
        h0,
        (
            jnp.moveaxis(dt_c, 1, 0),
            jnp.moveaxis(x_c, 1, 0),
            jnp.moveaxis(b_c, 1, 0),
            jnp.moveaxis(c_c, 1, 0),
        ),
    )
    y = jnp.moveaxis(y, 0, 1).reshape(bsz, t, di)
    return y, h_final


def mamba_fwd(
    params: dict,
    x: jnp.ndarray,  # (B,T,d)
    cfg: ModelConfig,
    *,
    chunk: int = 64,
    return_cache: bool = False,
):
    from .sharding import rules_for, shard_activation

    rules = rules_for(cfg)
    bsz, t, _ = x.shape
    di = _d_inner(cfg)
    xz = jnp.einsum("btd,dk->btk", x, params["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xin, conv_state = _conv1d_causal(xin, params["conv_w"], params["conv_b"], None)
    xin = silu(xin)
    # Pin the scan operands' sharding: batch stays on (pod, data) and the
    # expanded inner channels on model — without this GSPMD all-gathers the
    # batch through the chunked-scan reshapes (16x redundant work; see
    # EXPERIMENTS.md §Perf jamba iteration 1).
    xin = shard_activation(xin, ("batch", "seq", "inner"), rules)

    dt, b_mat, c_mat, a = _ssm_inputs(params, xin, cfg)
    dt = shard_activation(dt, ("batch", "seq", "inner"), rules)
    b_mat = shard_activation(b_mat, ("batch", "seq", None), rules)
    c_mat = shard_activation(c_mat, ("batch", "seq", None), rules)
    c = min(chunk, t)
    while t % c:
        c -= 1
    h0 = jnp.zeros((bsz, di, a.shape[1]), jnp.float32)
    if cfg.use_pallas:
        from ..kernels.ops import mamba_chunk_scan

        y, h = mamba_chunk_scan(dt, b_mat, c_mat, a, xin.astype(jnp.float32), h0, chunk=c)
    else:
        y, h = _chunk_scan(dt, b_mat, c_mat, a, xin, h0, c)
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :] * xin.astype(jnp.float32)
    y = (y.astype(x.dtype)) * silu(z)
    out = jnp.einsum("btd,dk->btk", y, params["out_proj"])
    if return_cache:
        return out, {"h": h, "conv": conv_state}
    return out


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    di = _d_inner(cfg)
    return {
        "h": jnp.zeros((batch, di, cfg.mamba.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.mamba.conv_width - 1, di), dtype),
    }


def abstract_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    di = _d_inner(cfg)
    return {
        "h": jax.ShapeDtypeStruct((batch, di, cfg.mamba.state_dim), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.mamba.conv_width - 1, di), dtype),
    }


def mamba_decode(
    params: dict,
    x_t: jnp.ndarray,  # (B,1,d)
    cache: dict,
    cfg: ModelConfig,
) -> tuple[jnp.ndarray, dict]:
    xz = jnp.einsum("btd,dk->btk", x_t, params["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xin, conv_state = _conv1d_causal(xin, params["conv_w"], params["conv_b"], cache["conv"])
    xin = silu(xin)
    dt, b_mat, c_mat, a = _ssm_inputs(params, xin, cfg)
    da = jnp.exp(jnp.einsum("btd,dn->bdn", dt, a))  # t == 1
    dbx = jnp.einsum("btd,btn->bdn", dt * xin.astype(jnp.float32), b_mat)
    h = da * cache["h"] + dbx
    y = jnp.einsum("bdn,btn->btd", h, c_mat)
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :] * xin.astype(jnp.float32)
    y = y.astype(x_t.dtype) * silu(z)
    out = jnp.einsum("btd,dk->btk", y, params["out_proj"])
    return out, {"h": h, "conv": conv_state}
