"""Runtime replication-divergence contracts (the dynamic half of replint).

The paper's HA deployment (§3.4.1, Fig. 3) serializes broker mutations
through a Raft log so replicas stay interchangeable across failover.
That only holds if every applied op is deterministic (same entry, same
resulting state on every node) and idempotent (replaying an entry is a
no-op). :mod:`repro.analysis.replint` proves those properties statically
over the apply cone; this module checks them at runtime:

* :class:`ColonyDigest` — an **incremental per-colony digest** of broker
  state. Each process contributes one hash over its replication-visible
  tuple (state, owner, retries, queue membership, leader-stamped
  timestamps); the colony digest is the XOR-fold of the contributions,
  so updating one process after an apply is O(1), and the fold is
  order-independent (replicas need not observe processes in the same
  order).
* :class:`ClusterJournal` — per-node **apply journals**. On every Raft
  apply, the node appends ``(index, chained digest)`` where the chain
  folds in the entry's canonical digest and the apply's effect digest.
  The journal cross-checks nodes incrementally: the first index at which
  two nodes journal different digests raises (or records, on the event
  loop) :class:`ReplicationDivergenceError` — either their logs diverged
  (different entry at the same index) or an apply was nondeterministic.
* the **double-apply harness** lives in ``HAColonyCluster._apply``:
  under the flag, every applied entry is immediately applied a second
  time and the colony digest must be a fixpoint — a non-idempotent apply
  (one that survives its CAS twice) fails hard instead of silently
  double-mutating after a replay.

Everything is gated behind ``REPRO_REPL_CHECK=1`` (or :func:`enable`):
disabled, the hooks are a single flag check and no digests, journals, or
double applies happen.
"""

from __future__ import annotations

import hashlib
import json
import os
import threading
from typing import Any

from .locktrack import make_lock


class ReplicationDivergenceError(AssertionError):
    """Two replicas applied the same Raft log prefix to different states,
    or an apply was not idempotent under replay."""


class _Registry:
    def __init__(self) -> None:
        self.enabled = os.environ.get("REPRO_REPL_CHECK", "") not in ("", "0")


_REG = _Registry()


def is_enabled() -> bool:
    return _REG.enabled


def enable(on: bool = True) -> None:
    """Toggle checking at runtime (tests)."""
    _REG.enabled = on


# ---------------------------------------------------------------------------
# Digests
# ---------------------------------------------------------------------------

_MASK = (1 << 256) - 1


def _h(data: str) -> int:
    return int.from_bytes(hashlib.sha256(data.encode("utf-8")).digest(), "big")


def process_state_tuple(p: Any) -> tuple:
    """The replication-visible row of one process.

    Exactly the fields a replicated apply may change, all of which must
    be derived from leader-stamped entry fields: state, ownership, queue
    membership, retry count, and the start/end stamps. Anything else
    (submission metadata, spec) is written outside the replicated plane.
    """
    return (
        p.processid,
        p.state,
        p.assignedexecutorid,
        int(p.retries),
        bool(p.wait_for_parents),
        bool(p.queue_ready),
        int(p.starttime_ns),
        int(p.endtime_ns),
    )


def item_digest(item: tuple) -> int:
    """Stable hash of one process's replication-visible tuple."""
    return _h(repr(item))


def entry_digest(entry: dict) -> str:
    """Canonical digest of a proposed/applied log entry (key-order free)."""
    return hashlib.sha256(
        json.dumps(entry, sort_keys=True, separators=(",", ":")).encode("utf-8")
    ).hexdigest()


class ColonyDigest:
    """Incremental XOR-fold digest over one colony's replicated rows.

    ``observe(pid, item)`` replaces ``pid``'s contribution in O(1); the
    fold is order-independent, so every replica converges on the same
    digest regardless of the order it observed processes in. Only
    processes touched by replicated applies are tracked — submissions
    happen outside the Raft log in the shared-database deployment.
    """

    __slots__ = ("_items", "_acc")

    def __init__(self) -> None:
        self._items: dict[str, int] = {}
        self._acc = 0

    def observe(self, pid: str, item: tuple) -> None:
        h = item_digest(item)
        old = self._items.get(pid)
        if old is not None:
            self._acc ^= old
        self._items[pid] = h
        self._acc = (self._acc ^ h) & _MASK

    def forget(self, pid: str) -> None:
        old = self._items.pop(pid, None)
        if old is not None:
            self._acc ^= old

    def digest(self) -> str:
        return f"{self._acc:064x}"

    def __len__(self) -> int:
        return len(self._items)


def full_colony_digest(db: Any, colony: str) -> str:
    """Non-incremental digest over ``db.replica_state(colony)``.

    The from-scratch recomputation tests compare against the incremental
    fold (they must agree whenever every process has been observed).
    """
    d = ColonyDigest()
    for item in db.replica_state(colony):
        d.observe(item[0], item)
    return d.digest()


# ---------------------------------------------------------------------------
# Apply journals
# ---------------------------------------------------------------------------


class ClusterJournal:
    """Per-node apply journals with incremental cross-checking.

    Each node's journal is a list of ``(index, digest)`` where the digest
    chains the previous journal digest, the entry's canonical digest, and
    the apply's effect digest (the post-apply colony digest, shared by
    the HA cluster across its deduped replicas). Chaining makes a single
    divergent apply poison every later index, so the *first* divergent
    index is always detected even if later digests collide.

    ``record`` never raises on the Raft event-loop thread — the first
    divergence is stored and re-raised by :meth:`check` (and by
    ``ThreadedRaftCluster.propose_and_wait``), so the loop keeps driving
    the cluster while tests and callers fail loudly.
    """

    def __init__(self) -> None:
        self._lock = make_lock("repljournal")
        self._journals: dict[str, list[tuple[int, str]]] = {}
        self._chains: dict[str, str] = {}
        # First digest journaled per index, and by whom (the cross-check).
        self._by_index: dict[int, tuple[str, str]] = {}
        self.divergence: ReplicationDivergenceError | None = None

    def record(
        self, node_id: str, index: int, entry: dict, effect: str | None
    ) -> None:
        ed = entry_digest(entry)
        with self._lock:
            prev = self._chains.get(node_id, "")
            digest = hashlib.sha256(
                f"{prev}|{index}|{ed}|{effect or ''}".encode("utf-8")
            ).hexdigest()
            self._chains[node_id] = digest
            self._journals.setdefault(node_id, []).append((index, digest))
            first = self._by_index.get(index)
            if first is None:
                self._by_index[index] = (digest, node_id)
            elif first[0] != digest and self.divergence is None:
                self.divergence = ReplicationDivergenceError(
                    f"replica state diverged at raft index {index}:"
                    f" node {node_id} journaled {digest[:16]}… but node"
                    f" {first[1]} journaled {first[0][:16]}… (nondeterministic"
                    " or non-idempotent apply — see REPLICATION.md)"
                )

    def entries(self, node_id: str) -> list[tuple[int, str]]:
        with self._lock:
            return list(self._journals.get(node_id, ()))

    def nodes(self) -> list[str]:
        with self._lock:
            return sorted(self._journals)

    def note(self, exc: ReplicationDivergenceError) -> None:
        """Record an externally detected divergence (double-apply harness).

        Like :meth:`record`, never raises — the apply runs on the Raft
        event-loop thread; the error surfaces via :meth:`check`.
        """
        with self._lock:
            if self.divergence is None:
                self.divergence = exc

    def check(self) -> None:
        """Re-raise the first recorded divergence, if any."""
        if self.divergence is not None:
            raise self.divergence
