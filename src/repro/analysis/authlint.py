"""Zero-trust authorization lint (stdlib ``ast`` only).

Run as ``python -m repro.analysis.authlint [paths...]`` (default:
``src/repro`` plus ``examples`` when present). Exits non-zero on any
violation; there is no suppression mechanism — rules are written so the
repo passes with zero exceptions, and a new violation means the code
(not the lint) should change.

The paper's security model (§3.4.6, "never trust, always verify") makes
every RPC handler responsible for establishing an **authorization
fact** — a ``_require_member`` / ``_require_colony_owner`` /
``_require_executor`` / ``_require_server_owner`` check — before acting
on database state. This lint proves that property statically for every
``_h_*`` handler (server and extensions), interprocedurally: a handler
may delegate database work to ``self`` / ``self.server`` methods, and
per-method summaries (does it touch the db? does it establish auth?)
are propagated to a fixpoint.

Rules:

* **AUT001 missing-auth** — a registered handler (transitively) touches
  ``self.db`` / ``self._db`` but never establishes any authorization
  fact. The bypass shape: whoever signs *any* envelope gets the data.
* **AUT002 confused-deputy** — the payload-derived colony name passed to
  a database call is not one of the colony expressions that were passed
  to an auth check: the handler verified membership of colony A, then
  acted on colony B. Expressions are compared canonically (variables
  resolved through simple assignments, ``x.get("k", d)`` treated as
  ``x["k"]``); only payload-derived expressions are compared — opaque
  values (constructor results, database fetches) are out of scope.
* **AUT003 unverified-envelope** — non-test code constructs
  ``open_envelope(..., verify=False)`` or passes
  ``verify_signatures=False``. The unverified path trusts a bare
  identity *claim* and exists only for in-process benchmark harnesses
  (which live outside the linted tree).
* **AUT004 fetch-before-auth** — a database access other than an
  id-keyed fetch precedes the handler's first auth fact. Id-keyed
  fetches (``get_process``, ``cron_get``, ...) are the allowed first
  half of the fetch-then-authorize pattern — the row is needed to learn
  *which* colony to authorize against; anything else (listings, writes)
  before auth leaks data or mutates state for unauthenticated callers.

Static limitations (documented, deliberate): statements are walked
linearly through ``if``/``try`` bodies (a branch that skips the auth
check still counts as authed afterwards — the runtime contracts in
authtrack.py catch that shape), and expression canonicalization follows
single-target assignments only.
"""

from __future__ import annotations

import ast
import os
import sys

DEFAULT_PATHS = ("src/repro", "examples")

# Auth-fact helpers: name -> (role, index of the colony argument after
# identity; None = server owner, which authorizes any colony).
AUTH_FUNCS: dict[str, tuple[str, int | None]] = {
    "_require_server_owner": ("server owner", None),
    "_require_colony_owner": ("colony owner", 1),
    "_require_executor": ("executor", 1),
    "_require_member": ("member", 1),
}

# Id-keyed fetches allowed before the auth fact (fetch-then-authorize:
# the fetched row is what names the colony to authorize against).
FETCH_WHITELIST = frozenset(
    {
        "get_colony",
        "get_executor",
        "get_executor_by_name",
        "get_process",
        "cron_get",
        "generator_get",
        "user_get",
        "kv_get",
        "kv_len",
    }
)

# Database methods taking the colony name as a positional string argument.
COLONY_ARG: dict[str, int] = {
    "list_executors": 0,
    "list_functions": 0,
    "add_function": 1,
    "list_processes": 0,
    "candidates": 0,
    "colony_stats": 0,
    "user_list": 0,
    "cfs_get_file": 0,
    "cfs_get_files_by_ids": 0,
    "cfs_head": 0,
    "cfs_list": 0,
    "cfs_remove_file": 0,
    "cfs_pin_count": 0,
    "cfs_get_snapshot": 0,
    "cfs_list_snapshots": 0,
    "cfs_remove_snapshot": 0,
    "cron_list": 0,
    "generator_list": 0,
}

# Database methods taking an entry dict carrying 'colonyname'.
COLONY_ENTRY = frozenset(
    {"cfs_add_file", "cfs_create_snapshot", "cron_put", "generator_put", "user_put"}
)

_ROLE_ORDER = ("server owner", "colony owner", "executor", "member")


class Violation:
    __slots__ = ("path", "line", "rule", "msg")

    def __init__(self, path: str, line: int, rule: str, msg: str) -> None:
        self.path = path
        self.line = line
        self.rule = rule
        self.msg = msg

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.msg}"


class HandlerInfo:
    """One registered RPC handler, for reports and the permission matrix."""

    __slots__ = ("path", "classname", "name", "line", "ptypes", "role")

    def __init__(self, path: str, classname: str, name: str, line: int) -> None:
        self.path = path
        self.classname = classname
        self.name = name
        self.line = line
        self.ptypes: list[str] = []
        self.role = ""


def _dotted(node: ast.AST) -> str:
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Call):
        return _dotted(node.func)
    return ""


# ---------------------------------------------------------------------------
# Per-function event extraction
# ---------------------------------------------------------------------------

# Event tuples, in statement order:
#   ("auth", role, colony_expr, lineno)    colony_expr "*" = any colony
#   ("db", method, colony_expr|None, lineno)
#   ("call", bare_method_name, lineno)     self./self.server. method call


class _FnWalker:
    def __init__(self) -> None:
        self.env: dict[str, str] = {}
        self.dict_colony: dict[str, str] = {}
        self.events: list[tuple] = []

    # -- canonical expressions ------------------------------------------
    def canon(self, node: ast.AST) -> str:
        if isinstance(node, ast.Name):
            return self.env.get(node.id, node.id)
        if isinstance(node, ast.Attribute):
            return self.canon(node.value) + "." + node.attr
        if isinstance(node, ast.Subscript):
            if isinstance(node.slice, ast.Constant):
                return f"{self.canon(node.value)}[{node.slice.value!r}]"
            return self.canon(node.value) + "[?]"
        if isinstance(node, ast.Constant):
            return repr(node.value)
        if isinstance(node, ast.Call):
            f = node.func
            # x.get("k", default) names the same value as x["k"].
            if (
                isinstance(f, ast.Attribute)
                and f.attr == "get"
                and node.args
                and isinstance(node.args[0], ast.Constant)
            ):
                return f"{self.canon(f.value)}[{node.args[0].value!r}]"
            return self.canon(f) + "()"
        if isinstance(node, ast.BoolOp):  # `colony or fallback` -> main arm
            return self.canon(node.values[0])
        return "<expr>"

    # -- ordered traversal ----------------------------------------------
    def visit(self, node: ast.AST) -> None:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and isinstance(
            node.targets[0], ast.Name
        ):
            self.visit(node.value)
            name = node.targets[0].id
            if isinstance(node.value, ast.Dict):
                for k, v in zip(node.value.keys, node.value.values):
                    if isinstance(k, ast.Constant) and k.value == "colonyname":
                        self.dict_colony[name] = self.canon(v)
            self.env[name] = self.canon(node.value)
            return
        if isinstance(node, ast.Call):
            for arg in node.args:
                self.visit(arg)
            for kw in node.keywords:
                self.visit(kw.value)
            self._record_call(node)
            return
        for child in ast.iter_child_nodes(node):
            self.visit(child)

    def _record_call(self, node: ast.Call) -> None:
        d = _dotted(node.func)
        parts = d.split(".")
        leaf = parts[-1]
        if leaf in AUTH_FUNCS and parts[0] == "self":
            role, idx = AUTH_FUNCS[leaf]
            if idx is None:
                expr = "*"
            elif idx < len(node.args):
                expr = self.canon(node.args[idx])
            else:
                expr = "<expr>"
            self.events.append(("auth", role, expr, node.lineno))
            return
        if len(parts) >= 3 and parts[0] == "self" and parts[-2] in ("db", "_db"):
            self.events.append(("db", leaf, self._db_colony(leaf, node), node.lineno))
            return
        if parts[0] == "self" and (
            len(parts) == 2 or (len(parts) == 3 and parts[1] == "server")
        ):
            self.events.append(("call", leaf, node.lineno))

    def _db_colony(self, method: str, node: ast.Call) -> str | None:
        if method in COLONY_ARG:
            idx = COLONY_ARG[method]
            if idx < len(node.args):
                return self.canon(node.args[idx])
            return None
        if method in COLONY_ENTRY and node.args:
            arg = node.args[0]
            if isinstance(arg, ast.Name) and arg.id in self.dict_colony:
                return self.dict_colony[arg.id]
            return self.canon(arg) + "['colonyname']"
        return None


def _fn_events(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> list[tuple]:
    w = _FnWalker()
    for stmt in fn.body:
        w.visit(stmt)
    return w.events


# ---------------------------------------------------------------------------
# Whole-tree analysis
# ---------------------------------------------------------------------------


class _Summary:
    __slots__ = ("touches_db", "touches_db_nonfetch", "establishes_auth", "calls")

    def __init__(self) -> None:
        self.touches_db = False
        self.touches_db_nonfetch = False
        self.establishes_auth = False
        self.calls: set[str] = set()


def _payload_derived(expr: str | None) -> bool:
    return expr is not None and "payload[" in expr


def analyze(sources: list[tuple[str, str]]) -> tuple[list[HandlerInfo], list[Violation]]:
    """Analyze (path, source) pairs together (cross-file interprocedural)."""
    out: list[Violation] = []
    # (path, classname, fn) for every method of every class; events cached.
    methods: list[tuple[str, str, ast.FunctionDef]] = []
    events: dict[int, list[tuple]] = {}
    registered: dict[str, list[str]] = {}  # handler method name -> payloadtypes

    for path, src in sources:
        try:
            tree = ast.parse(src, filename=path)
        except SyntaxError as e:
            out.append(Violation(path, e.lineno or 0, "AUT000", f"syntax error: {e.msg}"))
            continue
        _check_unverified(tree, path, out)
        for cls in ast.walk(tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            for fn in cls.body:
                if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    methods.append((path, cls.name, fn))
                    events[id(fn)] = _fn_events(fn)
        # Handler-table dict literals: {"payloadtype": self._h_xxx, ...}
        for node in ast.walk(tree):
            if not isinstance(node, ast.Dict):
                continue
            for k, v in zip(node.keys, node.values):
                if (
                    isinstance(k, ast.Constant)
                    and isinstance(k.value, str)
                    and isinstance(v, ast.Attribute)
                    and v.attr.startswith("_h_")
                ):
                    registered.setdefault(v.attr, []).append(k.value)

    # Per-method summaries, propagated to a fixpoint across bare names
    # (extension handlers call into the server as self.server.<method>).
    summaries: dict[str, _Summary] = {}
    for _path, _cls, fn in methods:
        s = summaries.setdefault(fn.name, _Summary())
        for ev in events[id(fn)]:
            if ev[0] == "db":
                s.touches_db = True
                if ev[1] not in FETCH_WHITELIST:
                    s.touches_db_nonfetch = True
            elif ev[0] == "auth":
                s.establishes_auth = True
            elif ev[0] == "call":
                s.calls.add(ev[1])
    changed = True
    while changed:
        changed = False
        for s in summaries.values():
            for callee in s.calls:
                c = summaries.get(callee)
                if c is None:
                    continue
                for attr in ("touches_db", "touches_db_nonfetch", "establishes_auth"):
                    if getattr(c, attr) and not getattr(s, attr):
                        setattr(s, attr, True)
                        changed = True

    # Handler checks.
    handlers: list[HandlerInfo] = []
    for path, clsname, fn in methods:
        if not fn.name.startswith("_h_"):
            continue
        info = HandlerInfo(path, clsname, fn.name, fn.lineno)
        info.ptypes = sorted(registered.get(fn.name, []))
        handlers.append(info)
        evs = events[id(fn)]

        roles = [ev[1] for ev in evs if ev[0] == "auth"]
        if roles:
            info.role = min(roles, key=_ROLE_ORDER.index)

        authed = False
        auth_exprs: set[str] = set()
        any_colony = False
        touches = False
        establishes = bool(roles)
        for ev in evs:
            if ev[0] == "auth":
                authed = True
                if ev[2] == "*":
                    any_colony = True
                else:
                    auth_exprs.add(ev[2])
            elif ev[0] == "db":
                touches = True
                _method, expr, line = ev[1], ev[2], ev[3]
                if not authed and _method not in FETCH_WHITELIST:
                    out.append(
                        Violation(
                            path,
                            line,
                            "AUT004",
                            f"{clsname}.{fn.name}: db.{_method} before any"
                            " auth fact (only id-keyed fetches may precede"
                            " authorization)",
                        )
                    )
                if (
                    _payload_derived(expr)
                    and not any_colony
                    and expr not in auth_exprs
                ):
                    out.append(
                        Violation(
                            path,
                            line,
                            "AUT002",
                            f"{clsname}.{fn.name}: db.{_method} acts on colony"
                            f" {expr} but the auth check covered"
                            f" {sorted(auth_exprs) or 'nothing'}"
                            " (confused deputy)",
                        )
                    )
            elif ev[0] == "call":
                callee = summaries.get(ev[1])
                if callee is None:
                    continue
                if callee.establishes_auth:
                    authed = True
                    establishes = True
                if callee.touches_db:
                    touches = True
                    if not authed and callee.touches_db_nonfetch:
                        out.append(
                            Violation(
                                path,
                                ev[2],
                                "AUT004",
                                f"{clsname}.{fn.name}: {ev[1]}() touches the db"
                                " before any auth fact",
                            )
                        )
        if touches and not establishes:
            out.append(
                Violation(
                    path,
                    fn.lineno,
                    "AUT001",
                    f"{clsname}.{fn.name} touches the database but never"
                    " establishes an authorization fact"
                    " (_require_member/_require_colony_owner/...)",
                )
            )
    return handlers, out


def _check_unverified(tree: ast.Module, path: str, out: list[Violation]) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fname = _dotted(node.func)
        for kw in node.keywords:
            if not (isinstance(kw.value, ast.Constant) and kw.value.value is False):
                continue
            if kw.arg == "verify" and fname.endswith("open_envelope"):
                out.append(
                    Violation(
                        path,
                        node.lineno,
                        "AUT003",
                        "open_envelope(verify=False) trusts a bare identity"
                        " claim; only in-process test/benchmark harnesses may"
                        " do that (outside the linted tree)",
                    )
                )
            elif kw.arg == "verify_signatures":
                out.append(
                    Violation(
                        path,
                        node.lineno,
                        "AUT003",
                        f"{fname}(verify_signatures=False) disables the"
                        " zero-trust protocol; only in-process"
                        " test/benchmark harnesses may do that",
                    )
                )


# ---------------------------------------------------------------------------
# CLI (style of repro.analysis.lint)
# ---------------------------------------------------------------------------


def lint_source(src: str, path: str) -> list[Violation]:
    """Single-source convenience (rule fixtures in tests)."""
    _handlers, vs = analyze([(path, src)])
    return vs


def _py_files(paths: list[str]) -> list[str]:
    files: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
        else:
            for root, _dirs, names in os.walk(p):
                files.extend(os.path.join(root, n) for n in names if n.endswith(".py"))
    return sorted(files)


def run(paths: list[str] | None = None) -> tuple[int, list[HandlerInfo], list[Violation]]:
    if not paths:
        paths = [p for p in DEFAULT_PATHS if os.path.exists(p)]
    files = _py_files(paths)
    sources = []
    for f in files:
        with open(f, "r", encoding="utf-8") as fh:
            sources.append((f, fh.read()))
    handlers, vs = analyze(sources)
    return len(files), handlers, vs


def main(argv: list[str] | None = None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    nfiles, handlers, vs = run(args)
    for v in vs:
        print(v)
    nreg = sum(1 for h in handlers if h.ptypes)
    if vs:
        print(
            f"repro.analysis.authlint: {len(vs)} violation(s) in {nfiles} files"
            f" ({nreg} registered handlers)"
        )
        return 1
    print(
        f"repro.analysis.authlint: OK ({nfiles} files clean,"
        f" {nreg} registered handlers verified)"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
