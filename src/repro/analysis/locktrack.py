"""Runtime lock-order detector (the dynamic half of the concurrency lint).

The repo has four lock families with a documented acquisition order
(CONCURRENCY.md): server-level colony/shard locks outermost, then the
database registry lock ``_glock`` as a *leaf* (nothing may be acquired
while holding it), CFS shard locks independent of broker shard locks, and
Raft/cluster leader-local locks that must never nest inside database
locks. This module makes that order machine-checked:

* :func:`make_lock` is the single lock factory used by database.py,
  server.py, cluster.py, raft.py and fs.py. Disabled (the default), it
  returns a plain ``threading.RLock`` — zero overhead. Enabled via
  ``REPRO_LOCK_CHECK=1`` or :func:`enable`, it returns a
  :class:`TrackedRLock`.
* :class:`TrackedRLock` records, per thread, the ordered set of held
  locks. Each first (non-reentrant) acquisition checks:

  - **acquire-under-leaf** — acquiring anything while holding a lock in a
    leaf family (``_glock`` must guard only straight-line dict ops);
  - **cross-instance** — acquiring a second instance of an exclusive
    family (e.g. colony shard A's lock while holding colony shard B's:
    the broker never nests colonies, so this is a latent deadlock);
  - **lock-order-cycle** — the new (held-family → acquired-family) edge
    closes a cycle in the global lock-order graph, i.e. two code paths
    acquire the same families in opposite orders;
  - **wait-under-lock** — a ``Condition`` built on a tracked lock started
    waiting while the thread still held other tracked locks (blocking
    while holding a shared lock starves every other acquirer), unless
    the pairing is declared deadlock-free via :func:`allow_wait`.

Violations are *recorded*, not raised (raising mid-acquisition would
corrupt unrelated state); tests and CI assert :func:`violations` is
empty. Contract decorators (contracts.py) raise, because they guard
single functions and a violation there is a programming error at a
well-defined boundary.

Tracked locks also record **hold times**: per family, the count of
holds, total/max held nanoseconds, and which instance held longest.
Time under a ``Condition.wait()`` does not count as holding (the lock
is released while parked). Holds longer than the warn threshold
(``REPRO_LOCK_HOLD_WARN_MS``, default 50ms, or :func:`set_hold_warn_ms`)
are logged via :func:`hold_warnings` — kept separate from
:func:`violations` so a slow CI box never fails the correctness gate —
and the atexit summary prints the top holders by max hold time.

Lock names are ``"family"`` or ``"family:instance"``; the family is the
text before the first ``:``.
"""

from __future__ import annotations

import atexit
import os
import sys
import threading
import time

# Families that must guard only straight-line, non-blocking code: nothing
# may be acquired while one is held.
LEAF_FAMILIES = frozenset({"glock"})

# Families whose instances never legitimately nest with each other
# (per-colony shards, per-colony CFS shards, per-colony server locks,
# per-node Raft locks, per-database registry/connection locks).
EXCLUSIVE_FAMILIES = frozenset(
    {"glock", "shard", "cfs", "sqlite", "dbcolony", "assignlocal", "raft"}
)

# Declared wait-under-lock allowances: condition family -> families that
# may stay held across a wait on it. Empty by default; a caller that
# proves the pairing deadlock-free (the notifier never acquires the held
# family) registers it via :func:`allow_wait` next to the wait site.
_WAIT_ALLOWED: dict[str, frozenset[str]] = {}


def allow_wait(cond_family: str, *holding: str) -> None:
    """Declare a condition wait on ``cond_family`` safe while holding
    locks from ``holding`` families.

    Wait-under-lock is a violation because the parked thread blocks
    every acquirer of what it still holds — *and* deadlocks if the
    notifier needs one of those locks. An allowance is a contract that
    neither applies: register it at the wait site with a comment proving
    the notifying thread never touches the held family. Any held lock
    outside the declared families still fires.
    """
    _WAIT_ALLOWED[cond_family] = _WAIT_ALLOWED.get(
        cond_family, frozenset()
    ) | frozenset(holding)


class _Registry:
    """Global detector state: the lock-order graph and the violation log."""

    def __init__(self) -> None:
        # A plain, untracked lock: the registry must never feed itself.
        self.guard = threading.Lock()
        self.enabled = os.environ.get("REPRO_LOCK_CHECK", "") not in ("", "0")
        # (held_family, acquired_family) -> first-seen "lockA -> lockB"
        self.edges: dict[tuple[str, str], str] = {}
        self.violations: list[dict] = []
        # family -> {count, total_ns, max_ns, max_lock}
        self.holds: dict[str, dict] = {}
        self.hold_warnings: list[dict] = []
        self.hold_warn_ns = int(
            float(os.environ.get("REPRO_LOCK_HOLD_WARN_MS", "50")) * 1e6
        )


_REG = _Registry()
_TLS = threading.local()


def _held() -> dict["TrackedRLock", int]:
    """This thread's held tracked locks, in acquisition order, with counts."""
    d = getattr(_TLS, "held", None)
    if d is None:
        d = _TLS.held = {}
    return d


def _hold_t0() -> dict["TrackedRLock", int]:
    """Per-thread monotonic_ns timestamp of each lock's outermost acquire."""
    d = getattr(_TLS, "hold_t0", None)
    if d is None:
        d = _TLS.hold_t0 = {}
    return d


def is_enabled() -> bool:
    return _REG.enabled


def enable(on: bool = True) -> None:
    """Toggle tracking at runtime (tests). Only affects locks created after."""
    _REG.enabled = on


def reset() -> None:
    """Clear the order graph, violation log, and hold stats (test isolation)."""
    with _REG.guard:
        _REG.edges.clear()
        _REG.violations.clear()
        _REG.holds.clear()
        _REG.hold_warnings.clear()


def violations() -> list[dict]:
    with _REG.guard:
        return [dict(v) for v in _REG.violations]


def order_edges() -> dict[tuple[str, str], str]:
    with _REG.guard:
        return dict(_REG.edges)


def hold_stats() -> dict[str, dict]:
    """Per-family hold-time stats: count, total_ns, max_ns, mean_ns, max_lock."""
    with _REG.guard:
        out = {}
        for fam, st in _REG.holds.items():
            d = dict(st)
            d["mean_ns"] = st["total_ns"] / st["count"] if st["count"] else 0.0
            out[fam] = d
        return out


def hold_warnings() -> list[dict]:
    """Holds that exceeded the warn threshold (not counted as violations)."""
    with _REG.guard:
        return [dict(w) for w in _REG.hold_warnings]


def set_hold_warn_ms(ms: float) -> None:
    """Set the long-hold warn threshold (tests / tuning)."""
    with _REG.guard:
        _REG.hold_warn_ns = int(ms * 1e6)


def _record_hold(lock: "TrackedRLock", dur_ns: int) -> None:
    with _REG.guard:
        st = _REG.holds.get(lock.family)
        if st is None:
            st = _REG.holds[lock.family] = {
                "count": 0,
                "total_ns": 0,
                "max_ns": 0,
                "max_lock": lock.name,
            }
        st["count"] += 1
        st["total_ns"] += dur_ns
        if dur_ns > st["max_ns"]:
            st["max_ns"] = dur_ns
            st["max_lock"] = lock.name
        if dur_ns >= _REG.hold_warn_ns:
            _REG.hold_warnings.append(
                {
                    "lock": lock.name,
                    "family": lock.family,
                    "held_ns": dur_ns,
                    "thread": threading.current_thread().name,
                }
            )


def _record(kind: str, msg: str) -> None:
    with _REG.guard:
        _REG.violations.append(
            {"kind": kind, "msg": msg, "thread": threading.current_thread().name}
        )


def _cycle_after(edges: dict[tuple[str, str], str], src: str, dst: str) -> list[str] | None:
    """After adding src->dst: a dst ~> src path means the edge closed a cycle."""
    adj: dict[str, list[str]] = {}
    for a, b in edges:
        adj.setdefault(a, []).append(b)
    stack: list[tuple[str, list[str]]] = [(dst, [dst])]
    seen = {dst}
    while stack:
        node, path = stack.pop()
        if node == src:
            return path + [dst]
        for nxt in adj.get(node, ()):
            if nxt not in seen:
                seen.add(nxt)
                stack.append((nxt, path + [nxt]))
    return None


class TrackedRLock:
    """Reentrant lock that feeds the order detector on every acquisition.

    Drop-in for ``threading.RLock``, including the private
    ``_is_owned``/``_release_save``/``_acquire_restore`` hooks that
    ``threading.Condition`` uses — so a Condition built on a TrackedRLock
    keeps the held-set accurate across ``wait()`` (and flags waits
    entered while other tracked locks are held).
    """

    __slots__ = ("name", "family", "_inner")

    def __init__(self, name: str) -> None:
        self.name = name
        self.family = name.split(":", 1)[0]
        self._inner = threading.RLock()

    def __repr__(self) -> str:
        return f"TrackedRLock({self.name!r})"

    # -- detector ----------------------------------------------------------
    def _check_acquire(self) -> None:
        held = _held()
        if self in held:  # reentrant re-acquire: no new ordering information
            return
        for other in held:
            if other.family in LEAF_FAMILIES:
                _record(
                    "acquire-under-leaf",
                    f"acquiring {self.name} while holding leaf lock {other.name}",
                )
            elif other.family == self.family:
                if self.family in EXCLUSIVE_FAMILIES:
                    _record(
                        "cross-instance",
                        f"acquiring {self.name} while holding {other.name}"
                        " (same exclusive family)",
                    )
            else:
                self._note_edge(other)

    def _note_edge(self, other: "TrackedRLock") -> None:
        key = (other.family, self.family)
        with _REG.guard:
            if key in _REG.edges:
                return
            _REG.edges[key] = f"{other.name} -> {self.name}"
            cycle = _cycle_after(_REG.edges, other.family, self.family)
            if cycle:
                _REG.violations.append(
                    {
                        "kind": "lock-order-cycle",
                        "msg": "lock-order cycle "
                        + " -> ".join(cycle)
                        + f" (new edge {other.name} -> {self.name})",
                        "thread": threading.current_thread().name,
                    }
                )

    # -- lock protocol -----------------------------------------------------
    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        if _REG.enabled:
            self._check_acquire()
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            held = _held()
            n = held.get(self, 0)
            held[self] = n + 1
            if n == 0:  # outermost acquire: hold starts now
                _hold_t0()[self] = time.monotonic_ns()
        return ok

    def release(self) -> None:
        self._inner.release()
        held = _held()
        n = held.get(self, 0) - 1
        if n <= 0:
            held.pop(self, None)
            t0 = _hold_t0().pop(self, None)
            if t0 is not None:
                _record_hold(self, time.monotonic_ns() - t0)
        else:
            held[self] = n

    __enter__ = acquire

    def __exit__(self, exc_type, exc, tb) -> None:
        self.release()

    # -- Condition integration ----------------------------------------------
    def _is_owned(self) -> bool:
        return self._inner._is_owned()

    def _release_save(self):
        # Called exactly when a Condition.wait() is about to block: the
        # thread parks with this lock released — anything *else* still
        # held blocks every other acquirer for the whole wait.
        if _REG.enabled:
            allowed = _WAIT_ALLOWED.get(self.family, frozenset())
            others = [
                lk.name
                for lk in _held()
                if lk is not self and lk.family not in allowed
            ]
            if others:
                _record(
                    "wait-under-lock",
                    f"condition wait on {self.name} while holding {others}",
                )
        count = _held().pop(self, 0)
        # The wait releases this lock: close out the hold now so parked
        # time is not billed as held time.
        t0 = _hold_t0().pop(self, None)
        if t0 is not None:
            _record_hold(self, time.monotonic_ns() - t0)
        return (self._inner._release_save(), count)

    def _acquire_restore(self, state) -> None:
        inner_state, count = state
        self._inner._acquire_restore(inner_state)
        if count:
            _held()[self] = count
            _hold_t0()[self] = time.monotonic_ns()

    def held_by_current_thread(self) -> bool:
        return self in _held()


def make_lock(name: str):
    """The repo's lock factory: tracked when the detector is on, plain RLock
    otherwise (zero overhead in production)."""
    if _REG.enabled:
        return TrackedRLock(name)
    return threading.RLock()


def _report_at_exit() -> None:
    vs = violations()
    if vs:
        print(
            f"REPRO_LOCK_CHECK: {len(vs)} concurrency violation(s) detected:",
            file=sys.stderr,
        )
        for v in vs:
            print(f"  [{v['kind']}] ({v['thread']}) {v['msg']}", file=sys.stderr)
    stats = hold_stats()
    if stats:
        top = sorted(stats.items(), key=lambda kv: kv[1]["max_ns"], reverse=True)
        print("REPRO_LOCK_CHECK: lock hold times (top families by max):", file=sys.stderr)
        for fam, st in top[:8]:
            print(
                f"  {fam:12s} holds={st['count']:<8d}"
                f" mean={st['mean_ns'] / 1e3:8.1f}us"
                f" max={st['max_ns'] / 1e3:10.1f}us ({st['max_lock']})",
                file=sys.stderr,
            )
    warns = hold_warnings()
    if warns:
        print(
            f"REPRO_LOCK_CHECK: {len(warns)} hold(s) exceeded the warn"
            f" threshold ({_REG.hold_warn_ns / 1e6:.0f}ms):",
            file=sys.stderr,
        )
        for w in warns[:10]:
            print(
                f"  {w['lock']} held {w['held_ns'] / 1e6:.1f}ms ({w['thread']})",
                file=sys.stderr,
            )


if _REG.enabled:  # pragma: no cover - exercised via REPRO_LOCK_CHECK=1 runs
    atexit.register(_report_at_exit)
