"""Repo-specific static concurrency/hygiene lint (stdlib ``ast`` only).

Run as ``python -m repro.analysis.lint [paths...]`` (default:
``src/repro`` plus ``benchmarks`` and ``examples`` when present). Exits
non-zero on any violation; there is no suppression mechanism — rules are
written so the repo passes with zero exceptions, and a new violation
means the code (not the lint) should change.

Rules:

* **LNT001 kv-list-scan** — no ``kv_list`` call outside ``_migrate*``
  functions. Every hot path must use an indexed first-class table
  (processes, cfs_files, crons, generators, ...); ``kv_list`` is a full
  table scan and exists only so sqlite migrations can drain legacy rows.
* **LNT002 blocking-under-glock** — inside a ``with ..._glock:`` block:
  no ``time.sleep``, no ``.wait(...)``/``.join(...)``/``.acquire(...)``,
  and no nested ``with`` on another lock. ``_glock`` is a leaf lock
  guarding dict lookups; blocking under it stalls every shard.
* **LNT003 bare-except** — ``except:`` swallows ``KeyboardInterrupt`` and
  ``SystemExit``; name the exception.
* **LNT004 mutable-default** — list/dict/set literals (or constructor
  calls) as parameter defaults are shared across calls.
* **LNT005 shard-lock-contract** — any function taking a parameter
  annotated ``_ColonyShard``/``_CfsShard`` (or any ``*Shard``) mutates
  shard state and must declare ``@requires_lock(...)``; the runtime
  detector then enforces the declaration.
"""

from __future__ import annotations

import ast
import os
import sys

DEFAULT_PATHS = ("src/repro", "benchmarks", "examples")

_BLOCKING_ATTRS = {"wait", "join", "acquire"}


class Violation:
    __slots__ = ("path", "line", "rule", "msg")

    def __init__(self, path: str, line: int, rule: str, msg: str) -> None:
        self.path = path
        self.line = line
        self.rule = rule
        self.msg = msg

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.msg}"


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an expression ('self._glock', 'time.sleep')."""
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Call):
        return _dotted(node.func)
    return ""


def _mentions_glock(node: ast.AST) -> bool:
    return any(
        (isinstance(n, ast.Attribute) and n.attr == "_glock")
        or (isinstance(n, ast.Name) and n.id == "_glock")
        for n in ast.walk(node)
    )


def _decorator_names(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> set[str]:
    out = set()
    for d in fn.decorator_list:
        name = _dotted(d)
        out.add(name.rsplit(".", 1)[-1])
    return out


def _annotation_name(ann: ast.AST | None) -> str:
    if ann is None:
        return ""
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        return ann.value  # from __future__ import annotations keeps strings rare
    return _dotted(ann)


def _iter_args(fn: ast.FunctionDef | ast.AsyncFunctionDef):
    a = fn.args
    yield from a.posonlyargs
    yield from a.args
    yield from a.kwonlyargs


def _check_kv_list(tree: ast.Module, path: str, out: list[Violation]) -> None:
    # Map every node to its enclosing function name to exempt migrations.
    def visit(node: ast.AST, fname: str) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fname = node.name
        for child in ast.iter_child_nodes(node):
            if (
                isinstance(child, ast.Call)
                and isinstance(child.func, ast.Attribute)
                and child.func.attr == "kv_list"
                and not fname.startswith("_migrate")
            ):
                out.append(
                    Violation(
                        path,
                        child.lineno,
                        "LNT001",
                        "kv_list is a full-table scan; use an indexed table"
                        " (allowed only inside _migrate* functions)",
                    )
                )
            visit(child, fname)

    visit(tree, "<module>")


def _check_glock_blocking(tree: ast.Module, path: str, out: list[Violation]) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, ast.With):
            continue
        if not any(_mentions_glock(item.context_expr) for item in node.items):
            continue
        for inner in node.body:
            for sub in ast.walk(inner):
                if isinstance(sub, ast.Call):
                    name = _dotted(sub.func)
                    leaf = name.rsplit(".", 1)[-1]
                    if name == "time.sleep" or leaf in _BLOCKING_ATTRS:
                        out.append(
                            Violation(
                                path,
                                sub.lineno,
                                "LNT002",
                                f"{name or leaf}() under _glock: the registry"
                                " lock is a leaf and must never block",
                            )
                        )
                if isinstance(sub, ast.With):
                    for item in sub.items:
                        name = _dotted(item.context_expr)
                        if name.endswith(".lock") or name.endswith("colony_lock"):
                            out.append(
                                Violation(
                                    path,
                                    sub.lineno,
                                    "LNT002",
                                    f"acquiring {name} under _glock: _glock is"
                                    " a leaf lock (see CONCURRENCY.md)",
                                )
                            )


def _check_bare_except(tree: ast.Module, path: str, out: list[Violation]) -> None:
    for node in ast.walk(tree):
        if isinstance(node, ast.ExceptHandler) and node.type is None:
            out.append(
                Violation(
                    path,
                    node.lineno,
                    "LNT003",
                    "bare except swallows KeyboardInterrupt/SystemExit;"
                    " name the exception",
                )
            )


def _check_mutable_defaults(tree: ast.Module, path: str, out: list[Violation]) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        defaults = list(node.args.defaults) + [
            d for d in node.args.kw_defaults if d is not None
        ]
        for d in defaults:
            bad = isinstance(d, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(d, ast.Call)
                and isinstance(d.func, ast.Name)
                and d.func.id in ("list", "dict", "set")
            )
            if bad:
                out.append(
                    Violation(
                        path,
                        d.lineno,
                        "LNT004",
                        f"mutable default argument in {node.name}() is shared"
                        " across calls",
                    )
                )


def _check_shard_contracts(tree: ast.Module, path: str, out: list[Violation]) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        shard_args = [
            a.arg
            for a in _iter_args(node)
            if _annotation_name(a.annotation).rsplit(".", 1)[-1].endswith("Shard")
        ]
        if shard_args and "requires_lock" not in _decorator_names(node):
            out.append(
                Violation(
                    path,
                    node.lineno,
                    "LNT005",
                    f"{node.name}() takes shard argument"
                    f" {shard_args[0]!r} (lock-guarded mutable state) but"
                    " declares no @requires_lock contract",
                )
            )


def lint_source(src: str, path: str) -> list[Violation]:
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Violation(path, e.lineno or 0, "LNT000", f"syntax error: {e.msg}")]
    out: list[Violation] = []
    _check_kv_list(tree, path, out)
    _check_glock_blocking(tree, path, out)
    _check_bare_except(tree, path, out)
    _check_mutable_defaults(tree, path, out)
    _check_shard_contracts(tree, path, out)
    return out


def _py_files(paths: list[str]) -> list[str]:
    files: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
        else:
            for root, _dirs, names in os.walk(p):
                files.extend(
                    os.path.join(root, n) for n in names if n.endswith(".py")
                )
    return sorted(files)


def run(paths: list[str] | None = None) -> tuple[int, list[Violation]]:
    if not paths:
        paths = [p for p in DEFAULT_PATHS if os.path.exists(p)]
    files = _py_files(paths)
    violations: list[Violation] = []
    for f in files:
        with open(f, "r", encoding="utf-8") as fh:
            violations.extend(lint_source(fh.read(), f))
    return len(files), violations


def main(argv: list[str] | None = None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    nfiles, vs = run(args)
    for v in vs:
        print(v)
    if vs:
        print(f"repro.analysis.lint: {len(vs)} violation(s) in {nfiles} files")
        return 1
    print(f"repro.analysis.lint: OK ({nfiles} files clean)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
