"""Runtime auth-fact contracts (the dynamic half of the authorization lint).

The zero-trust protocol (paper §3.4.6) is only as strong as the weakest
`_h_*` handler: each one must call `_require_member` / owner / executor
checks before touching the database, and nothing at runtime used to
verify that it did. This module turns the verified identity into an
explicit **auth fact** and makes colony-scoped database access refuse to
run without one — so a future handler that forgets its check fails hard
in CI instead of silently bypassing authorization.

Mechanics, mirroring :mod:`repro.analysis.locktrack`:

* Disabled (the default), everything here is a cheap flag check — no
  context is created and no fact is recorded. Enabled via
  ``REPRO_AUTH_CHECK=1`` or :func:`enable`:
* :func:`request_scope` — entered by ``ColoniesServer.handle`` around
  handler dispatch. Inside a scope the fact set starts empty; outside a
  scope (background failsafe/cron/generator ticks, Raft applies, direct
  database use in tests and benchmarks) the guards are inert, because
  those paths have no request identity to verify.
* :func:`record` — called by the server's ``_require_*`` helpers after a
  check passes, recording ``(identity, colony, role)`` in the
  request-scoped context (a ``contextvars.ContextVar``, so concurrent
  long-poll requests on different threads never share facts).
* :func:`check_colony` — invoked by the colony-scoped ``Database`` entry
  points (wired up in ``Database.__init_subclass__``): inside a request
  scope, touching colony X's rows without a recorded fact for X raises
  :class:`AuthContractError`.
* :func:`requires_auth` — decorator for handler internals
  (``close_process``, ``submit_workflow_processes``): entering one inside
  a request scope without a fact of (at least) the declared role raises.

Roles form the paper's Table 5 lattice: ``server`` (server owner,
recorded with the wildcard colony ``"*"``) satisfies everything,
``owner`` satisfies ``member``, ``executor`` satisfies ``member``, and
``member`` is the floor. Contract violations *raise* (like
contracts.py, unlike the lock detector): they guard single well-defined
boundaries where an exception is a correct hard failure.
"""

from __future__ import annotations

import contextlib
import contextvars
import functools
import os
from typing import Callable

#: roles that satisfy a requirement for the key role
ROLE_SATISFIED_BY = {
    "member": frozenset({"member", "executor", "owner", "server"}),
    "executor": frozenset({"executor", "server"}),
    "owner": frozenset({"owner", "server"}),
    "server": frozenset({"server"}),
}

#: the wildcard colony recorded by a server-owner fact
ANY_COLONY = "*"


class AuthContractError(AssertionError):
    """A database access or handler internal ran without a matching
    recorded auth fact — a missed/bypassed authorization check."""


class _Registry:
    def __init__(self) -> None:
        self.enabled = os.environ.get("REPRO_AUTH_CHECK", "") not in ("", "0")


_REG = _Registry()

# The facts for the current request: a tuple of (identity, colony, role).
# None = not inside a request scope (guards inert).
_FACTS: contextvars.ContextVar[tuple | None] = contextvars.ContextVar(
    "repro_auth_facts", default=None
)


def is_enabled() -> bool:
    return _REG.enabled


def enable(on: bool = True) -> None:
    """Toggle checking at runtime (tests)."""
    _REG.enabled = on


def in_request() -> bool:
    """True when the current context is inside a handler dispatch."""
    return _REG.enabled and _FACTS.get() is not None


def facts() -> tuple:
    """The current request's recorded facts (empty outside a scope)."""
    return _FACTS.get() or ()


@contextlib.contextmanager
def request_scope():
    """Mark handler dispatch: facts start empty, guards become active."""
    if not _REG.enabled:
        yield
        return
    token = _FACTS.set(())
    try:
        yield
    finally:
        _FACTS.reset(token)


def record(identity: str, colony: str, role: str) -> None:
    """Record a verified (identity, colony, role) fact for this request.

    Called by the server's ``_require_*`` helpers immediately after the
    check passes. Outside a request scope (or disabled) this is a no-op.
    """
    if not _REG.enabled:
        return
    cur = _FACTS.get()
    if cur is None:
        return
    fact = (identity, colony, role)
    if fact not in cur:
        _FACTS.set(cur + (fact,))


def has_fact(colony: str | None = None, role: str = "member") -> bool:
    """Does the current request hold a fact for ``colony`` at ``role``?

    ``colony=None`` checks role only; a ``server`` fact (colony ``"*"``)
    matches any colony.
    """
    ok_roles = ROLE_SATISFIED_BY[role]
    for _ident, fcolony, frole in _FACTS.get() or ():
        if frole not in ok_roles:
            continue
        if colony is None or fcolony == colony or fcolony == ANY_COLONY:
            return True
    return False


def check_colony(method: str, colony: str) -> None:
    """Guard for colony-scoped Database entry points.

    Active only inside a request scope: raises unless the request
    recorded an auth fact for ``colony`` (any role — role placement is
    the handler's job, enforced by authlint + :func:`requires_auth`).
    """
    cur = _FACTS.get()
    if cur is None:
        return
    if has_fact(colony):
        return
    raise AuthContractError(
        f"Database.{method} touched colony {colony!r} with no recorded auth"
        f" fact for it (facts: {[(c, r) for _i, c, r in cur]}) — a handler"
        " skipped its _require_* check (see SECURITY.md)"
    )


def requires_auth(role: str = "member") -> Callable:
    """Declare that a handler internal runs only after a ``role`` fact.

    Inert outside request scopes (leader ticks, failsafe, Raft applies
    legitimately run these functions with no request identity).
    """
    if role not in ROLE_SATISFIED_BY:
        raise ValueError(f"unknown auth role {role!r}")

    def deco(fn: Callable) -> Callable:
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if _REG.enabled and _FACTS.get() is not None and not has_fact(None, role):
                raise AuthContractError(
                    f"{fn.__qualname__} requires a recorded {role!r} auth fact"
                    f" (facts: {[(c, r) for _i, c, r in _FACTS.get() or ()]})"
                )
            return fn(*args, **kwargs)

        wrapper.__auth_contract__ = role
        return wrapper

    return deco


# ---------------------------------------------------------------------------
# Database wiring
# ---------------------------------------------------------------------------

# Colony-scoped Database entry points and how to pull the colony out of
# their positional args (index past self). "attr"/"key" reach into the
# Process/Executor object or entry dict those methods take. Id-keyed
# fetches (get_process, get_executor, cron_get, user_get, kv_get, ...)
# are deliberately absent: they are the allowed "fetch" half of the
# fetch-then-authorize pattern (authlint AUT004 polices their ordering).
GUARDED_DB_METHODS: dict[str, tuple] = {
    # colony string in positional args
    "list_executors": ("arg", 0),
    "list_functions": ("arg", 0),
    "add_function": ("arg", 1),
    "list_processes": ("arg", 0),
    "candidates": ("arg", 0),
    "colony_stats": ("arg", 0),
    "user_list": ("arg", 0),
    "cfs_get_file": ("arg", 0),
    "cfs_get_files_by_ids": ("arg", 0),
    "cfs_head": ("arg", 0),
    "cfs_list": ("arg", 0),
    "cfs_remove_file": ("arg", 0),
    "cfs_pin_count": ("arg", 0),
    "cfs_get_snapshot": ("arg", 0),
    "cfs_list_snapshots": ("arg", 0),
    "cfs_remove_snapshot": ("arg", 0),
    "cron_list": ("arg", 0),
    "generator_list": ("arg", 0),
    # colony on an object attribute
    "add_process": ("attr", 0, "colonyname"),
    "update_process": ("attr", 0, "colonyname"),
    "requeue": ("attr", 0, "colonyname"),
    "add_executor": ("attr", 0, "colonyname"),
    "add_colony": ("attr", 0, "colonyname"),
    # colony under a dict key
    "cfs_add_file": ("key", 0, "colonyname"),
    "cfs_create_snapshot": ("key", 0, "colonyname"),
    "cron_put": ("key", 0, "colonyname"),
    "generator_put": ("key", 0, "colonyname"),
    "user_put": ("key", 0, "colonyname"),
}


def _extract_colony(spec: tuple, args: tuple) -> str | None:
    kind, idx = spec[0], spec[1]
    if idx >= len(args):
        return None  # kwargs-only call: nothing to check against
    val = args[idx]
    if kind == "arg":
        return val if isinstance(val, str) else None
    if kind == "attr":
        return getattr(val, spec[2], None)
    if kind == "key":
        try:
            return val.get(spec[2])
        except AttributeError:
            return None
    return None


def guard_db_method(name: str, fn: Callable) -> Callable:
    """Wrap one Database entry point with the colony auth-fact guard."""
    spec = GUARDED_DB_METHODS[name]

    @functools.wraps(fn)
    def wrapper(self, *args, **kwargs):
        if _REG.enabled and _FACTS.get() is not None:
            colony = _extract_colony(spec, args)
            if colony:
                check_colony(name, colony)
        return fn(self, *args, **kwargs)

    wrapper.__auth_guarded__ = True
    return wrapper


def guard_database_subclass(cls) -> None:
    """Called from ``Database.__init_subclass__``: wrap every guarded
    entry point the subclass defines (inherited wrappers stay wrapped)."""
    for name in GUARDED_DB_METHODS:
        fn = cls.__dict__.get(name)
        if fn is None or getattr(fn, "__auth_guarded__", False):
            continue
        setattr(cls, name, guard_db_method(name, fn))
