"""Idempotency-classification drift gate (stdlib ``ast`` only).

Run as ``python -m repro.analysis.idemlint [paths...]`` (default:
``src/repro``). Exits non-zero on any violation; there is no suppression
mechanism — a new violation means the code or the spec should change.

Retrying transports deliver RPCs at least once; exactly-once *effect*
depends on every payloadtype being correctly classified in
``repro.core.idempotency.SPEC`` (KEYED / NATURAL / READ — see
ROBUSTNESS.md). This lint proves the spec matches the dispatch tables:

* **IDM001 unclassified** — a payloadtype registered in a handler table
  (``{"ptype": self._h_x}`` dict literal, server or extension) has no
  entry in the SPEC literal. An unclassified mutating RPC silently gets
  READ semantics: the client stamps no msgid, a retry duplicates state.
* **IDM002 mutating-read** — a handler whose call cone (transitively,
  through ``self.<m>`` / ``self.server.<m>`` methods) reaches a
  database mutator is classified READ. Same failure shape as IDM001,
  but for a mis-filed entry rather than a missing one.
* **IDM003 stale-spec** — a SPEC entry names a payloadtype no handler
  table registers: dead weight that misdocuments the RPC surface.
* **IDM004 keyed-read-only** — a handler that never reaches a database
  mutator is classified KEYED or NATURAL: every such call pays a dedup
  write (KEYED) for an effect that cannot duplicate, hiding the real
  hot-path cost the benchmark gate bounds.

Heartbeat writes (``touch_executor``) and the dedup table's own
bookkeeping (``dedup_put``) are not mutators here: they are read-path
side effects whose duplication is harmless by construction.
"""

from __future__ import annotations

import ast
import os
import sys

DEFAULT_PATHS = ("src/repro",)

# Database writes whose duplication an RPC retry must not produce.
# Deliberately broader than replint.DB_MUTATORS (which only tracks
# replica-observable process writes): any persistent state counts here.
MUTATORS = frozenset(
    {
        "add_colony",
        "add_executor",
        "set_executor_state",
        "remove_executor",
        "add_function",
        "add_process",
        "update_process",
        "requeue",
        "delete_process",
        "cron_put",
        "cron_del",
        "generator_put",
        "generator_del",
        "user_put",
        "user_del",
        "kv_put",
        "kv_del",
        "kv_append",
        "kv_take_all",
        "cfs_add_file",
        "cfs_remove_file",
        "cfs_create_snapshot",
        "cfs_remove_snapshot",
        "_write_process",
        "executemany",
    }
)

# Read-path side effects exempt from MUTATORS (duplication harmless).
EXEMPT = frozenset({"touch_executor", "dedup_put", "dedup_get"})


class Violation:
    __slots__ = ("path", "line", "rule", "msg")

    def __init__(self, path: str, line: int, rule: str, msg: str) -> None:
        self.path = path
        self.line = line
        self.rule = rule
        self.msg = msg

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.msg}"


def _dotted(node: ast.AST) -> str:
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Call):
        return _dotted(node.func)
    return ""


def _method_calls(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> tuple[bool, set[str]]:
    """(mutates directly?, bare self./self.server. callee names)."""
    mutates = False
    calls: set[str] = set()
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        parts = _dotted(node.func).split(".")
        leaf = parts[-1]
        if (
            len(parts) >= 3
            and parts[0] == "self"
            and parts[-2] in ("db", "_db", "_conn")
            and leaf in MUTATORS
            and leaf not in EXEMPT
        ):
            mutates = True
        elif parts[0] == "self" and leaf in MUTATORS and leaf not in EXEMPT:
            # direct private helpers like self._write_process(...)
            mutates = True
        elif parts[0] == "self" and (
            len(parts) == 2 or (len(parts) == 3 and parts[1] == "server")
        ):
            calls.add(leaf)
    return mutates, calls


def analyze(sources: list[tuple[str, str]]) -> list[Violation]:
    out: list[Violation] = []
    registered: dict[str, tuple[str, str, int]] = {}  # ptype -> (path, handler, line)
    spec: dict[str, str] = {}
    spec_site: tuple[str, int] = ("", 0)
    methods: dict[str, tuple[bool, set[str]]] = {}
    handler_site: dict[str, tuple[str, int]] = {}

    for path, src in sources:
        try:
            tree = ast.parse(src, filename=path)
        except SyntaxError as e:
            out.append(Violation(path, e.lineno or 0, "IDM000", f"syntax error: {e.msg}"))
            continue
        for cls in ast.walk(tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            for fn in cls.body:
                if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                mutates, calls = _method_calls(fn)
                prev = methods.get(fn.name)
                if prev is not None:  # same-named methods merge conservatively
                    mutates = mutates or prev[0]
                    calls = calls | prev[1]
                methods[fn.name] = (mutates, calls)
                handler_site.setdefault(fn.name, (path, fn.lineno))
        for node in ast.walk(tree):
            if not isinstance(node, ast.Dict):
                continue
            for k, v in zip(node.keys, node.values):
                if not (isinstance(k, ast.Constant) and isinstance(k.value, str)):
                    continue
                if isinstance(v, ast.Attribute) and v.attr.startswith("_h_"):
                    registered[k.value] = (path, v.attr, k.lineno or 0)
                elif (
                    path.endswith("idempotency.py")
                    and isinstance(v, ast.Name)
                    and v.id in ("KEYED", "NATURAL", "READ")
                ):
                    spec[k.value] = v.id.lower()
                    spec_site = (path, k.lineno or 0)

    # Propagate mutation through the call graph to a fixpoint.
    changed = True
    while changed:
        changed = False
        for name, (mutates, calls) in methods.items():
            if mutates:
                continue
            if any(methods.get(c, (False, set()))[0] for c in calls):
                methods[name] = (True, calls)
                changed = True

    if not spec:
        out.append(
            Violation(
                "src/repro/core/idempotency.py",
                0,
                "IDM000",
                "no SPEC literal found (idempotency.py missing or rewritten"
                " without the payloadtype classification dict)",
            )
        )
        return out

    for ptype, (path, handler, line) in sorted(registered.items()):
        cls = spec.get(ptype)
        mutates = methods.get(handler, (False, set()))[0]
        if cls is None:
            out.append(
                Violation(
                    path,
                    line,
                    "IDM001",
                    f"payloadtype {ptype!r} ({handler}) is not classified in"
                    " idempotency.SPEC — a retried call would silently get"
                    " READ semantics",
                )
            )
            continue
        if mutates and cls == "read":
            out.append(
                Violation(
                    path,
                    line,
                    "IDM002",
                    f"payloadtype {ptype!r} ({handler}) reaches a database"
                    " mutator but is classified READ — retries can duplicate"
                    " its effect",
                )
            )
        elif not mutates and cls != "read":
            out.append(
                Violation(
                    path,
                    line,
                    "IDM004",
                    f"payloadtype {ptype!r} ({handler}) never reaches a"
                    f" database mutator but is classified {cls.upper()}",
                )
            )
    for ptype in sorted(set(spec) - set(registered)):
        out.append(
            Violation(
                spec_site[0],
                spec_site[1],
                "IDM003",
                f"idempotency.SPEC classifies {ptype!r} but no handler table"
                " registers it (stale entry)",
            )
        )
    return out


def lint_source(src: str, path: str) -> list[Violation]:
    """Single-source convenience (rule fixtures in tests)."""
    return analyze([(path, src)])


def _py_files(paths: list[str]) -> list[str]:
    files: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
        else:
            for root, _dirs, names in os.walk(p):
                files.extend(os.path.join(root, n) for n in names if n.endswith(".py"))
    return sorted(files)


def run(paths: list[str] | None = None) -> tuple[int, list[Violation]]:
    if not paths:
        paths = [p for p in DEFAULT_PATHS if os.path.exists(p)]
    files = _py_files(paths)
    sources = []
    for f in files:
        with open(f, "r", encoding="utf-8") as fh:
            sources.append((f, fh.read()))
    return len(files), analyze(sources)


def main(argv: list[str] | None = None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    nfiles, vs = run(args)
    for v in vs:
        print(v)
    if vs:
        print(f"repro.analysis.idemlint: {len(vs)} violation(s) in {nfiles} files")
        return 1
    print(f"repro.analysis.idemlint: OK ({nfiles} files clean)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
