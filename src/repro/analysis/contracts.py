"""Declared lock contracts — the static half of "called with the lock held".

``@requires_lock("shard")`` replaces the database.py comment-contract
("all called with the shard lock held") with a declaration that is

* **asserted at runtime** when the detector is enabled (REPRO_LOCK_CHECK=1
  or :func:`repro.analysis.locktrack.enable`): entering the function on a
  thread that does not hold the named lock raises
  :class:`LockContractError`;
* **checked statically** by ``python -m repro.analysis.lint``: every
  database method that takes a shard parameter must carry one.

``@no_locks_held(...)`` is the dual: the function blocks (long-poll wait,
Raft commit wait, failsafe scan) and must not be entered while holding
the named lock families — with no families given, while holding *any*
tracked lock. This encodes the PR-1 deadlock fix as a contract: a Raft
proposal must never happen under a database lock, because the commit is
applied on another thread that needs those same locks.

Disabled, both decorators cost one attribute load and branch per call —
the wrapped function is otherwise a pass-through.
"""

from __future__ import annotations

import functools
from typing import Callable

from . import locktrack
from .locktrack import TrackedRLock, _REG, _held

# Attributes probed (in order) on each positional argument to find the
# lock instance a contract refers to: shard objects expose ``.lock``,
# databases expose ``._glock`` / ``._lock``.
_LOCK_ATTRS = ("lock", "_glock", "_lock")


class LockContractError(AssertionError):
    """A function's declared lock contract was violated at runtime."""


def _locate(family: str, args: tuple) -> TrackedRLock | None:
    for a in args:
        for attr in _LOCK_ATTRS:
            lk = getattr(a, attr, None)
            if isinstance(lk, TrackedRLock) and lk.family == family:
                return lk
    return None


def requires_lock(
    family: str, getter: Callable[..., object] | None = None
) -> Callable:
    """Declare that the decorated function runs with a ``family`` lock held.

    The lock instance is found by scanning the positional arguments for an
    object whose ``.lock`` / ``._glock`` / ``._lock`` is a tracked lock of
    that family (shard methods receive the shard; sqlite methods receive
    ``self``), or via an explicit ``getter(*args, **kwargs)``. Objects
    created while the detector was off carry plain RLocks and are skipped
    — the contract only binds where it can be checked.
    """

    def deco(fn: Callable) -> Callable:
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if _REG.enabled:
                lk = getter(*args, **kwargs) if getter is not None else _locate(family, args)
                if isinstance(lk, TrackedRLock) and lk not in _held():
                    raise LockContractError(
                        f"{fn.__qualname__} requires {lk.name} held"
                        f" (declared @requires_lock({family!r}))"
                    )
            return fn(*args, **kwargs)

        wrapper.__lock_contract__ = ("requires", family)
        return wrapper

    return deco


def no_locks_held(*families: str) -> Callable:
    """Declare that the decorated (blocking) function must be entered with
    no tracked locks of the given families held — or none at all when
    called as ``@no_locks_held()``."""

    def deco(fn: Callable) -> Callable:
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if _REG.enabled:
                bad = [
                    lk.name
                    for lk in _held()
                    if not families or lk.family in families
                ]
                if bad:
                    raise LockContractError(
                        f"{fn.__qualname__} may block but was entered holding {bad}"
                        f" (declared @no_locks_held{families or ''})"
                    )
            return fn(*args, **kwargs)

        wrapper.__lock_contract__ = ("forbids", families)
        return wrapper

    return deco


# Re-exported for convenience so call sites import one module.
enable = locktrack.enable
is_enabled = locktrack.is_enabled
