"""Replication-safety lint: the apply cone must be deterministic
(stdlib ``ast`` only).

Run as ``python -m repro.analysis.replint [paths...]`` (default:
``src/repro`` plus ``examples`` when present). Exits non-zero on any
violation; there is no suppression mechanism — rules are written so the
repo passes with zero exceptions, and a new violation means the code
(not the lint) should change.

The paper's HA deployment (§3.4.1, Fig. 3) serializes broker mutations
through a Raft log; replicas stay interchangeable across failover only
if every applied op is **deterministic** (same entry ⇒ same state on
every node) and **idempotent** (replay ⇒ no-op). This lint computes the
**apply cone** — every function reachable from a replicated-op apply
handler (the ``apply`` qualnames in the ``REPLICATED_OPS`` literal in
``core/cluster.py``, plus any method named ``_apply``) — and proves the
cone free of divergence sources, interprocedurally to a fixpoint like
``authlint``.

Rules:

* **REP001 nondeterministic-call** — the cone calls a wall-clock or
  randomness source (``time.*``, ``now_ns``, ``random.*``, ``uuid4``,
  ``new_id``, ``os.urandom``, ...). Nondeterministic values must be
  fixed *before* the Raft log as leader-stamped entry fields, the way
  ``apply_assign`` reads ``op["ts"]`` instead of calling ``now_ns()``.
* **REP002 unordered-iteration** — a loop over an unordered collection
  (``set(...)``, ``.values()`` / ``.keys()`` / ``.items()`` not wrapped
  in ``sorted``) whose body issues a database write: iteration order
  would flow into replicated state. (Python dicts preserve insertion
  order, but insertion order itself differs across replicas that
  observed events in different sequences — only sorted iteration is
  replay-stable.)
* **REP003 unguarded-mutation** — a ``self.db`` / ``self._db`` write in
  the cone that is not CAS-guarded: the call must sit inside a
  ``colony_lock`` ``with`` block with a ``.state`` compare lexically
  before it, or (for helpers) the helper must carry its own ``.state``
  compare and be called from the cone only inside such guarded blocks.
  The CAS is what turns a Raft replay into a clean conflict instead of
  a double mutation.
* **REP004 unstamped-propose** — a ``propose`` / ``propose_and_wait`` /
  ``_propose_*`` call site whose entry resolves to a dict literal
  missing a leader-stamped ``ts`` or a stable ``opid``: the apply would
  have to improvise them per replica. Bare-parameter forwarding (a
  propose wrapper passing its own argument through) is exempt — the
  stamping obligation sits with whoever builds the literal.
* **REP005 environment-dependence** — the cone reads ``os.environ`` /
  ``os.getenv``, opens files, spawns threads or subprocesses, or
  touches sockets: replica-local context that has no place in a
  replicated state transition.

Static limitations (documented, deliberate): the call graph is
name-keyed on bare method names — ``self.db.X`` / ``self._db.X``
resolves only into ``*Database*`` classes, ``self.X`` prefers the
caller's own class, anything else joins every definition of that name
except builtin-colliding leaves (``.get``, ``.items``, ...) which never
create edges — and constructor bodies are not followed. The runtime half
(:mod:`repro.analysis.statehash` under ``REPRO_REPL_CHECK=1``) catches
what static analysis cannot: journal cross-checks between replicas and
the double-apply idempotence harness.
"""

from __future__ import annotations

import ast
import os
import sys

DEFAULT_PATHS = ("src/repro", "examples")

# REP001: wall-clock / randomness sources. Dotted prefixes catch module
# calls (time.time, random.random, uuid.uuid4, secrets.token_hex);
# leaves catch the repo's own wrappers and bound-method forms.
NONDET_PREFIXES = ("time.", "random.", "uuid.", "secrets.", "os.urandom")
NONDET_LEAVES = frozenset(
    {
        "now_ns",
        "new_id",
        "token_hex",
        "token_bytes",
        "urandom",
        "uuid4",
        "getrandbits",
        "randint",
        "random",
        "choice",
        "shuffle",
        "monotonic",
        "monotonic_ns",
        "time_ns",
        "perf_counter",
    }
)

# REP002/REP003: database writes observable by other replicas.
DB_MUTATORS = frozenset(
    {
        "add_process",
        "update_process",
        "requeue",
        "delete_process",
        "user_put",
        "cron_put",
        "generator_put",
        "cfs_add_file",
        "cfs_remove_file",
        "cfs_create_snapshot",
        "cfs_remove_snapshot",
        "_write_process",
        "_exec",
        "executemany",
    }
)

# REP004: proposal entry points.
PROPOSE_LEAVES = frozenset({"propose", "propose_and_wait"})

# REP005: replica-local environment / IO.
ENV_PREFIXES = (
    "os.environ",
    "os.getenv",
    "subprocess.",
    "socket.",
    "threading.Thread",
)
ENV_LEAVES = frozenset({"getenv", "open", "Thread", "Popen", "input"})

# Leaves that collide with builtin container/str methods: ``x.get(...)``
# on a dict must not resolve into some class's ``def get``. Calls with
# these leaves never create interprocedural edges (a genuine helper
# behind one of these names would need an unambiguous name anyway).
GENERIC_LEAVES = frozenset(
    {
        "get",
        "items",
        "keys",
        "values",
        "append",
        "extend",
        "pop",
        "popleft",
        "add",
        "discard",
        "remove",
        "clear",
        "copy",
        "update",
        "setdefault",
        "sort",
        "split",
        "rsplit",
        "join",
        "strip",
        "format",
        "encode",
        "decode",
    }
)


class Violation:
    __slots__ = ("path", "line", "rule", "msg")

    def __init__(self, path: str, line: int, rule: str, msg: str) -> None:
        self.path = path
        self.line = line
        self.rule = rule
        self.msg = msg

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.msg}"


def _dotted(node: ast.AST) -> str:
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Call):
        return _dotted(node.func)
    return ""


# ---------------------------------------------------------------------------
# REPLICATED_OPS literal (shared with replmap)
# ---------------------------------------------------------------------------


def _literal(node: ast.AST):
    try:
        return ast.literal_eval(node)
    except (ValueError, SyntaxError):
        return None


def collect_ops(sources: list[tuple[str, str]]) -> dict[str, dict]:
    """Parse the ``REPLICATED_OPS`` dict literal out of the sources.

    The matrix is data, not code — keeping it a pure literal means the
    lint, the doc generator, and the cluster dispatch all read the same
    single source of truth.
    """
    for path, src in sources:
        try:
            tree = ast.parse(src, filename=path)
        except SyntaxError:
            continue
        for node in ast.walk(tree):
            target = None
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                target, value = node.targets[0], node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                target, value = node.target, node.value
            else:
                continue
            if isinstance(target, ast.Name) and target.id == "REPLICATED_OPS":
                ops = _literal(value)
                if isinstance(ops, dict):
                    return ops
    return {}


# ---------------------------------------------------------------------------
# Per-function scan
# ---------------------------------------------------------------------------


class _Call:
    """One call site, with enough context for every REP rule."""

    __slots__ = ("dotted", "leaf", "base", "line", "in_guard", "node")

    def __init__(self, dotted: str, line: int, in_guard: bool, node: ast.Call) -> None:
        self.dotted = dotted
        parts = dotted.split(".")
        self.leaf = parts[-1]
        self.base = ".".join(parts[:-1])
        self.line = line
        self.in_guard = in_guard
        self.node = node


class _FnScan:
    """Ordered single-pass scan of one function body.

    Tracks, lexically: calls (with whether each sits inside a
    ``colony_lock`` ``with``), ``.state`` compares, unordered loops with
    db writes in their bodies, and dict-literal assignments (for REP004
    entry resolution).
    """

    def __init__(self, fn, classname: str, path: str) -> None:
        self.fn = fn
        self.name = fn.name
        self.classname = classname
        self.path = path
        self.params = {
            a.arg
            for a in (
                list(fn.args.posonlyargs)
                + list(fn.args.args)
                + list(fn.args.kwonlyargs)
            )
        }
        self.calls: list[_Call] = []
        self.state_cmp_lines: list[int] = []
        self.unordered_writes: list[tuple[int, str]] = []  # (line, iter repr)
        self.env_reads: list[tuple[str, int]] = []  # non-call os.environ use
        self.dicts: dict[str, ast.Dict] = {}
        self._guard_depth = 0
        for stmt in fn.body:
            self._visit(stmt)

    # -- helpers ---------------------------------------------------------
    @staticmethod
    def _is_unordered_iter(node: ast.AST) -> str | None:
        """Name the unordered source iterated over, or None if ordered."""
        if isinstance(node, ast.Call):
            d = _dotted(node.func)
            leaf = d.split(".")[-1]
            if d == "sorted":
                return None  # sorted(...) makes any source replay-stable
            if d == "set" or leaf in ("values", "keys", "items"):
                return d
        return None

    def _visit(self, node: ast.AST) -> None:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and isinstance(
            node.targets[0], ast.Name
        ):
            self._visit(node.value)
            if isinstance(node.value, ast.Dict):
                self.dicts[node.targets[0].id] = node.value
            return
        if isinstance(node, ast.With):
            guard = any(
                _dotted(item.context_expr).endswith("colony_lock")
                for item in node.items
            )
            for item in node.items:
                self._visit(item.context_expr)
            if guard:
                self._guard_depth += 1
            for stmt in node.body:
                self._visit(stmt)
            if guard:
                self._guard_depth -= 1
            return
        if isinstance(node, ast.Compare):
            operands = [node.left] + list(node.comparators)
            if any(
                isinstance(o, ast.Attribute) and o.attr == "state" for o in operands
            ):
                self.state_cmp_lines.append(node.lineno)
            for child in ast.iter_child_nodes(node):
                self._visit(child)
            return
        if isinstance(node, (ast.For, ast.comprehension)):
            it = node.iter
            src = self._is_unordered_iter(it)
            body = node.body if isinstance(node, ast.For) else []
            if src is not None and self._body_writes(body):
                self.unordered_writes.append((node.lineno, src))
            for child in ast.iter_child_nodes(node):
                self._visit(child)
            return
        if isinstance(node, ast.Call):
            for arg in node.args:
                self._visit(arg)
            for kw in node.keywords:
                self._visit(kw.value)
            d = _dotted(node.func)
            if d:
                self.calls.append(_Call(d, node.lineno, self._guard_depth > 0, node))
            return
        if isinstance(node, ast.Attribute):
            d = _dotted(node)
            if d.startswith("os.environ"):
                self.env_reads.append((d, node.lineno))
            self._visit(node.value)
            return
        for child in ast.iter_child_nodes(node):
            self._visit(child)

    @staticmethod
    def _body_writes(body: list[ast.stmt]) -> bool:
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, ast.Call):
                    if _dotted(node.func).split(".")[-1] in DB_MUTATORS:
                        return True
        return False

    def state_cmp_before(self, line: int) -> bool:
        return any(l < line for l in self.state_cmp_lines)


# ---------------------------------------------------------------------------
# Whole-tree analysis
# ---------------------------------------------------------------------------


def _is_db_base(base: str) -> bool:
    return base.endswith(".db") or base.endswith("._db") or base in ("db", "_db")


class _Index:
    """All scanned functions, keyed for name-based call resolution."""

    def __init__(self) -> None:
        self.by_name: dict[str, list[_FnScan]] = {}
        self.by_class: dict[tuple[str, str], _FnScan] = {}

    def add(self, scan: _FnScan) -> None:
        self.by_name.setdefault(scan.name, []).append(scan)
        self.by_class[(scan.classname, scan.name)] = scan

    def resolve(self, caller: _FnScan, call: _Call) -> list[_FnScan]:
        if _is_db_base(call.base):
            return [
                s
                for s in self.by_name.get(call.leaf, ())
                if "Database" in s.classname
            ]
        if call.base == "self":
            own = self.by_class.get((caller.classname, call.leaf))
            if own is not None:
                return [own]
        if call.leaf in GENERIC_LEAVES:
            return []
        return self.by_name.get(call.leaf, [])


def analyze(sources: list[tuple[str, str]]) -> tuple[set[str], list[Violation]]:
    """Lint (path, source) pairs together; returns (cone names, violations)."""
    out: list[Violation] = []
    index = _Index()
    for path, src in sources:
        try:
            tree = ast.parse(src, filename=path)
        except SyntaxError as e:
            out.append(
                Violation(path, e.lineno or 0, "REP000", f"syntax error: {e.msg}")
            )
            continue
        for cls in ast.walk(tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            for fn in cls.body:
                if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    index.add(_FnScan(fn, cls.name, path))

    ops = collect_ops(sources)
    root_names = {"_apply"} | {
        spec["apply"].rsplit(".", 1)[-1]
        for spec in ops.values()
        if isinstance(spec, dict) and isinstance(spec.get("apply"), str)
    }

    # Apply cone: closure over resolved call edges from the roots.
    cone: set[int] = set()
    cone_scans: list[_FnScan] = []
    work = [
        s for name in sorted(root_names) for s in index.by_name.get(name, ())
    ]
    while work:
        scan = work.pop()
        if id(scan) in cone:
            continue
        cone.add(id(scan))
        cone_scans.append(scan)
        for call in scan.calls:
            work.extend(index.resolve(scan, call))

    # Cone call-sites per callee (REP003 helper discharge).
    callee_sites: dict[int, list[tuple[_FnScan, _Call]]] = {}
    for scan in cone_scans:
        for call in scan.calls:
            for target in index.resolve(scan, call):
                if id(target) in cone:
                    callee_sites.setdefault(id(target), []).append((scan, call))

    cone_names = {f"{s.classname}.{s.name}" for s in cone_scans}

    for scan in cone_scans:
        _check_cone_fn(scan, callee_sites, out)

    # REP004 applies everywhere a proposal is made, cone or not.
    for scans in index.by_name.values():
        for scan in scans:
            _check_proposes(scan, out)

    return cone_names, out


def _check_cone_fn(
    scan: _FnScan,
    callee_sites: dict[int, list[tuple[_FnScan, _Call]]],
    out: list[Violation],
) -> None:
    where = f"{scan.classname}.{scan.name}"
    for call in scan.calls:
        d, leaf = call.dotted, call.leaf
        if d.startswith(NONDET_PREFIXES) or leaf in NONDET_LEAVES:
            out.append(
                Violation(
                    scan.path,
                    call.line,
                    "REP001",
                    f"{where}: nondeterministic call {d}() in the apply cone —"
                    " stamp the value into the proposed entry on the leader"
                    " (the way apply_assign reads op[\"ts\"])",
                )
            )
        if d.startswith(ENV_PREFIXES) or (
            leaf in ENV_LEAVES and (call.base == "" or d.startswith(ENV_PREFIXES))
        ):
            out.append(
                Violation(
                    scan.path,
                    call.line,
                    "REP005",
                    f"{where}: {d}() depends on replica-local environment/IO"
                    " inside the apply cone",
                )
            )
        if leaf in DB_MUTATORS and _is_db_base(call.base):
            guarded = call.in_guard and scan.state_cmp_before(call.line)
            if not guarded:
                # Helper discharge: own CAS compare + only guarded call-sites.
                # Self-recursive sites inherit the entry guard and are
                # judged by the external callers instead.
                sites = [
                    site
                    for caller, site in callee_sites.get(id(scan), [])
                    if caller is not scan
                ]
                discharged = (
                    scan.state_cmp_before(call.line)
                    and sites
                    and all(site.in_guard for site in sites)
                )
                if not discharged:
                    out.append(
                        Violation(
                            scan.path,
                            call.line,
                            "REP003",
                            f"{where}: db.{leaf} in the apply cone is not"
                            " CAS-guarded (needs a .state compare inside a"
                            " colony_lock block — replay idempotence)",
                        )
                    )
    for d, line in scan.env_reads:
        out.append(
            Violation(
                scan.path,
                line,
                "REP005",
                f"{where}: {d} read depends on replica-local environment"
                " inside the apply cone",
            )
        )
    for line, src in scan.unordered_writes:
        out.append(
            Violation(
                scan.path,
                line,
                "REP002",
                f"{where}: iteration over unordered {src}() flows into a"
                " database write — wrap the source in sorted(...) so replay"
                " order is stable",
            )
        )


def _check_proposes(scan: _FnScan, out: list[Violation]) -> None:
    where = f"{scan.classname}.{scan.name}"
    for call in scan.calls:
        if not (call.leaf in PROPOSE_LEAVES or call.leaf.startswith("_propose")):
            continue
        entry = _entry_literal(scan, call.node)
        if entry is None:
            continue  # forwarded parameter / opaque value: obligation upstream
        keys = {
            k.value
            for k in entry.keys
            if isinstance(k, ast.Constant) and isinstance(k.value, str)
        }
        missing = sorted({"opid", "ts"} - keys)
        if missing:
            out.append(
                Violation(
                    scan.path,
                    call.line,
                    "REP004",
                    f"{where}: {call.dotted}() entry lacks leader-stamped"
                    f" field(s) {missing} — every replicated entry carries a"
                    " stable opid and a stamped ts",
                )
            )


def _entry_literal(scan: _FnScan, node: ast.Call) -> ast.Dict | None:
    """Resolve the proposed-entry argument to a dict literal, if possible."""
    for arg in reversed(node.args):
        if isinstance(arg, ast.Dict):
            return arg
        if isinstance(arg, ast.Name):
            if arg.id in scan.dicts:
                return scan.dicts[arg.id]
            return None  # parameter or opaque local — exempt
    return None


# ---------------------------------------------------------------------------
# CLI (style of repro.analysis.lint / authlint)
# ---------------------------------------------------------------------------


def lint_source(src: str, path: str) -> list[Violation]:
    """Single-source convenience (rule fixtures in tests)."""
    _cone, vs = analyze([(path, src)])
    return vs


def _py_files(paths: list[str]) -> list[str]:
    files: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
        else:
            for root, _dirs, names in os.walk(p):
                files.extend(os.path.join(root, n) for n in names if n.endswith(".py"))
    return sorted(files)


def run(paths: list[str] | None = None) -> tuple[int, set[str], list[Violation]]:
    if not paths:
        paths = [p for p in DEFAULT_PATHS if os.path.exists(p)]
    files = _py_files(paths)
    sources = []
    for f in files:
        with open(f, "r", encoding="utf-8") as fh:
            sources.append((f, fh.read()))
    cone, vs = analyze(sources)
    return len(files), cone, vs


def main(argv: list[str] | None = None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    nfiles, cone, vs = run(args)
    for v in vs:
        print(v)
    if vs:
        print(
            f"repro.analysis.replint: {len(vs)} violation(s) in {nfiles} files"
            f" ({len(cone)} functions in the apply cone)"
        )
        return 1
    print(
        f"repro.analysis.replint: OK ({nfiles} files clean,"
        f" {len(cone)} functions in the apply cone verified deterministic)"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
