"""Concurrency-contract analysis for the ColonyOS broker core.

Three tools, one contract (see CONCURRENCY.md):

* :mod:`repro.analysis.locktrack` — a runtime lock-order detector.
  ``make_lock(name)`` hands out plain ``threading.RLock`` objects unless
  ``REPRO_LOCK_CHECK=1`` (or :func:`locktrack.enable`), in which case it
  returns :class:`TrackedRLock` instances that record per-thread held-lock
  sets, build the global lock-order graph, and report cycles, acquisition
  under a leaf lock (``_glock``), cross-shard nesting, and condition-waits
  entered while holding other locks.
* :mod:`repro.analysis.contracts` — ``@requires_lock("shard")`` /
  ``@no_locks_held(...)`` decorators turning the "called with the shard
  lock held" comments into runtime-checked declarations.
* :mod:`repro.analysis.lint` — ``python -m repro.analysis.lint``, a
  stdlib-``ast`` static pass enforcing the repo's concurrency and hygiene
  rules (shard methods declare contracts, no ``kv_list`` scans outside
  migrations, no blocking under ``_glock``, no bare ``except``, no
  mutable default args).
"""

from .contracts import LockContractError, no_locks_held, requires_lock
from .locktrack import TrackedRLock, enable, is_enabled, make_lock, reset, violations

__all__ = [
    "LockContractError",
    "TrackedRLock",
    "enable",
    "is_enabled",
    "make_lock",
    "no_locks_held",
    "requires_lock",
    "reset",
    "violations",
]
