"""Concurrency-, authorization-, and replication-contract analysis for
the ColonyOS core.

Three contract planes, each with a runtime detector and a static lint:

Concurrency (see CONCURRENCY.md):

* :mod:`repro.analysis.locktrack` — a runtime lock-order detector.
  ``make_lock(name)`` hands out plain ``threading.RLock`` objects unless
  ``REPRO_LOCK_CHECK=1`` (or :func:`locktrack.enable`), in which case it
  returns :class:`TrackedRLock` instances that record per-thread held-lock
  sets, build the global lock-order graph, report cycles, acquisition
  under a leaf lock (``_glock``), cross-shard nesting, and condition-waits
  entered while holding other locks — and record per-family lock
  hold-time stats (max/mean, long-hold warnings).
* :mod:`repro.analysis.contracts` — ``@requires_lock("shard")`` /
  ``@no_locks_held(...)`` decorators turning the "called with the shard
  lock held" comments into runtime-checked declarations.
* :mod:`repro.analysis.lint` — ``python -m repro.analysis.lint``, a
  stdlib-``ast`` static pass enforcing the repo's concurrency and hygiene
  rules (LNT001–LNT005).

Authorization (see SECURITY.md):

* :mod:`repro.analysis.authtrack` — runtime auth-fact contracts behind
  ``REPRO_AUTH_CHECK=1``: the server records each verified
  ``(identity, colony, role)``; colony-scoped ``Database`` entry points
  and ``@requires_auth(role)``-decorated internals raise
  :class:`AuthContractError` when no matching fact was recorded.
* :mod:`repro.analysis.authlint` — ``python -m repro.analysis.authlint``,
  a stdlib-``ast`` interprocedural pass proving every registered RPC
  handler authorizes before touching the database (AUT001–AUT004).
* :mod:`repro.analysis.authmap` — ``python -m repro.analysis.authmap``,
  which generates the payloadtype → required-role permission matrix in
  SECURITY.md (``--check`` gates drift in CI).

Replication (see REPLICATION.md):

* :mod:`repro.analysis.statehash` — runtime divergence contracts behind
  ``REPRO_REPL_CHECK=1``: incremental per-colony state digests, chained
  per-node apply journals cross-checked at each Raft index
  (:class:`ReplicationDivergenceError` on the first disagreement), and
  the double-apply idempotence harness in ``HAColonyCluster._apply``.
* :mod:`repro.analysis.replint` — ``python -m repro.analysis.replint``,
  a stdlib-``ast`` interprocedural pass proving the apply cone of every
  replicated op deterministic and CAS-guarded (REP001–REP005).
* :mod:`repro.analysis.replmap` — ``python -m repro.analysis.replmap``,
  which generates the replicated-op matrix (op → required fields,
  leader-stamped fields, CAS guard) in REPLICATION.md (``--check``
  gates drift in CI).
"""

from .authtrack import AuthContractError, requires_auth
from .contracts import LockContractError, no_locks_held, requires_lock
from .locktrack import (
    TrackedRLock,
    enable,
    hold_stats,
    hold_warnings,
    is_enabled,
    make_lock,
    reset,
    set_hold_warn_ms,
    violations,
)
from .statehash import (
    ClusterJournal,
    ColonyDigest,
    ReplicationDivergenceError,
    entry_digest,
    full_colony_digest,
    process_state_tuple,
)

__all__ = [
    "AuthContractError",
    "ClusterJournal",
    "ColonyDigest",
    "LockContractError",
    "ReplicationDivergenceError",
    "TrackedRLock",
    "entry_digest",
    "full_colony_digest",
    "process_state_tuple",
    "enable",
    "hold_stats",
    "hold_warnings",
    "is_enabled",
    "make_lock",
    "no_locks_held",
    "requires_auth",
    "requires_lock",
    "reset",
    "set_hold_warn_ms",
    "violations",
]
