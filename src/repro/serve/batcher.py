"""Dynamic batching through ColonyOS generators (paper §3.4.4 applied).

Each inference request is a fire-and-forget ``pack``; the generator fires
a batched-inference workflow once ``queuesize`` requests accumulate (or
the timeout lapses). The serving executor materializes the batch, runs
the engine once, and publishes per-request results to CFS under
``/results/<request_id>`` — requesters poll the metadata plane. This is
the paper's "integration via fire-and-forget" pattern turned into a
dynamic-batching inference server.
"""

from __future__ import annotations

import json
import secrets
import time
from typing import Any

import numpy as np

from ..core.client import Colonies
from ..core.errors import NotFoundError, TimeoutError_
from ..core.fs import CFSClient

RESULTS_LABEL = "/results"


class InferenceClient:
    """Submit prompts as packs; poll CFS for results."""

    def __init__(self, client: Colonies, cfs: CFSClient, colony: str, generatorid: str, prvkey: str):
        self.client = client
        self.cfs = cfs
        self.colony = colony
        self.generatorid = generatorid
        self.prvkey = prvkey

    def submit(self, prompt_tokens: list[int], max_new_tokens: int = 8) -> str:
        rid = secrets.token_hex(8)
        self.client.pack(
            self.generatorid,
            {"request_id": rid, "prompt": list(map(int, prompt_tokens)), "max_new_tokens": max_new_tokens},
            self.prvkey,
        )
        return rid

    def result(self, rid: str) -> list[int] | None:
        try:
            data = self.cfs.download_bytes(self.colony, RESULTS_LABEL, f"{rid}.json")
        except NotFoundError:
            return None
        return json.loads(data)["tokens"]

    def wait(self, rid: str, timeout: float = 30.0, poll: float = 0.05) -> list[int]:
        deadline = time.time() + timeout
        while time.time() < deadline:
            r = self.result(rid)
            if r is not None:
                return r
            time.sleep(poll)
        raise TimeoutError_(f"request {rid} timed out")


def make_batch_handler(engine, cfs: CFSClient, colony: str):
    """Executor handler for the generator-fired 'generate_batch' function."""

    def generate_batch(ctx, **kwargs) -> list[Any]:
        requests = kwargs.get("packed_args", [])
        if not requests:
            return [0]
        max_new = max(int(r.get("max_new_tokens", 8)) for r in requests)
        longest = max(len(r["prompt"]) for r in requests)
        vocab_pad = 0
        prompts = np.full((len(requests), longest), vocab_pad, np.int32)
        for i, r in enumerate(requests):
            p = r["prompt"]
            prompts[i, longest - len(p):] = p  # right-align
        out = engine.generate(prompts, max_new_tokens=max_new)
        for i, r in enumerate(requests):
            cfs.upload_bytes(
                colony,
                RESULTS_LABEL,
                f"{r['request_id']}.json",
                json.dumps({"tokens": out[i].tolist()}).encode(),
            )
        return [len(requests)]

    return generate_batch
