"""Serving engine: jitted prefill + decode with sampling.

``serve_step`` (decode one token for the whole batch against the KV/state
cache) is the function the decode_32k / long_500k cells lower on the
production mesh. On-device sampling keeps the decode loop host-free
except for the final token fetch.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.model import decode_step, forward, pad_cache, prefill


def make_serve_step(cfg: ModelConfig):
    """(params, tokens(B,1), cache, pos) -> (logits(B,1,V), cache)."""

    def serve_step(params, tokens, cache, pos):
        return decode_step(params, cfg, tokens, cache, pos)

    return serve_step


def make_prefill(cfg: ModelConfig, max_len: int | None = None):
    def prefill_fn(params, batch):
        return prefill(params, cfg, batch, max_len=max_len)

    return prefill_fn


def sample_token(logits: jnp.ndarray, rng: jax.Array, temperature: float) -> jnp.ndarray:
    """logits: (B,1,V) -> (B,1) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    return jax.random.categorical(rng, logits[:, -1] / temperature, axis=-1)[
        :, None
    ].astype(jnp.int32)


class ServeEngine:
    """Host-side generation loop over the jitted prefill/decode steps."""

    def __init__(self, cfg: ModelConfig, params: Any, max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(make_prefill(cfg, max_len))
        self._decode = jax.jit(make_serve_step(cfg))
        self._sample = jax.jit(sample_token, static_argnums=(2,))
        self.stats = {"requests": 0, "tokens": 0, "batches": 0}

    def generate(
        self,
        tokens: np.ndarray,  # (B, S) right-aligned prompts (no padding support needed for synthetic)
        max_new_tokens: int = 16,
        temperature: float = 0.0,
        seed: int = 0,
        extras: dict | None = None,
    ) -> np.ndarray:
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})
        b, s = tokens.shape
        assert s + max_new_tokens <= self.max_len, "increase max_len"
        logits, cache = self._prefill(self.params, batch)
        rng = jax.random.key(seed)
        out = []
        tok = self._sample(logits, rng, temperature)
        out.append(tok)
        pos = s
        for i in range(max_new_tokens - 1):
            rng, sub = jax.random.split(rng)
            logits, cache = self._decode(self.params, tok, cache, jnp.int32(pos))
            tok = self._sample(logits, sub, temperature)
            out.append(tok)
            pos += 1
        self.stats["requests"] += b
        self.stats["tokens"] += b * max_new_tokens
        self.stats["batches"] += 1
        return np.asarray(jnp.concatenate(out, axis=1))
