"""repro.serve subpackage."""
