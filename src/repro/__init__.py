"""ColonyOS reproduction: meta-OS orchestration + JAX compute continuum."""
