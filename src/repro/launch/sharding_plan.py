"""Sharding plans for the dry-run: state, batch, and cache PartitionSpecs.

Parameters use the logical-axis rules (models/sharding.py). Optimizer
state mirrors parameter specs (AdamW) or drops the factored axis
(Adafactor). Caches get explicit per-leaf specs with divisibility-aware
fallbacks: when KV heads don't divide the model axis (qwen's 8 kv heads
on a 16-wide axis), the cache shards its *sequence* dim instead —
sequence-parallel attention, which GSPMD lowers to partial-softmax
collectives.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, TrainConfig
from ..models.sharding import ParamLeaf, param_pspecs, resolve_axes, rules_for


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape.get(a, 1)
        return n
    return mesh.shape.get(axis, 1)


def batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _full(pspec: P, ndim: int) -> tuple:
    t = tuple(pspec)
    return t + (None,) * (ndim - len(t))


# ---------------------------------------------------------------------------
# Train-state sharding
# ---------------------------------------------------------------------------


def opt_pspecs(spec_tree: Any, pspecs: Any, tcfg: TrainConfig) -> Any:
    """Optimizer-state PartitionSpec tree mirroring the param tree."""
    is_leaf = lambda x: isinstance(x, ParamLeaf)
    if tcfg.optimizer == "adafactor":
        from ..train.optimizer import _factored

        def per_leaf(leaf: ParamLeaf, ps: P):
            t = _full(ps, len(leaf.shape))
            if _factored(leaf.shape):
                return {"vr": P(*t[:-1]), "vc": P(*(t[:-2] + t[-1:]))}
            return {"v": P(*t)}

        return {"v": jax.tree.map(per_leaf, spec_tree, pspecs, is_leaf=is_leaf)}
    return {
        "m": jax.tree.map(lambda _leaf, ps: ps, spec_tree, pspecs, is_leaf=is_leaf),
        "v": jax.tree.map(lambda _leaf, ps: ps, spec_tree, pspecs, is_leaf=is_leaf),
    }


def state_pspecs(cfg: ModelConfig, spec_tree: Any, mesh: Mesh, tcfg: TrainConfig) -> dict:
    rules = rules_for(cfg)
    pps = param_pspecs(spec_tree, rules, mesh)
    return {
        "step": P(),
        "params": pps,
        "opt": opt_pspecs(spec_tree, pps, tcfg),
    }


def batch_pspecs(cfg: ModelConfig, batch_tree: Any, mesh: Mesh, batch_dim: int = 0) -> Any:
    """batch_dim=1 for pre-split microbatch leaves shaped (k, B/k, ...)."""
    baxes = batch_axes(mesh)

    def per_leaf(leaf):
        if (
            baxes
            and len(leaf.shape) > batch_dim
            and leaf.shape[batch_dim] % max(_axis_size(mesh, baxes), 1) == 0
        ):
            return P(*([None] * batch_dim + [baxes]))
        return P()

    return jax.tree.map(per_leaf, batch_tree)


def microbatch_specs(batch_tree: Any, k: int) -> Any:
    """Reshape abstract batch leaves (B, ...) -> (k, B/k, ...)."""
    import jax as _jax

    def per_leaf(leaf):
        b = leaf.shape[0]
        assert b % k == 0, f"batch {b} not divisible by microbatches {k}"
        return _jax.ShapeDtypeStruct((k, b // k) + tuple(leaf.shape[1:]), leaf.dtype)

    return jax.tree.map(per_leaf, batch_tree)


# ---------------------------------------------------------------------------
# Decode-cache sharding
# ---------------------------------------------------------------------------


def cache_pspecs(cfg: ModelConfig, cache_tree: Any, mesh: Mesh) -> Any:
    """Walk the cache dict; assign specs by leaf name + divisibility."""
    baxes = batch_axes(mesh)
    model_n = mesh.shape.get("model", 1)
    batch_n = _axis_size(mesh, baxes)

    def bspec(b: int):
        return baxes if (baxes and b % batch_n == 0) else None

    def leaf_spec(name: str, s) -> P:
        shp = s.shape
        if name in ("k", "v"):  # (L, B, S, KV, HD)
            _, b, seq, kv, hd = shp
            if kv % model_n == 0:
                return P(None, bspec(b), None, "model", None)
            if seq % model_n == 0:  # sequence-parallel KV cache
                return P(None, bspec(b), "model", None, None)
            return P(None, bspec(b))
        if name in ("xk", "xv"):  # (L, B, M, KV, HD)
            _, b, m, kv, hd = shp
            if kv % model_n == 0:
                return P(None, bspec(b), None, "model", None)
            return P(None, bspec(b))
        if name == "c_kv":  # (L, B, S, R) — MLA latent: shard seq (TP on q side)
            _, b, seq, r = shp
            if seq % model_n == 0:
                return P(None, bspec(b), "model", None)
            return P(None, bspec(b))
        if name == "k_rope":  # (L, B, S, dr) — shared across heads; align with c_kv
            _, b, seq, dr = shp
            if seq % model_n == 0:
                return P(None, bspec(b), "model", None)
            return P(None, bspec(b))
        if name == "h":  # mamba state (L, B, DI, N)
            _, b, di, n = shp
            if di % model_n == 0:
                return P(None, bspec(b), "model", None)
            return P(None, bspec(b))
        if name == "conv":  # (L, B, CW-1, DI)
            _, b, cw, di = shp
            if di % model_n == 0:
                return P(None, bspec(b), None, "model")
            return P(None, bspec(b))
        if name == "wkv":  # rwkv state (L, B, H, K, V)
            _, b, h, *_ = shp
            if h % model_n == 0:
                return P(None, bspec(b), "model", None, None)
            return P(None, bspec(b))
        if name == "x_prev":  # (L, B, D)
            _, b, d = shp
            return P(None, bspec(b), "model" if d % model_n == 0 else None)
        return P()  # replicate small/unknown leaves

    def walk(tree):
        if isinstance(tree, dict):
            return {k: (walk(v) if isinstance(v, dict) else leaf_spec(k, v)) for k, v in tree.items()}
        return tree

    out = {"layers": walk(cache_tree["layers"])}
    if "prefix_layers" in cache_tree:
        out["prefix_layers"] = walk(cache_tree["prefix_layers"])
    mem = cache_tree.get("memory")
    if mem is not None:
        b, m, d = mem.shape
        out["memory"] = P(bspec(b))
    else:
        out["memory"] = None
    return out


def decode_in_pspecs(cfg: ModelConfig, specs: dict, mesh: Mesh) -> dict:
    baxes = batch_axes(mesh)
    b = specs["tokens"].shape[0]
    batch_n = _axis_size(mesh, baxes)
    tokens_spec = P(baxes) if (baxes and b % batch_n == 0) else P()
    return {
        "tokens": tokens_spec,
        "cache": cache_pspecs(cfg, specs["cache"], mesh),
        "pos": P(),
    }


def to_shardings(pspec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps) if ps is not None else None,
        pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
