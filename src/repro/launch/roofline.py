"""Roofline terms for TPU v5e from compiled dry-run artifacts.

  compute term    = per_device_HLO_FLOPs / peak_FLOPs_per_chip
  memory term     = per_device_HLO_bytes / HBM_bw_per_chip
  collective term = per_device_wire_bytes / ICI_bw_per_chip

(SPMD: the compiled module IS the per-device program, so dividing the
module's cost by per-chip peaks equals the brief's global/(chips x peak).)

MODEL_FLOPS uses 6·N_active·D for training (fwd+bwd) and 2·N_active·D for
inference; N_active discounts routed experts to top_k/E (+ shared).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax

from ..configs.base import ModelConfig, ShapeConfig
from ..models.model import model_spec
from ..models.sharding import ParamLeaf

# TPU v5e hardware constants (per chip), from the assignment brief.
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link


def active_params(cfg: ModelConfig) -> tuple[int, int]:
    """(total, active) parameter counts; routed experts discounted."""
    spec = model_spec(cfg)
    total = 0
    active = 0
    e = max(cfg.moe.num_experts, 1)
    frac = cfg.moe.top_k / e if cfg.moe.num_experts else 1.0
    for leaf in jax.tree.leaves(spec, is_leaf=lambda x: isinstance(x, ParamLeaf)):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        active += int(n * frac) if "experts" in leaf.axes else n
    return total, active


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    _, n_active = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1  # decode: one token per sequence
    return 2.0 * n_active * tokens


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float  # TPU-fusion traffic model (matmuls/copies/colls)
    collective_bytes_per_device: float
    chips: int
    model_flops_total: float
    bytes_per_device_pessimistic: float = 0.0  # per-op (CPU-fusion) model

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def memory_s_pessimistic(self) -> float:
        return self.bytes_per_device_pessimistic / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO flops x chips) — remat/redundancy waste."""
        hlo_total = self.flops_per_device * self.chips
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs throughput vs peak, if the dominant term binds:
        (MODEL_FLOPS / chips / peak) / max(term) — an MFU-style score."""
        ideal_s = self.model_flops_total / self.chips / PEAK_FLOPS
        worst = max(self.compute_s, self.memory_s, self.collective_s)
        return ideal_s / worst if worst else 0.0

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "bytes_per_device_pessimistic": self.bytes_per_device_pessimistic,
            "memory_s_pessimistic": self.memory_s_pessimistic,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "chips": self.chips,
            "model_flops_total": self.model_flops_total,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }
