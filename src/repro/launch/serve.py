"""Serving launcher: boots a ServeExecutor (optionally from a trained CFS
run) plus the generator-based dynamic batcher, then runs a request load.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b --requests 8
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--run", default=None, help="CFS run to load a checkpoint from")
    args = ap.parse_args()

    import numpy as np

    from repro.core import Colonies, Crypto, InProcTransport
    from repro.core.cluster import standalone_server
    from repro.core.fs import CFSClient, MemoryStorage
    from repro.runtime.jax_executor import ServeExecutor
    from repro.serve.batcher import InferenceClient

    server_prv, colony_prv = Crypto.prvkey(), Crypto.prvkey()
    server = standalone_server(Crypto.id(server_prv))
    server.start_background(failsafe_interval=0.1)
    client = Colonies(InProcTransport([server]))
    client.add_colony("serve", Crypto.id(colony_prv), server_prv)
    storage = MemoryStorage()
    worker = ServeExecutor(client, "serve", "serve-0", "tpu-serve", storage,
                           colony_prvkey=colony_prv, arch=args.arch,
                           max_len=64, run=args.run)
    worker.start(poll_timeout=0.2)
    wf = {"colonyname": "serve", "functionspecs": [
        {"nodename": "batch", "funcname": "generate_batch",
         "conditions": {"executortype": "tpu-serve", "dependencies": []},
         "maxexectime": 300}]}
    g = client.add_generator(
        {"colonyname": "serve", "name": "batcher", "queuesize": args.batch_size,
         "timeout": 2.0, "workflow": wf}, colony_prv)
    infc = InferenceClient(client, CFSClient(client, storage, colony_prv),
                           "serve", g["generatorid"], colony_prv)
    rng = np.random.default_rng(0)
    t0 = time.time()
    rids = [infc.submit(rng.integers(0, 200, 8).tolist(),
                        max_new_tokens=args.max_new_tokens)
            for _ in range(args.requests)]
    for rid in rids:
        print(rid, infc.wait(rid, timeout=300))
    st = worker.engine.stats
    print(f"{st['requests']} requests in {st['batches']} batches, "
          f"{st['tokens']} tokens, {time.time()-t0:.1f}s")
    worker.stop()
    server.stop()


if __name__ == "__main__":
    main()
