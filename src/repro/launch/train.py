"""Training launcher: ``--arch <id>`` runs the colony-dispatched training
loop (smoke variant on CPU; full variant is what the dry-run lowers).

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b --steps 20

The process is submitted as a ColonyOS function specification and
executed by a TrainerExecutor — the same path the continuum uses — so
checkpointing, lease-based fault tolerance and CFS hand-off all apply.
"""

from __future__ import annotations

import argparse
import json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "adafactor"])
    ap.add_argument("--learning-rate", type=float, default=3e-4)
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--run", default="cli-run")
    ap.add_argument("--use-pallas", action="store_true")
    args = ap.parse_args()

    from repro.core import Colonies, Crypto, FunctionSpec, InProcTransport
    from repro.core.cluster import standalone_server
    from repro.core.fs import MemoryStorage
    from repro.runtime.jax_executor import TrainerExecutor

    server_prv, colony_prv = Crypto.prvkey(), Crypto.prvkey()
    server = standalone_server(Crypto.id(server_prv))
    server.start_background(failsafe_interval=0.2)
    client = Colonies(InProcTransport([server]))
    client.add_colony("launch", Crypto.id(colony_prv), server_prv)
    trainer = TrainerExecutor(client, "launch", "trainer-0", "tpu-pod",
                              MemoryStorage(), colony_prvkey=colony_prv)
    trainer.start(poll_timeout=0.2)

    spec = FunctionSpec.from_dict({
        "conditions": {"colonyname": "launch", "executortype": "tpu-pod"},
        "funcname": "train",
        "kwargs": {
            "arch": args.arch, "variant": args.variant, "steps": args.steps,
            "batch": args.batch, "seq_len": args.seq_len,
            "microbatches": args.microbatches, "optimizer": args.optimizer,
            "learning_rate": args.learning_rate,
            "checkpoint_every": args.checkpoint_every, "run": args.run,
            "use_pallas": args.use_pallas,
        },
        "maxexectime": 24 * 3600, "maxretries": 3,
    })
    p = client.submit(spec, colony_prv)
    done = client.wait(p["processid"], colony_prv, timeout=24 * 3600)
    print(json.dumps(done["out"], indent=1))
    trainer.stop()
    server.stop()
    if done["state"] != "successful":
        raise SystemExit(1)


if __name__ == "__main__":
    main()
