"""Render EXPERIMENTS.md tables from results/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report [--outdir results/dryrun]
"""

from __future__ import annotations

import argparse
import json
import os


def load(outdir: str) -> list[dict]:
    recs = []
    for fname in sorted(os.listdir(outdir)):
        if fname.endswith(".json") and fname != "summary.json":
            with open(os.path.join(outdir, fname)) as f:
                recs.append(json.load(f))
    return recs


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def roofline_table(recs: list[dict], mesh_tag: str) -> str:
    rows = [
        "| arch | shape | compute | memory | collective | bottleneck | "
        "useful-FLOPs | roofline frac | HBM GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in recs:
        tag = "single" if rec["mesh"].get("pod") is None else "multi"
        if tag != mesh_tag:
            continue
        if rec["status"] == "skipped":
            rows.append(
                f"| {rec['arch']} | {rec['shape']} | — | — | — | SKIP | — | — | — |"
            )
            continue
        if rec["status"] != "ok":
            rows.append(
                f"| {rec['arch']} | {rec['shape']} | ERROR | | | | | | |"
            )
            continue
        r = rec["roofline"]
        m = rec["memory_analysis"]
        hbm = (
            m.get("argument_size_in_bytes", 0) + m.get("temp_size_in_bytes", 0)
        ) / 1e9
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {fmt_s(r['compute_s'])} "
            f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
            f"| {r['bottleneck']} | {r['useful_flops_fraction']:.2f} "
            f"| {r['roofline_fraction']:.4f} | {hbm:.1f} |"
        )
    return "\n".join(rows)


def dryrun_table(recs: list[dict]) -> str:
    rows = [
        "| arch | shape | mesh | status | compile | FLOPs/dev | bytes/dev | coll bytes/dev | dominant collectives |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in recs:
        tag = "16x16" if rec["mesh"].get("pod") is None else "2x16x16"
        if rec["status"] == "skipped":
            rows.append(f"| {rec['arch']} | {rec['shape']} | {tag} | SKIP | | | | | |")
            continue
        if rec["status"] != "ok":
            rows.append(f"| {rec['arch']} | {rec['shape']} | {tag} | ERROR | | | | | |")
            continue
        r = rec["roofline"]
        counts = rec["collectives"]["counts"]
        dom = ", ".join(f"{k}x{v}" for k, v in sorted(counts.items()))
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {tag} | ok | {rec['compile_s']}s "
            f"| {r['flops_per_device']/1e12:.2f}T | {r['bytes_per_device']/1e9:.1f}G "
            f"| {r['collective_bytes_per_device']/1e9:.2f}G | {dom} |"
        )
    return "\n".join(rows)


def interesting_cells(recs: list[dict]) -> list[tuple[str, str, str]]:
    ok = [r for r in recs if r["status"] == "ok" and r["mesh"].get("pod") is None]
    worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = max(ok, key=lambda r: r["roofline"]["collective_s"])
    return [
        (worst["arch"], worst["shape"], "worst roofline fraction"),
        (coll["arch"], coll["shape"], "most collective-bound"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="results/dryrun")
    args = ap.parse_args()
    recs = load(args.outdir)
    print("## Roofline (single-pod 16x16)\n")
    print(roofline_table(recs, "single"))
    print("\n## Dry-run records (both meshes)\n")
    print(dryrun_table(recs))
    print("\nmost interesting:", interesting_cells(recs))


if __name__ == "__main__":
    main()
