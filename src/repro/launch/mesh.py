"""Production mesh definitions (multi-pod dry-run contract).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; dryrun.py sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, then calls it.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod (v5e-256); 2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_devices(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
