"""repro.launch subpackage."""
