"""Dry-run core: lower + compile every (arch x shape x mesh) cell.

Import-order contract: the caller (dryrun.py) sets XLA_FLAGS *before*
importing jax/this module. Functions here are device-count agnostic so
tests can run them on small host-device meshes.
"""

from __future__ import annotations

import json
import os
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs import SHAPES, TrainConfig, get_config
from ..configs.base import ModelConfig, ShapeConfig
from ..configs.shapes import CellSkip, batch_specs, cell_skip_reason, decode_specs
from ..models.model import model_spec
from ..models.sharding import abstract_params, param_pspecs, rules_for
from ..serve.engine import make_prefill, make_serve_step
from ..train.train_step import init_state, make_train_step
from . import hlo_analysis
from .roofline import Roofline, model_flops
from .sharding_plan import (
    batch_pspecs,
    decode_in_pspecs,
    state_pspecs,
    to_shardings,
)


def _metrics_pspecs(cfg: ModelConfig) -> dict:
    from ..train.train_step import _metric_keys

    keys = _metric_keys(cfg) + ["grad_norm", "lr"]
    return {k: P() for k in keys}


def _abstract_state(cfg: ModelConfig, tcfg: TrainConfig):
    spec = model_spec(cfg)
    params_abs = abstract_params(spec, jnp.dtype(cfg.param_dtype))
    return jax.eval_shape(lambda p: init_state(p, tcfg), params_abs)


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, tcfg: TrainConfig | None = None):
    """Build + lower the right step function for this cell. Returns lowered."""
    from ..models.sharding import activation_mesh

    with activation_mesh(mesh):
        return _lower_cell(cfg, shape, mesh, tcfg)


def _lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, tcfg: TrainConfig | None = None):
    tcfg = tcfg or TrainConfig()
    spec = model_spec(cfg)
    if shape.kind == "train":
        from .sharding_plan import microbatch_specs

        state_abs = _abstract_state(cfg, tcfg)
        batch_abs = batch_specs(cfg, shape)
        bdim = 0
        if tcfg.microbatches > 1:
            batch_abs = microbatch_specs(batch_abs, tcfg.microbatches)
            bdim = 1
        state_sh = to_shardings(state_pspecs(cfg, spec, mesh, tcfg), mesh)
        batch_sh = to_shardings(batch_pspecs(cfg, batch_abs, mesh, batch_dim=bdim), mesh)
        fn = jax.jit(
            make_train_step(cfg, tcfg),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, to_shardings(_metrics_pspecs(cfg), mesh)),
            donate_argnums=(0,),
        )
        with mesh:
            return fn.lower(state_abs, batch_abs)
    params_abs = abstract_params(spec, jnp.dtype(cfg.param_dtype))
    params_sh = to_shardings(param_pspecs(spec, rules_for(cfg), mesh), mesh)
    if shape.kind == "prefill":
        batch_abs = batch_specs(cfg, shape)
        batch_sh = to_shardings(batch_pspecs(cfg, batch_abs, mesh), mesh)
        fn = jax.jit(make_prefill(cfg), in_shardings=(params_sh, batch_sh))
        with mesh:
            return fn.lower(params_abs, batch_abs)
    # decode
    specs = decode_specs(cfg, shape)
    in_ps = decode_in_pspecs(cfg, specs, mesh)
    fn = jax.jit(
        make_serve_step(cfg),
        in_shardings=(
            params_sh,
            to_shardings(in_ps["tokens"], mesh),
            to_shardings(in_ps["cache"], mesh),
            to_shardings(in_ps["pos"], mesh),
        ),
        donate_argnums=(2,),
    )
    with mesh:
        return fn.lower(params_abs, specs["tokens"], specs["cache"], specs["pos"])


def analyze_compiled(compiled, cfg: ModelConfig, shape: ShapeConfig, chips: int) -> dict:
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = dict(ca)
    except Exception as e:  # noqa: BLE001
        cost = {"error": str(e)}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ):
            if hasattr(ma, attr):
                mem[attr] = getattr(ma, attr)
    except Exception as e:  # noqa: BLE001
        mem = {"error": str(e)}
    text = compiled.as_text()
    hlo = hlo_analysis.analyze_hlo(text)
    roof = Roofline(
        flops_per_device=hlo["dot_flops"],
        bytes_per_device=hlo["hbm_bytes_fused"],
        collective_bytes_per_device=hlo["collectives"]["total_bytes"],
        chips=chips,
        model_flops_total=model_flops(cfg, shape),
        bytes_per_device_pessimistic=hlo["hbm_bytes"],
    )
    return {
        # XLA's own (loop-unaware) numbers kept for reference
        "cost_analysis": {k: v for k, v in cost.items() if isinstance(v, (int, float))},
        "memory_analysis": mem,
        "collectives": hlo["collectives"],
        "loop_trip_counts": hlo["loop_trip_counts"],
        "dot_count": hlo["dot_count"],
        "roofline": roof.to_dict(),
        "hlo_bytes": len(text),
    }


_BIG_ARCHS = {"jamba-1.5-large-398b", "deepseek-v3-671b"}  # adafactor state


def default_tcfg(arch: str, shape: ShapeConfig) -> TrainConfig:
    """Per-cell training policy used by the baseline dry-run sweep:
    8 microbatches for train_4k (fits activations in 16 GB HBM),
    Adafactor for the >=100B configs (factored optimizer state)."""
    return TrainConfig(
        microbatches=8 if shape.kind == "train" else 1,
        optimizer="adafactor" if arch in _BIG_ARCHS else "adamw",
    )


def run_cell(
    arch: str,
    shape_name: str,
    mesh,
    *,
    variant: str = "full",
    tcfg: TrainConfig | None = None,
    cfg_overrides: dict | None = None,
) -> dict:
    """Lower+compile one cell; returns the result record (never raises)."""
    shape = SHAPES[shape_name]
    if tcfg is None:
        tcfg = default_tcfg(arch, shape)
    chips = 1
    for v in mesh.shape.values():
        chips *= v
    record: dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": dict(mesh.shape),
        "chips": chips,
        "variant": variant,
    }
    cfg = get_config(arch, variant)
    if cfg_overrides:
        cfg = cfg.copy(**cfg_overrides)
    reason = cell_skip_reason(cfg, shape)
    if reason:
        record["status"] = "skipped"
        record["reason"] = reason
        return record
    try:
        t0 = time.time()
        lowered = lower_cell(cfg, shape, mesh, tcfg)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        record.update(analyze_compiled(compiled, cfg, shape, chips))
        record["status"] = "ok"
        record["lower_s"] = round(t1 - t0, 2)
        record["compile_s"] = round(t2 - t1, 2)
        del compiled, lowered
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc(limit=20)
    return record


def sweep(
    archs: list[str],
    shapes: list[str],
    mesh,
    outdir: str,
    mesh_tag: str,
    *,
    force: bool = False,
    cfg_overrides: dict | None = None,
) -> list[dict]:
    os.makedirs(outdir, exist_ok=True)
    results = []
    for arch in archs:
        for shape_name in shapes:
            path = os.path.join(outdir, f"{mesh_tag}__{arch}__{shape_name}.json")
            if os.path.exists(path) and not force:
                with open(path) as f:
                    results.append(json.load(f))
                continue
            rec = run_cell(arch, shape_name, mesh, cfg_overrides=cfg_overrides)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            status = rec["status"]
            extra = ""
            if status == "ok":
                r = rec["roofline"]
                extra = (
                    f" bottleneck={r['bottleneck']}"
                    f" frac={r['roofline_fraction']:.3f}"
                    f" compile={rec['compile_s']}s"
                )
            elif status == "error":
                extra = " " + rec["error"][:120]
            print(f"[dryrun] {mesh_tag} {arch} {shape_name}: {status}{extra}", flush=True)
            results.append(rec)
    return results
