"""Loop-aware roofline accounting from compiled (post-SPMD) HLO text.

XLA's ``HloCostAnalysis`` visits each computation ONCE, so anything inside
a ``while`` (scan-over-layers, microbatch accumulation, chunked SSM scans)
is undercounted by its trip count. This module re-derives the three
roofline inputs from the optimized HLO text itself:

  * dot FLOPs      — 2 * prod(result dims) * prod(contracting dims),
  * HBM bytes      — Σ per-op (result + operand bytes) over top-level ops
                     (a perfect-fusion traffic model: every producer write
                     and consumer read counted once),
  * collective wire bytes — per-kind conventions (all-reduce 2x, others 1x),

each scaled by the product of enclosing while-loop trip counts (parsed
from the loop condition's comparison constant). Shapes in post-SPMD HLO
are per-device, so totals are per-device quantities.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# op definition: %name = <types> opcode(...)
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\(")
_COMP_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_ZERO_COST = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
_WIRE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclass
class _Computation:
    name: str
    is_entry: bool = False
    ops: dict[str, _Op] = field(default_factory=dict)
    order: list[str] = field(default_factory=list)


def _parse(hlo_text: str) -> tuple[dict[str, _Computation], str]:
    comps: dict[str, _Computation] = {}
    entry = ""
    current: _Computation | None = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        head = _COMP_HEAD_RE.match(line.strip())
        if head:
            current = _Computation(head.group(2), is_entry=bool(head.group(1)))
            comps[current.name] = current
            if current.is_entry:
                entry = current.name
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        m = _DEF_RE.match(line)
        if m:
            op = _Op(m.group(1), m.group(2), m.group(3), line)
            current.ops[op.name] = op
            current.order.append(op.name)
    return comps, entry


def _trip_count(cond: _Computation) -> int | None:
    """Loop condition is `param < constant` (scan): read the constant."""
    consts = re.findall(r"constant\((\d+)\)", "\n".join(o.line for o in cond.ops.values()))
    if consts:
        return max(int(c) for c in consts)
    return None


def _operands_of(op: _Op, comp: _Computation) -> list[_Op]:
    """Resolve operand names inside the call parens to defs in this comp."""
    paren = op.line.find("(", op.line.find(op.opcode))
    if paren < 0:
        return []
    depth = 0
    end = paren
    for i in range(paren, len(op.line)):
        if op.line[i] == "(":
            depth += 1
        elif op.line[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    args = op.line[paren + 1 : end]
    out = []
    for name in _OPERAND_RE.findall(args):
        other = comp.ops.get(name)
        if other is not None and other.name != op.name:
            out.append(other)
    return out


def _dot_flops(op: _Op, comp: _Computation) -> float:
    result_elems = 1
    for d in _shape_dims(op.type_str):
        result_elems *= d
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contracting = 1
    if mc:
        idxs = [int(x) for x in mc.group(1).split(",") if x]
        operands = _operands_of(op, comp)
        if operands:
            lhs_dims = _shape_dims(operands[0].type_str)
            for i in idxs:
                if i < len(lhs_dims):
                    contracting *= lhs_dims[i]
    return 2.0 * result_elems * contracting


def _conv_flops(op: _Op, comp: _Computation) -> float:
    # flops ~= 2 * output elems * kernel spatial * in_channels (rare here)
    result_elems = 1
    for d in _shape_dims(op.type_str):
        result_elems *= d
    operands = _operands_of(op, comp)
    k = 1
    if len(operands) >= 2:
        for d in _shape_dims(operands[1].type_str):
            k *= d
        out_d = _shape_dims(op.type_str)
        if out_d:
            k = max(1, k // max(out_d[-1], 1))
    return 2.0 * result_elems * k


_META_RE = re.compile(r'op_name="([^"]*)"')


def _op_label(op: _Op) -> str:
    m = _META_RE.search(op.line)
    if not m:
        return op.opcode
    parts = m.group(1).split("/")
    tail = "/".join(parts[-2:]) if len(parts) >= 2 else parts[-1]
    return f"{op.opcode}:{tail}"


def analyze_hlo(hlo_text: str, breakdown_top: int = 0) -> dict:
    comps, entry = _parse(hlo_text)
    if not entry:
        # fall back: biggest computation
        entry = max(comps, key=lambda c: len(comps[c].ops)) if comps else ""

    flops_total = 0.0
    bytes_total = 0.0
    bytes_fused = 0.0  # TPU-fusion approximation: matmul/copy/collective traffic only
    coll_bytes: dict[str, float] = defaultdict(float)
    coll_counts: dict[str, int] = defaultdict(int)
    trip_counts: dict[str, int] = {}
    dot_count = 0
    bytes_by_label: dict[str, float] = defaultdict(float)
    flops_by_label: dict[str, float] = defaultdict(float)

    seen: set[tuple[str, float]] = set()

    def visit(comp_name: str, scale: float) -> None:
        nonlocal flops_total, bytes_total, bytes_fused, dot_count
        comp = comps.get(comp_name)
        if comp is None:
            return
        key = (comp_name, scale)
        if key in seen:  # identical revisit; loops can't recurse in HLO
            return
        seen.add(key)
        for op_name in comp.order:
            op = comp.ops[op_name]
            if op.opcode in _ZERO_COST:
                continue
            # bytes: result + operands (per-op HBM traffic model — pessimistic:
            # counts every top-level op's tensors; CPU XLA fuses less than TPU)
            b = _type_bytes(op.type_str)
            operand_bytes = [_type_bytes(o.type_str) for o in _operands_of(op, comp)]
            b += sum(operand_bytes)
            # In-place update semantics: a dynamic-update-slice writes only
            # the slice (the carried buffer aliases); a dynamic-slice reads
            # only the slice. Remove the full-buffer double counting.
            is_dus = op.opcode == "dynamic-update-slice" or "dynamic-update-slice" in op.name
            is_ds = not is_dus and (op.opcode == "dynamic-slice" or "dynamic-slice" in op.name)
            if is_dus and operand_bytes:
                b -= 2 * max(operand_bytes)
            elif is_ds and operand_bytes:
                b -= max(operand_bytes)
            b = max(b, 0)
            bytes_total += b * scale
            # fused model: only matmul operands/results, scan saves (dus),
            # copies and collectives hit HBM; elementwise chains fuse away.
            if (
                op.opcode in ("dot", "convolution", "copy", "dynamic-update-slice", "dynamic-slice")
                or "dynamic-update-slice" in op.name
                or "dynamic_update_slice" in op.line[:200]
                or any(op.opcode.startswith(c) for c in _COLL_KINDS)
            ):
                bytes_fused += b * scale
            if breakdown_top:
                bytes_by_label[_op_label(op)] += b * scale
            if op.opcode == "dot":
                f = _dot_flops(op, comp) * scale
                flops_total += f
                dot_count += 1
                if breakdown_top:
                    flops_by_label[_op_label(op)] += f
            elif op.opcode == "convolution":
                flops_total += _conv_flops(op, comp) * scale
            # collectives (incl. async -start variants)
            base = op.opcode[:-6] if op.opcode.endswith("-start") else op.opcode
            if base in _COLL_KINDS and not op.opcode.endswith("-done"):
                size = _type_bytes(op.type_str)
                if op.opcode.endswith("-start"):
                    size = size / 2  # start tuple carries (operand, result)
                coll_bytes[base] += size * _WIRE_FACTOR[base] * scale
                coll_counts[base] += 1
            if op.opcode == "while":
                mbody = re.search(r"body=%?([\w.\-]+)", op.line)
                trip = None
                mtrip = _TRIP_RE.search(op.line)  # backend_config, exact
                if mtrip:
                    trip = int(mtrip.group(1))
                else:
                    mcond = re.search(r"condition=%?([\w.\-]+)", op.line)
                    if mcond and mcond.group(1) in comps:
                        trip = _trip_count(comps[mcond.group(1)])
                if mbody:
                    t = trip if trip else 1
                    trip_counts[mbody.group(1)] = t
                    visit(mbody.group(1), scale * t)
            elif op.opcode == "conditional":
                for branch in re.findall(r"%([\w.\-]+)", op.line.split("branch_computations")[-1]):
                    if branch in comps:
                        visit(branch, scale)
            elif op.opcode in ("call", "async-start"):
                mcall = re.search(r"to_apply=%?([\w.\-]+)", op.line)
                if mcall:
                    visit(mcall.group(1), scale)

    visit(entry, 1.0)
    out = {
        "dot_flops": flops_total,
        "hbm_bytes": bytes_total,
        "hbm_bytes_fused": bytes_fused,
        "dot_count": dot_count,
        "collectives": {
            "bytes_by_kind": dict(coll_bytes),
            "counts": dict(coll_counts),
            "total_bytes": float(sum(coll_bytes.values())),
        },
        "loop_trip_counts": trip_counts,
    }
    if breakdown_top:
        out["bytes_breakdown"] = dict(
            sorted(bytes_by_label.items(), key=lambda kv: -kv[1])[:breakdown_top]
        )
        out["flops_breakdown"] = dict(
            sorted(flops_by_label.items(), key=lambda kv: -kv[1])[:breakdown_top]
        )
    return out


# Back-compat shims (earlier callers)
def collective_bytes(hlo_text: str) -> dict:
    return analyze_hlo(hlo_text)["collectives"]


def collectives_with_loops(hlo_text: str) -> dict:
    a = analyze_hlo(hlo_text)
    out = dict(a["collectives"])
    out["loop_trip_counts"] = a["loop_trip_counts"]
    return out
