import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

Proves the distribution config is coherent without hardware: for every
(architecture x input shape), ``jax.jit(step).lower(...).compile()`` must
succeed on the single-pod 16x16 mesh AND the 2x16x16 multi-pod mesh, with
memory/cost analysis recorded for EXPERIMENTS.md §Dry-run / §Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
"""

import argparse
import json


def main() -> None:
    # jax gets imported only now — after XLA_FLAGS is pinned above.
    from repro.configs import ARCH_IDS, SHAPES
    from repro.launch.dryrun_lib import sweep
    from repro.launch.mesh import make_production_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]

    all_results = []
    if args.mesh in ("single", "both"):
        mesh = make_production_mesh(multi_pod=False)
        all_results += sweep(archs, shapes, mesh, args.outdir, "single", force=args.force)
    if args.mesh in ("multi", "both"):
        mesh = make_production_mesh(multi_pod=True)
        all_results += sweep(archs, shapes, mesh, args.outdir, "multi", force=args.force)

    ok = sum(1 for r in all_results if r["status"] == "ok")
    skipped = sum(1 for r in all_results if r["status"] == "skipped")
    errors = [r for r in all_results if r["status"] == "error"]
    print(f"\n=== dry-run summary: ok={ok} skipped={skipped} errors={len(errors)} ===")
    for r in errors:
        print(f"  ERROR {r['arch']} {r['shape']} ({r['mesh']}): {r['error'][:200]}")
    summary_path = f"{args.outdir}/summary.json"
    with open(summary_path, "w") as f:
        json.dump(all_results, f, indent=1)
    print(f"wrote {summary_path}")
    if errors:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
