#!/usr/bin/env bash
# CI entry point: tier-1 quick suite + the broker and CFS hot-path benchmarks.
#
#   scripts/verify.sh          # quick suite (skips @slow compile tests)
#   scripts/verify.sh --full   # everything, including @slow
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

if [[ "${1:-}" == "--full" ]]; then
    python -m pytest -q
else
    python -m pytest -q -m "not slow"
fi

python -m benchmarks.run broker cfs
