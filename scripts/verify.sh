#!/usr/bin/env bash
# CI entry point: the four contract planes (concurrency, authorization,
# replication, idempotency — static lints, matrix drift gates, runtime
# detectors, chaos soak) + tier-1 quick suite + the broker and CFS
# hot-path benchmarks.
#
#   scripts/verify.sh          # quick suite (skips @slow compile tests)
#   scripts/verify.sh --full   # everything, including @slow
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

# Static concurrency/hygiene lint (see CONCURRENCY.md). Exits non-zero on
# any violation; there is no suppression mechanism.
python -m repro.analysis.lint

# Static authorization lint + permission-matrix drift gate (see
# SECURITY.md): every registered RPC handler must establish an auth fact
# before touching the database, and the committed matrix must match the
# handler tables.
python -m repro.analysis.authlint
python -m repro.analysis.authmap --check

# Static replication lint + replicated-op matrix drift gate (see
# REPLICATION.md): the apply cone of every replicated op must be
# deterministic and CAS-guarded, and the committed matrix must match the
# REPLICATED_OPS literal.
python -m repro.analysis.replint
python -m repro.analysis.replmap --check

# Static idempotency lint (see ROBUSTNESS.md): every registered
# payloadtype must be classified in idempotency.SPEC, and the
# classification must match whether the handler's call cone reaches a
# database mutator — retried RPCs must not duplicate effects.
python -m repro.analysis.idemlint

if [[ "${1:-}" == "--full" ]]; then
    python -m pytest -q
else
    python -m pytest -q -m "not slow"
fi

# Runtime lock-order detector over the multi-threaded broker tests:
# every lock acquisition is checked for ordering/leaf/cross-shard
# violations (recorded violations fail the stress assertion).
REPRO_LOCK_CHECK=1 python -m pytest -q tests/test_concurrency.py \
    tests/test_http_and_ha.py tests/test_failsafe.py \
    tests/test_replication.py tests/test_faults.py tests/test_blobstore.py

# Runtime auth-fact contracts over the full RPC surface: colony-scoped
# database access inside a handler dispatch raises without a recorded
# (identity, colony, role) fact.
REPRO_AUTH_CHECK=1 python -m pytest -q -m "not slow"

# Runtime replication-divergence contracts over the Raft/HA tests:
# per-node apply journals cross-checked at every index, plus the
# double-apply idempotence harness on every replicated op.
REPRO_REPL_CHECK=1 python -m pytest -q tests/test_raft.py \
    tests/test_http_and_ha.py tests/test_replication.py

# Chaos soak gate (see ROBUSTNESS.md): 3-replica HA cluster under a
# seeded FaultPlan (transport resets/drops) and a ChaosMonkey
# partitioning raft replicas; every process must reach a terminal state
# exactly once with zero replication divergence. Includes the blob-plane
# soak (STORAGE.md): one of three storage shards killed mid-soak, every
# snapshot still materializing byte-identical, scrub restoring
# replication.
REPRO_REPL_CHECK=1 python -m pytest -q tests/test_chaos_soak.py

# Blob fault matrix (STORAGE.md gates): put tolerance, get rotation,
# read-repair, quarantine, CFSClient retry, executor sync directives.
python -m pytest -q tests/test_blobstore.py

python -m benchmarks.run broker cfs storage
