"""End-to-end continuum driver (deliverable b): a full AI workflow across
heterogeneous executors, exactly the paper's vision —

  edge executor ingests data into CFS  ->  tpu-pod executor trains an LM
  with CFS checkpoints (surviving a mid-run chaos crash via the
  maxexectime failsafe)  ->  eval executor scores the checkpoint  ->
  a serve executor boots the trained model from CFS.

Defaults are CPU-sized; crank --steps/--arch for bigger runs.

    PYTHONPATH=src python examples/train_continuum.py --steps 30
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.core import Colonies, Crypto, InProcTransport, WorkflowSpec
from repro.core.cluster import standalone_server
from repro.core.fs import MemoryStorage
from repro.runtime.jax_executor import DataExecutor, ServeExecutor, TrainerExecutor


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--chaos", action="store_true", default=True,
                    help="kill the first trainer mid-run (default on)")
    args = ap.parse_args()

    server_prv, colony_prv = Crypto.prvkey(), Crypto.prvkey()
    server = standalone_server(Crypto.id(server_prv))
    server.start_background(failsafe_interval=0.2)
    client = Colonies(InProcTransport([server]))
    client.add_colony("continuum", Crypto.id(colony_prv), server_prv)
    storage = MemoryStorage()

    die_at = args.steps // 2 if args.chaos else None
    edge = DataExecutor(client, "continuum", "edge-0", "edge-data", storage,
                        colony_prvkey=colony_prv)
    hpc_a = TrainerExecutor(client, "continuum", "hpc-a", "tpu-pod", storage,
                            colony_prvkey=colony_prv, die_at_step=die_at)
    hpc_b = TrainerExecutor(client, "continuum", "hpc-b", "tpu-pod", storage,
                            colony_prvkey=colony_prv)
    for ex in (edge, hpc_a, hpc_b):
        ex.start(poll_timeout=0.2)

    wf = WorkflowSpec.from_dict({
        "colonyname": "continuum",
        "functionspecs": [
            {"nodename": "ingest", "funcname": "prepare_data",
             "kwargs": {"shards": 4, "tokens_per_shard": 4096},
             "conditions": {"executortype": "edge-data", "dependencies": []},
             "maxexectime": 60},
            {"nodename": "train", "funcname": "train",
             "kwargs": {"arch": args.arch, "steps": args.steps,
                        "batch": args.batch, "seq_len": args.seq_len,
                        "checkpoint_every": max(args.steps // 5, 1),
                        "run": "continuum-demo"},
             "conditions": {"executortype": "tpu-pod", "dependencies": ["ingest"]},
             "maxexectime": 45, "maxretries": 3},
            {"nodename": "eval", "funcname": "evaluate",
             "kwargs": {"arch": args.arch, "batch": args.batch,
                        "seq_len": args.seq_len, "run": "continuum-demo"},
             "conditions": {"executortype": "tpu-pod", "dependencies": ["train"]},
             "maxexectime": 60},
        ],
    })
    t0 = time.time()
    r = client.submit_workflow(wf, colony_prv)
    procs = {p["spec"]["nodename"]: p for p in r["processes"]}
    print(f"workflow submitted: {list(procs)}  (trainer will "
          f"{'crash at step ' + str(die_at) if die_at else 'run clean'})")
    done = client.wait(procs["eval"]["processid"], colony_prv, timeout=600)
    train = client.get_process(procs["train"]["processid"], colony_prv)
    print(f"train: state={train['state']} retries={train['retries']} "
          f"result={train['out']}")
    print(f"eval : state={done['state']} result={done['out']}")
    print(f"wall time: {time.time() - t0:.1f}s")

    # hand the trained model to a 'cloud' serve executor via CFS
    cloud = ServeExecutor(client, "continuum", "cloud-0", "tpu-serve", storage,
                          colony_prvkey=colony_prv, arch=args.arch,
                          max_len=args.seq_len + 16, run="continuum-demo")
    import numpy as np

    prompts = np.random.default_rng(0).integers(0, 100, (2, 8), dtype=np.int32)
    out = cloud.engine.generate(prompts, max_new_tokens=8)
    print("served generation from the trained checkpoint:", out.tolist())

    for ex in (edge, hpc_a, hpc_b):
        ex.stop()
    server.stop()


if __name__ == "__main__":
    main()
