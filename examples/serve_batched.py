"""Dynamic-batching inference server built from the paper's generators
(§3.4.4): each request is a fire-and-forget ``pack``; the generator fires
one batched-inference workflow per ``--batch-size`` requests (or on
timeout); results land in CFS where clients poll them.

    PYTHONPATH=src python examples/serve_batched.py --requests 8 --batch-size 4
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import Colonies, Crypto, InProcTransport
from repro.core.cluster import standalone_server
from repro.core.fs import CFSClient, MemoryStorage
from repro.runtime.jax_executor import ServeExecutor
from repro.serve.batcher import InferenceClient


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    args = ap.parse_args()

    server_prv, colony_prv = Crypto.prvkey(), Crypto.prvkey()
    server = standalone_server(Crypto.id(server_prv))
    server.start_background(failsafe_interval=0.1)
    client = Colonies(InProcTransport([server]))
    client.add_colony("serve", Crypto.id(colony_prv), server_prv)
    storage = MemoryStorage()

    worker = ServeExecutor(client, "serve", "gpu-0", "tpu-serve", storage,
                           colony_prvkey=colony_prv, arch=args.arch, max_len=64)
    worker.start(poll_timeout=0.2)

    wf = {"colonyname": "serve", "functionspecs": [
        {"nodename": "batch", "funcname": "generate_batch",
         "conditions": {"executortype": "tpu-serve", "dependencies": []},
         "maxexectime": 120}]}
    g = client.add_generator(
        {"colonyname": "serve", "name": "batcher", "queuesize": args.batch_size,
         "timeout": 2.0, "workflow": wf},
        colony_prv,
    )
    infc = InferenceClient(client, CFSClient(client, storage, colony_prv),
                           "serve", g["generatorid"], colony_prv)

    rng = np.random.default_rng(0)
    t0 = time.time()
    rids = [
        infc.submit(rng.integers(0, 200, rng.integers(4, 12)).tolist(),
                    max_new_tokens=args.max_new_tokens)
        for _ in range(args.requests)
    ]
    print(f"submitted {len(rids)} requests (fire-and-forget packs)")
    for rid in rids:
        tokens = infc.wait(rid, timeout=120)
        print(f"  {rid}: {tokens}")
    dt = time.time() - t0
    st = worker.engine.stats
    print(f"\n{st['requests']} requests served in {st['batches']} batched "
          f"calls ({st['tokens']} tokens) in {dt:.1f}s")
    worker.stop()
    server.stop()


if __name__ == "__main__":
    main()
