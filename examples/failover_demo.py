"""Chaos + HA demo: a 3-replica Raft cluster keeps assigning work while a
chaos monkey kills executors AND the leader replica is partitioned away
(paper §3.4 + §3.4.1 + Fig. 3).

    PYTHONPATH=src python examples/failover_demo.py --processes 20
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.core import Colonies, Crypto, ExecutorBase, FunctionSpec, InProcTransport
from repro.core.cluster import HAColonyCluster
from repro.runtime.chaos import ChaosMonkey


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--processes", type=int, default=20)
    ap.add_argument("--replicas", type=int, default=3)
    args = ap.parse_args()

    server_prv, colony_prv = Crypto.prvkey(), Crypto.prvkey()
    cluster = HAColonyCluster(Crypto.id(server_prv), replicas=args.replicas, seed=1)
    cluster.start(failsafe_interval=0.2)
    assert cluster.wait_for_leader(10)
    client = Colonies(InProcTransport(cluster.servers))
    client.add_colony("chaos", Crypto.id(colony_prv), server_prv)

    pool: list[ExecutorBase] = []
    counter = [0]

    def spawn() -> None:
        counter[0] += 1
        ex = ExecutorBase(client, "chaos", f"w{counter[0]}", "worker",
                          colony_prvkey=colony_prv)
        ex.register_function("work", lambda ctx, i: time.sleep(0.1) or [i])
        ex.start(poll_timeout=0.3)
        pool.append(ex)

    def kill() -> None:
        if len(pool) > 1:
            victim = pool.pop(0)
            victim.stop()

    for _ in range(3):
        spawn()
    monkey = ChaosMonkey(kill, spawn, interval=(0.3, 0.8), seed=2)
    monkey.start()

    pids = []
    for i in range(args.processes):
        p = client.submit(FunctionSpec.from_dict({
            "conditions": {"colonyname": "chaos", "executortype": "worker"},
            "funcname": "work", "args": [i],
            "maxexectime": 3, "maxretries": 10,
        }), colony_prv)
        pids.append(p["processid"])
    print(f"{len(pids)} processes submitted; chaos monkey active")

    # partition the raft leader mid-flight
    time.sleep(1.0)
    lid = cluster.raft.leader_id()
    print(f"partitioning leader replica {lid} ...")
    cluster.kill_server(int(lid[1:]))

    results = []
    for pid in pids:
        done = client.wait(pid, colony_prv, timeout=120)
        results.append(done["out"][0])
    monkey.stop()

    stats = client.stats("chaos", colony_prv)
    print(f"all {len(results)} processes completed: {sorted(results) == list(range(args.processes))}")
    print(f"executors killed by chaos monkey: {monkey.kills}")
    print(f"new leader: {cluster.raft.leader_id()} (was {lid})")
    print(f"colony stats: {stats}")
    for ex in pool:
        ex.stop()
    cluster.stop()


if __name__ == "__main__":
    main()
