"""Quickstart — the paper's §4.1 walkthrough end to end.

Boots an in-process Colonies server, registers a helloworld executor with
a colony (Listing 3), submits a function specification (Listings 1/5),
lets the executor pick it up (Listing 4), then runs the Listing-6-style
diamond workflow with real dataflow (Tables 1-4).

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

from repro.core import (
    Colonies,
    Crypto,
    ExecutorBase,
    FunctionSpec,
    InProcTransport,
    WorkflowSpec,
)
from repro.core.cluster import standalone_server


def main() -> None:
    # --- the colony --------------------------------------------------------
    server_prv, colony_prv = Crypto.prvkey(), Crypto.prvkey()
    server = standalone_server(Crypto.id(server_prv))
    server.start_background(failsafe_interval=0.1)
    colonies = Colonies(InProcTransport([server]))
    colonies.add_colony("dev", Crypto.id(colony_prv), server_prv)
    print("colony 'dev' registered; colonyid =", Crypto.id(colony_prv)[:16], "…")

    # --- Listing 3: a helloworld executor ------------------------------------
    ex = ExecutorBase(
        colonies, "dev", "helloworld_executor", "helloworld_executor",
        colony_prvkey=colony_prv,
    )
    ex.register_function("helloworld", lambda ctx: ["hello world"])
    ex.register_function("gen_nums", lambda ctx: [2, 3])
    ex.register_function("square0", lambda ctx: [ctx.inputs[0] ** 2])
    ex.register_function("square1", lambda ctx: [ctx.inputs[1] ** 2])
    ex.register_function("sum", lambda ctx: [sum(ctx.inputs)])
    ex.start(poll_timeout=0.2)

    # --- Listing 1/5: submit a function specification ------------------------
    spec = FunctionSpec.from_dict({
        "conditions": {"colonyname": "dev", "executortype": "helloworld_executor"},
        "funcname": "helloworld",
        "args": [],
        "maxwaittime": 10,
        "maxexectime": 100,
        "maxretries": 3,
        "priority": 1,
    })
    p = colonies.submit(spec, colony_prv)
    done = colonies.wait(p["processid"], colony_prv, timeout=10)
    print("helloworld ->", done["out"], f"({done['state']})")

    # --- Tables 1-4: the diamond workflow with dataflow ----------------------
    wf = WorkflowSpec.from_dict({
        "colonyname": "dev",
        "functionspecs": [
            {"nodename": "t1", "funcname": "gen_nums",
             "conditions": {"executortype": "helloworld_executor", "dependencies": []}},
            {"nodename": "t2", "funcname": "square0",
             "conditions": {"executortype": "helloworld_executor", "dependencies": ["t1"]}},
            {"nodename": "t3", "funcname": "square1",
             "conditions": {"executortype": "helloworld_executor", "dependencies": ["t1"]}},
            {"nodename": "t4", "funcname": "sum",
             "conditions": {"executortype": "helloworld_executor",
                            "dependencies": ["t2", "t3"]}},
        ],
    })
    r = colonies.submit_workflow(wf, colony_prv)
    last = colonies.wait(r["processes"][-1]["processid"], colony_prv, timeout=15)
    print(f"workflow: gen_nums=[2,3] -> squares -> sum = {last['out']}  "
          f"(inputs were {last['in']})")
    assert last["out"] == [13]

    stats = colonies.stats("dev", colony_prv)
    print("colony stats:", stats)
    ex.stop()
    server.stop()


if __name__ == "__main__":
    main()
